"""Serving engine over the pooled KV cache: slot-based continuous batching.

Two serving surfaces share one decode substrate:

  * :meth:`Engine.generate` — one-shot batched greedy decode (every row
    shares a prompt length). The decode loop runs as jitted
    ``lax.scan`` chunks of ``sync_interval`` steps; done rows are masked
    ON-DEVICE with ``jnp.where`` and the host reads the done mask only at
    chunk boundaries (one explicit ``device_get`` per chunk, counted in
    ``last_stats["host_syncs"]``) — there is NO per-token device->host
    round-trip.
  * :meth:`Engine.serve` — continuous batching. The KV cache is a pool of
    ``n_slots`` sequence slots (:meth:`init_pool`); a
    :class:`~repro.serve.scheduler.Scheduler` admits queued requests into
    free slots at drain boundaries, a jitted admission step prefills the
    prompt and scatters its cache rows into the pool
    (:meth:`~repro.models.api.Model.slot_update`) without touching in-flight
    rows, and every chunk decodes ALL slots in one batched step with
    per-slot ``cache_len`` vectors. Finished sequences free their slots for
    immediate reuse. When the scheduler carries a
    :class:`~repro.serve.scheduler.PageGeometry`, serving switches to the
    **paged two-tier pool** (:meth:`init_paged_pool`): KV storage is a flat
    layer-0 page pool addressed through per-slot block tables, admission
    reserves *pages* instead of ``max_len`` slabs, and when layer 0 runs
    out the youngest resident spills verbatim to the layer-1 tier — the
    paper's two-die capacity split, applied to serving. A scheduler built
    with ``prefix_share=True`` additionally executes prefix-index hits as
    **suffix-only prefills** over ref-counted shared pages
    (:meth:`_shared_paged_admit`), turning shared-prefix TTFT compute from
    O(prompt) into O(suffix) — DESIGN.md §Prefix sharing & copy-on-write.

The cache layout is the pooled-memory design (DESIGN.md §Pooled KV cache):
sequence dim sharded across the `model` axis, so aggregate pod HBM is one
big KV pool — MemPool's shared L1, at cluster scale. The slot count is
derived from the SAME CapacityPartition budget formula as kernel tiles
(:func:`repro.serve.scheduler.derive_n_slots`).

Kernel block plans are obtained ONCE at engine construction from the model's
planner (sized for ``max_len`` on the current hardware target) and threaded
into every prefill/decode call — serving never re-plans per step.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models.api import Model
from repro.serve import scheduler as sched_mod
from repro.serve import speculate as spec_mod


@dataclasses.dataclass
class EngineConfig:
    """max_len bounds prompt + generation (the KV slot depth).

    ``sync_interval`` is the decode-chunk length: how many on-device steps
    run between host syncs (batch-drain boundaries). ``prompt_pad_multiple``
    right-pads slot prompts up to a multiple to bound prefill recompiles;
    it must stay ``None`` (exact-length prefill) for models with recurrent
    SSM layers, whose state would integrate the pad tokens.

    ``speculate_tokens`` (k) turns on self-drafting speculative decoding in
    the serve loops (DESIGN.md §Speculative decoding): each drain boundary
    proposes up to k draft tokens per live slot from the slot's own
    emitted+prompt history and scores them all in ONE width-(k+1) verify
    forward, emitting accepted-prefix + 1 tokens per slot per boundary.
    Greedy outputs are bit-exact with ``speculate_tokens=0``. Requires
    attention-only models (recurrent SSM state cannot roll back rejected
    draft tokens); size k with
    :func:`repro.serve.scheduler.derive_speculate_tokens`.

    ``phase_timing`` turns on the per-phase wall-clock breakdown
    (prefill / insert / generate / drain) in ``last_stats`` — benchmark
    mode only: each phase blocks on its device work, which serializes the
    dispatch pipeline the serve loop otherwise overlaps.

    ``mesh`` (a ``jax.sharding.Mesh``, e.g. from
    :func:`repro.launch.mesh.make_host_mesh`) runs every jitted engine
    function under that mesh: model weights are placed tensor-parallel
    (``repro.distributed.sharding.named_shardings``), KV pools/pages are
    placed on the head axis when the model's heads divide the `model` axis
    (DESIGN.md §Sharded serving), and GSPMD partitions the admission /
    decode / verify computations. ``None`` (default) is today's
    single-device path, bit-identical by construction; a 1x1 mesh is also
    bit-identical (every constraint resolves to replication). The
    one-host-sync-per-drain-boundary discipline is mesh-invariant: the
    block-table upload (host->device) and the drain fetch are the only
    host <-> device edges per boundary, regardless of mesh size.
    """

    max_len: int
    eos_token: int = 1
    greedy: bool = True
    sync_interval: int = 8
    pad_token: int = 0
    prompt_pad_multiple: Optional[int] = None
    speculate_tokens: int = 0
    phase_timing: bool = False
    mesh: Optional[Any] = None


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PoolState:
    """Device-side state of the KV slot pool (batch axis = slot index).

    ``block_tables`` is ``None`` for the dense slot-slab pool; in paged
    mode it is the ``(S, P)`` int32 map from each slot's logical page index
    to a physical page of the flat layer-0 page pool (null page 0 for
    unmapped entries). The host rebuilds and uploads it at every drain
    boundary from the scheduler's page mappings.
    """

    state: Dict[str, Any]       # model caches (+aux), slot- or page-major
    tok: jax.Array              # (S,) int32 — last emitted token per slot
    cache_len: jax.Array        # (S,) int32 — filled KV prefix per slot
    done: jax.Array             # (S,) bool — drained/empty slot mask
    n_gen: jax.Array            # (S,) int32 — tokens emitted per occupant
    budget: jax.Array           # (S,) int32 — occupant's max_new_tokens
    block_tables: Optional[jax.Array] = None    # (S, P) int32, paged only


@dataclasses.dataclass
class ServeReport:
    """Result of one :meth:`Engine.serve` run over a request stream."""

    requests: List[sched_mod.Request]
    stats: Dict[str, Any]

    @property
    def outputs(self) -> Dict[int, List[int]]:
        return {r.rid: r.tokens for r in self.requests}


class Engine:
    def __init__(self, model: Model, params: Any, ecfg: EngineConfig):
        self.model = model
        self.mesh = ecfg.mesh
        if self.mesh is not None:
            # tensor-parallel weight placement; cache pools are placed by
            # _place at init and the jitted fns run under _mesh_scope
            params = jax.device_put(
                params, shd.named_shardings(params, self.mesh))
        self.params = params
        self.ecfg = ecfg
        # one capacity-partitioned plan set for the whole engine lifetime
        self.plans = model.kernel_plans(ecfg.max_len, ecfg.max_len)
        self._chunk_fns: Dict[int, Any] = {}        # one-shot decode chunks
        self._pool_chunk_fns: Dict[int, Any] = {}   # pooled decode chunks
        self._verify_fns: Dict[int, Any] = {}       # speculative verify, by k
        self._admit = self._make_admit_fn()
        self._paged_admit_fns: Dict[Any, Any] = {}  # keyed by page geometry
        self._suffix_admit_fns: Dict[Any, Any] = {}  # + static prefix_len
        # chunked prefill (DESIGN.md §Chunked prefill): jit variants keyed
        # by POWER-OF-TWO padded chunk length (+ emit_first), never by the
        # runtime cursor — O(log chunk_tokens) compiles total
        self._chunk_prefill_fns: Dict[Any, Any] = {}        # paged
        self._dense_chunk_prefill_fns: Dict[Any, Any] = {}  # dense
        self._tier_copy = None      # jitted layer-0 <-> layer-1 copy
        self.last_stats: Dict[str, Any] = {}
        if ecfg.prompt_pad_multiple and self._has_ssm():
            raise ValueError(
                "prompt_pad_multiple requires attention-only models: SSM "
                "recurrences integrate pad tokens (see EngineConfig)")
        if ecfg.speculate_tokens and self._has_ssm():
            raise ValueError(
                "speculative decoding requires attention-only models: "
                "recurrent SSM state cannot roll back rejected draft "
                "tokens (docs/SERVING.md)")

    def _has_ssm(self) -> bool:
        return any(kind.attn == "mamba"
                   for group in self.model.cfg.layer_groups()
                   for kind in group.pattern)

    # -------------------------------------------------------------- mesh
    def _mesh_scope(self):
        """Ambient-mesh context for every traced/jitted engine call.

        With ``EngineConfig(mesh=...)`` set, entering the scope makes the
        ``repro.distributed.sharding.shard`` constraints inside the model
        live (head-axis KV placement, batch sharding); without one it is a
        null context and every constraint no-ops — the single-device path
        is untouched."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return shd.use_mesh(self.mesh)

    def _place(self, tree):
        """Commit a cache/pool tree to its mesh shardings (identity without
        a mesh): head-axis placement for GQA caches/pages, replication for
        latent/SSM state and scalars (``spec_for_cache``)."""
        if self.mesh is None:
            return tree
        return jax.device_put(tree, shd.named_shardings(tree, self.mesh))

    # ------------------------------------------------------------ host IO
    def _fetch(self, tree):
        """The ONLY device->host read path. One explicit transfer per call,
        issued at batch-drain boundaries; counted for the regression test."""
        self.last_stats["host_syncs"] = self.last_stats.get("host_syncs", 0) + 1
        return jax.device_get(tree)

    def _timed(self, phase: str, fn, *args):
        """Run ``fn`` and, in ``phase_timing`` mode, charge its wall time
        (blocked on device completion) to ``last_stats['phase_s'][phase]``.
        Off by default: blocking per phase would serialize the dispatch
        pipeline the serve loop overlaps."""
        if not self.ecfg.phase_timing:
            return fn(*args)
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        acc = self.last_stats.setdefault("phase_s", {})
        acc[phase] = acc.get(phase, 0.0) + (time.perf_counter() - t0)
        return out

    @staticmethod
    def _bucket_len(n: int, limit: int) -> int:
        """Next power of two >= n, clamped so the chunk write stays inside
        the cache depth — the static lengths chunk prefill compiles for."""
        return min(1 << (int(n) - 1).bit_length(), limit)

    # ---------------------------------------------------------- one-shot
    def prefill(self, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        logits, state = self.model.prefill(self.params, batch,
                                           self.ecfg.max_len,
                                           plans=self.plans)
        return logits, state

    def _decode_chunk(self, n: int):
        """Jitted: n decode steps with on-device EOS masking (lax.scan)."""
        if n not in self._chunk_fns:
            cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans

            def run(params, tok, state, cache_len, done):
                def step(carry, _):
                    tok, state, cache_len, done = carry
                    logits, state = self.model.decode_step(
                        params, tok[:, None], state, cache_len, plans=plans)
                    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
                    tok = jnp.where(done, ecfg.eos_token, nxt)
                    done = done | (tok == ecfg.eos_token)
                    return (tok, state, cache_len + 1, done), tok

                carry, toks = jax.lax.scan(step, (tok, state, cache_len, done),
                                           None, length=n)
                tok, state, cache_len, done = carry
                return jnp.moveaxis(toks, 0, 1), tok, state, cache_len, done

            self._chunk_fns[n] = jax.jit(run)
        return self._chunk_fns[n]

    def generate(self, batch: Dict[str, jax.Array], n_steps: int,
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Greedy continuation. Returns (tokens (B, <=n_steps), final_state).

        Rows that hit EOS are frozen on-device (EOS fill); the host checks
        the done mask once per ``sync_interval`` chunk and stops early at
        that granularity — never per token.
        """
        with self._mesh_scope():
            return self._generate_impl(batch, n_steps)

    def _generate_impl(self, batch: Dict[str, jax.Array], n_steps: int,
                       ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        self.last_stats = {"host_syncs": 0, "decode_steps": 0}
        cfg = self.model.cfg
        logits, state = self.prefill(batch)
        prompt_len = batch["tokens"].shape[1]
        if cfg.family != "encdec" and cfg.frontend_len:
            prompt_len += cfg.frontend_len
        cache_len = jnp.asarray(prompt_len, jnp.int32)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        done = tok == self.ecfg.eos_token
        out: List[jnp.ndarray] = [tok[:, None]]
        left = n_steps - 1
        while left > 0:
            n = min(self.ecfg.sync_interval, left)
            toks, tok, state, cache_len, done = self._decode_chunk(n)(
                self.params, tok, state, cache_len, done)
            out.append(toks)
            left -= n
            self.last_stats["decode_steps"] += n
            # drain boundary: one explicit host read, then maybe early-exit
            if left > 0 and bool(self._fetch(done).all()):
                break
        return jnp.concatenate(out, axis=1), state

    # ------------------------------------------------------------- pool
    def init_pool(self, n_slots: int) -> PoolState:
        """Empty slot pool: all slots done (free), caches zeroed."""
        cfg = self.model.cfg
        if cfg.family == "encdec":
            raise NotImplementedError(
                "pooled serving targets decoder-only families; encdec "
                "requests go through one-shot generate()")
        if cfg.frontend_len:
            raise NotImplementedError(
                "pooled serving takes token prompts; frontend-embed "
                "requests go through one-shot generate()")
        from repro.models import transformer
        state = {"caches": transformer.init_caches(cfg, n_slots,
                                                   self.ecfg.max_len)}
        zeros = jnp.zeros((n_slots,), jnp.int32)
        return self._place(PoolState(
            state=state,
            tok=jnp.full((n_slots,), self.ecfg.pad_token, jnp.int32),
            cache_len=zeros,
            done=jnp.ones((n_slots,), bool),
            n_gen=zeros, budget=zeros))

    def _pad_prompt(self, prompt: np.ndarray) -> Tuple[np.ndarray, int]:
        true_len = int(prompt.shape[0])
        if true_len > self.ecfg.max_len:
            raise ValueError(
                f"prompt of {true_len} tokens exceeds the KV slot depth "
                f"(max_len={self.ecfg.max_len})")
        m = self.ecfg.prompt_pad_multiple
        if not m:
            return prompt, true_len
        # clamp: the padded buffer must still fit the slot's KV depth
        padded = min(-(-true_len // m) * m, self.ecfg.max_len)
        if padded == true_len:
            return prompt, true_len
        out = np.full((padded,), self.ecfg.pad_token, np.int32)
        out[:true_len] = prompt
        return out, true_len

    def _make_admit_fn(self):
        """Jitted admission: prefill one prompt row and scatter it into the
        pool at ``slot`` — in-flight slots are untouched (pure row insert).
        One function; jit's shape-keyed cache retraces per padded prompt
        length (bounded by ``prompt_pad_multiple`` bucketing)."""
        cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans

        def run(params, tokens, true_len, budget, slot, pool: PoolState):
            last = (true_len - 1)[None]                     # (1,) gather
            logits, row = self.model.prefill(
                params, {"tokens": tokens}, ecfg.max_len, plans=plans,
                last_pos=last)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            state = self.model.slot_update(pool.state, row, slot)
            kv_len = true_len                               # filled prefix
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (kv_len >= ecfg.max_len))
            return PoolState(
                state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(kv_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def admit_into_slot(self, pool: PoolState, slot: int,
                        prompt: np.ndarray, max_new_tokens: int
                        ) -> Tuple[PoolState, jax.Array]:
        """Prefill ``prompt`` into ``slot``. Returns (pool, first_token) —
        the token stays on device; callers fetch it at the next drain."""
        tokens, true_len = self._pad_prompt(np.asarray(prompt, np.int32))
        return self._admit(self.params, tokens[None],
                           jnp.asarray(true_len, jnp.int32),
                           jnp.asarray(max_new_tokens, jnp.int32),
                           jnp.asarray(slot, jnp.int32), pool)

    def _pool_chunk(self, n: int):
        """Jitted: n batched decode steps over ALL slots with per-slot
        cache_len vectors and on-device done masking. Emits per-step
        (token, was_active) pairs; the host sees them only after the chunk."""
        if n not in self._pool_chunk_fns:
            cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans

            def run(params, pool: PoolState):
                def step(pool: PoolState, _):
                    logits, state = self.model.decode_step(
                        params, pool.tok[:, None], pool.state, pool.cache_len,
                        plans=plans, block_tables=pool.block_tables)
                    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
                    was_done = pool.done
                    tok = jnp.where(was_done, ecfg.eos_token,
                                    nxt).astype(jnp.int32)
                    n_gen = jnp.where(was_done, pool.n_gen, pool.n_gen + 1)
                    cache_len = jnp.where(was_done, pool.cache_len,
                                          pool.cache_len + 1)
                    done = (was_done | (tok == ecfg.eos_token)
                            | (n_gen >= pool.budget)
                            | (cache_len >= ecfg.max_len))
                    new = PoolState(state=state, tok=tok, cache_len=cache_len,
                                    done=done, n_gen=n_gen,
                                    budget=pool.budget,
                                    block_tables=pool.block_tables)
                    return new, (tok, ~was_done)

                pool, (toks, valid) = jax.lax.scan(step, pool, None, length=n)
                return pool, toks, valid        # (n, S) each

            self._pool_chunk_fns[n] = jax.jit(run)
        return self._pool_chunk_fns[n]

    # ------------------------------------------- speculative verify chunk
    def _verify_fn(self, k: int):
        """Jitted speculative boundary: ONE width-(k+1) verify forward over
        ALL slots, folded into the pool's done-masked updates (DESIGN.md
        §Speculative decoding).

        Each slot's verify row is its last emitted token followed by its k
        host-proposed drafts, so the forward's argmax column j is exactly
        what the j-th sequential :meth:`_pool_chunk` step would have
        produced — :func:`repro.serve.speculate.fold_acceptance` then
        emits the longest agreeing prefix plus one correction token and
        rolls ``cache_len`` back over the rejected suffix. Output shape
        matches :meth:`_pool_chunk`'s ``(steps, S)`` tokens/valid pair
        (steps = k+1 candidate positions), so the drain loop is unchanged.
        Done slots emit nothing; their junk K/V writes land in their own
        slab/pages (or the null page) exactly like the single-token path's
        frozen decode.
        """
        if k not in self._verify_fns:
            cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans

            def run(params, pool: PoolState, drafts, dlen):
                tokens = jnp.concatenate([pool.tok[:, None], drafts], axis=1)
                logits, state = self.model.verify_step(
                    params, tokens, pool.state, pool.cache_len, plans=plans,
                    block_tables=pool.block_tables)
                targets = jnp.argmax(logits[:, :, :cfg.vocab_size],
                                     axis=-1).astype(jnp.int32)   # (S, k+1)
                fold = spec_mod.fold_acceptance(
                    targets, drafts, dlen, done=pool.done, n_gen=pool.n_gen,
                    budget=pool.budget, cache_len=pool.cache_len,
                    max_len=ecfg.max_len, eos_token=ecfg.eos_token)
                toks = jnp.where(fold.valid, targets, ecfg.eos_token)
                new = PoolState(state=state, tok=fold.tok,
                                cache_len=fold.cache_len, done=fold.done,
                                n_gen=fold.n_gen, budget=pool.budget,
                                block_tables=pool.block_tables)
                return new, toks.astype(jnp.int32).T, fold.valid.T

            self._verify_fns[k] = jax.jit(run)
        return self._verify_fns[k]

    def _build_drafts(self, sch: sched_mod.Scheduler, k: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side draft proposal for every live slot (drain boundary).

        Proposes from the slot's host-mirrored prompt+emitted context via
        :func:`repro.serve.speculate.propose_ngram`. Slots without a
        proposable context — free, mid-chunked-prefill, or admitted this
        very boundary (first token still on device in ``pending_first``) —
        get ``dlen = 0``, which the fold degrades to an ordinary
        single-token step.
        """
        drafts = np.zeros((sch.n_slots, k), np.int32)
        dlen = np.zeros((sch.n_slots,), np.int32)
        for slot, req in sch.active.items():
            if req.status != sched_mod.DECODING or not req.tokens:
                continue
            ctx = np.concatenate([np.asarray(req.prompt, np.int32),
                                  np.asarray(req.tokens, np.int32)])
            d = spec_mod.propose_ngram(ctx, k)
            drafts[slot, :d.shape[0]] = d
            dlen[slot] = d.shape[0]
        return drafts, dlen

    # ------------------------------------------------- paged two-tier pool
    def init_paged_pool(self, sch: sched_mod.Scheduler
                        ) -> Tuple[PoolState, Dict[str, Any]]:
        """Empty paged pool + the layer-1 spill tier's device arrays.

        Layer 0 is a flat page pool shared by all slots (block tables map
        slots to pages); layer 1 mirrors it at the spill budget, plus one
        resident "seat" per spill page for recurrent SSM state (a spilled
        sequence holds at least one page, so seats cannot run out first).
        """
        geom = sch.pages
        assert geom is not None, "init_paged_pool needs a paged scheduler"
        cfg = self.model.cfg
        if cfg.family == "encdec" or cfg.frontend_len:
            raise NotImplementedError(
                "paged serving targets decoder-only token-prompt models; "
                "others go through one-shot generate()")
        from repro.models import transformer
        n_slots = sch.n_slots
        state = {"caches": transformer.init_paged_caches(
            cfg, n_slots, geom.n_pages, geom.page_tokens)}
        spill = transformer.init_paged_caches(
            cfg, geom.n_spill_pages, geom.n_spill_pages, geom.page_tokens)
        zeros = jnp.zeros((n_slots,), jnp.int32)
        pool = PoolState(
            state=state,
            tok=jnp.full((n_slots,), self.ecfg.pad_token, jnp.int32),
            cache_len=zeros, done=jnp.ones((n_slots,), bool),
            n_gen=zeros, budget=zeros,
            block_tables=jnp.zeros((n_slots, geom.max_pages_per_slot),
                                   jnp.int32))
        return self._place(pool), self._place(spill)

    def _make_paged_admit_fn(self, geom: sched_mod.PageGeometry):
        """Jitted paged admission: prefill one prompt row at the pool's
        page-aligned depth, cut it into pages and scatter them at the
        slot's block-table row. In-flight pages are untouched."""
        cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans
        depth, pt = geom.depth, geom.page_tokens

        def run(params, tokens, true_len, budget, slot, block_row,
                pool: PoolState):
            last = (true_len - 1)[None]                 # (1,) gather
            logits, row = self.model.prefill(
                params, {"tokens": tokens}, depth, plans=plans, last_pos=last)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            state = self.model.slot_update_paged(pool.state, row, slot,
                                                 block_row, pt)
            kv_len = true_len
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (kv_len >= ecfg.max_len))
            return dataclasses.replace(
                pool, state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(kv_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def _paged_admit(self, pool: PoolState, slot: int,
                     req: sched_mod.Request, geom: sched_mod.PageGeometry
                     ) -> Tuple[PoolState, jax.Array]:
        tokens, true_len = self._pad_prompt(np.asarray(req.prompt, np.int32))
        block_row = self._pad_pages(req.pages, geom.max_pages_per_slot)
        key = (geom.depth, geom.page_tokens)
        if key not in self._paged_admit_fns:
            self._paged_admit_fns[key] = self._make_paged_admit_fn(geom)
        return self._paged_admit_fns[key](
            self.params, tokens[None], jnp.asarray(true_len, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(slot, jnp.int32), block_row, pool)

    def _make_suffix_admit_fn(self, geom: sched_mod.PageGeometry,
                              prefix_len: int):
        """Jitted cache-hit admission: prefill ONLY the unmatched suffix.

        The shared prefix pages (plus the copy-on-write source, when the
        match ends mid-page) are gathered into a dense batch-1 view, the
        suffix runs through ``Model.prefill`` at a static ``prefix_len``
        offset (RoPE positions and causal masks continue where the shared
        prefix ends — bit-identical to the same rows of a full prefill),
        and the result is scattered back through ``write_row``, whose
        entries for shared pages point at null page 0: shared history is
        never written, and the frontier page lands in the request's fresh
        private page (the COW copy rides the gather->scatter cycle).
        TTFT compute drops from O(prompt) to O(suffix).
        """
        cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans
        depth, pt = geom.depth, geom.page_tokens

        def run(params, tokens, true_len, budget, slot, read_row, write_row,
                pool: PoolState):
            prefix = self.model.gather_row_paged(pool.state, read_row, pt)
            last = (true_len - 1)[None]                 # (1,) gather
            logits, row = self.model.prefill(
                params, {"tokens": tokens}, depth, plans=plans, last_pos=last,
                prefix_len=prefix_len, prefix_state=prefix)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            state = self.model.slot_update_paged(pool.state, row, slot,
                                                 write_row, pt)
            kv_len = true_len + prefix_len
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (kv_len >= ecfg.max_len))
            return dataclasses.replace(
                pool, state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(kv_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def _shared_paged_admit(self, pool: PoolState, slot: int,
                            req: sched_mod.Request,
                            geom: sched_mod.PageGeometry
                            ) -> Tuple[PoolState, jax.Array]:
        """Execute a prefix-index-hit admission planned by the scheduler.

        ``read_row`` maps the pages the suffix attends over: the shared
        full pages, plus — when the match ends mid-page — the COW *source*
        page at the frontier index. ``write_row`` maps where suffix K/V
        lands: null (page 0) under the shared prefix, the request's own
        fresh pages from the frontier on. The frontier page is therefore
        read from the canonical copy but written to a private one.
        """
        pt, p_max = geom.page_tokens, geom.max_pages_per_slot
        suffix = np.asarray(req.prompt, np.int32)[req.prefix_len:]
        tokens, true_len = self._pad_prompt(suffix)
        if req.prefix_len + tokens.shape[0] > geom.depth:
            tokens = tokens[:geom.depth - req.prefix_len]   # trim pad only
        f_w = req.prefix_len // pt                  # frontier logical page
        read = np.zeros((p_max,), np.int32)
        read[:req.n_shared] = req.pages[:req.n_shared]
        if req.cow_src >= 0:
            read[f_w] = req.cow_src
        write = np.zeros((p_max,), np.int32)
        write[f_w:len(req.pages)] = req.pages[f_w:]
        key = (geom.depth, pt, req.prefix_len, tokens.shape[0])
        if key not in self._suffix_admit_fns:
            self._suffix_admit_fns[key] = self._make_suffix_admit_fn(
                geom, req.prefix_len)
        return self._suffix_admit_fns[key](
            self.params, tokens[None], jnp.asarray(true_len, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(slot, jnp.int32), jnp.asarray(read),
            jnp.asarray(write), pool)

    # ------------------------------------------------- chunked prefill
    def _make_chunk_prefill_fn(self, geom: sched_mod.PageGeometry,
                               n_tok: int, emit_first: bool):
        """Jitted partial-prefill step: run ONE chunk of a prompt and
        scatter its K/V into the request's pages (DESIGN.md §Chunked
        prefill).

        The chunk cursor ``start`` and true length ``true_n`` are TRACED
        int32 scalars — the jit cache is keyed only by the power-of-two
        padded chunk length (plus ``emit_first``), never by where in the
        prompt the chunk lands, so a 4k-token prompt compiles the same
        O(log chunk_tokens) variants as a 64-token one. A traced cursor
        rides the same resumed-prefill path as the static-offset suffix
        admission: positions and causal masks continue at ``start``
        (bit-identical rows), and the traced offset forces the jnp
        reference attention (the Pallas kernel needs a static grid
        offset). Non-final chunks only advance ``cache_len`` — the slot
        stays done-masked, so the interleaved decode chunk freezes it for
        free. The final chunk emits the first output token and arms the
        slot exactly like an unchunked admission.
        """
        cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans
        depth, pt = geom.depth, geom.page_tokens

        def run(params, tokens, start, true_n, budget, slot, read_row,
                write_row, pool: PoolState):
            prefix = self.model.gather_row_paged(pool.state, read_row, pt)
            last = (true_n - 1)[None]                   # (1,) gather
            logits, row = self.model.prefill(
                params, {"tokens": tokens}, depth, plans=plans, last_pos=last,
                prefix_len=start, prefix_state=prefix)
            state = self.model.slot_update_paged(pool.state, row, slot,
                                                 write_row, pt)
            new_len = start + true_n
            if not emit_first:
                # done=True is NOT redundant: a slot freed by preempting a
                # mid-decode request still carries done=False on device —
                # without the mask the interleaved decode chunk would
                # decode the half-prefilled slot
                return dataclasses.replace(
                    pool, state=state,
                    cache_len=pool.cache_len.at[slot].set(new_len),
                    done=pool.done.at[slot].set(True),
                ), jnp.zeros((), jnp.int32)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (new_len >= ecfg.max_len))
            return dataclasses.replace(
                pool, state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(new_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def _exec_prefill_chunk(self, pool: PoolState, step: sched_mod.PrefillStep,
                            geom: sched_mod.PageGeometry
                            ) -> Tuple[PoolState, jax.Array]:
        """Execute one planned :class:`~repro.serve.scheduler.PrefillStep`.

        ``read_row`` maps every page holding KV the chunk attends over:
        the request's own pages below the cursor — which are the SHARED
        prefix pages for its leading entries — plus the copy-on-write
        source when the first chunk starts at a mid-page prefix match.
        ``write_row`` maps the pages the chunk's K/V lands in, from the
        cursor's page on (whole-page scatter re-writes the frontier page's
        earlier tokens with the very content just gathered, so a COW source
        is copied private on the first chunk for free)."""
        req = step.req
        pt, p_max = geom.page_tokens, geom.max_pages_per_slot
        n_pad = self._bucket_len(step.n_tokens, geom.depth)
        if step.start + n_pad > geom.depth:
            # slot-depth edge: exact length, or the traced-start cache
            # write would clamp backwards over earlier chunks (rare tail
            # variant; never hit while prompt + chunk fit the depth)
            n_pad = step.n_tokens
        tokens = np.full((n_pad,), self.ecfg.pad_token, np.int32)
        tokens[:step.n_tokens] = np.asarray(req.prompt, np.int32)[
            step.start:step.start + step.n_tokens]
        f_r = -(-step.start // pt)              # pages covering [0, start)
        read = np.zeros((p_max,), np.int32)
        read[:f_r] = req.pages[:f_r]
        if step.start == req.prefix_len and req.cow_src >= 0:
            read[step.start // pt] = req.cow_src
        f_w = step.start // pt                  # cursor's (frontier) page
        end_pages = geom.pages_for(step.start + step.n_tokens)
        write = np.zeros((p_max,), np.int32)
        write[f_w:end_pages] = req.pages[f_w:end_pages]
        key = (geom.depth, pt, n_pad, step.final)
        if key not in self._chunk_prefill_fns:
            self._chunk_prefill_fns[key] = self._make_chunk_prefill_fn(
                geom, n_pad, step.final)
        return self._chunk_prefill_fns[key](
            self.params, tokens[None], jnp.asarray(step.start, jnp.int32),
            jnp.asarray(step.n_tokens, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(step.slot, jnp.int32), jnp.asarray(read),
            jnp.asarray(write), pool)

    def _make_dense_chunk_prefill_fn(self, n_tok: int, emit_first: bool):
        """Dense-pool analog of :meth:`_make_chunk_prefill_fn`: the chunk
        attends over the slot's own slab (earlier chunks' K/V gathered by
        :meth:`~repro.models.api.Model.gather_row`) and the whole updated
        row is scattered back. Same traced cursor, same bucketed jit key."""
        cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans

        def run(params, tokens, start, true_n, budget, slot,
                pool: PoolState):
            prefix = self.model.gather_row(pool.state, slot)
            last = (true_n - 1)[None]                   # (1,) gather
            logits, row = self.model.prefill(
                params, {"tokens": tokens}, ecfg.max_len, plans=plans,
                last_pos=last, prefix_len=start, prefix_state=prefix)
            state = self.model.slot_update(pool.state, row, slot)
            new_len = start + true_n
            if not emit_first:
                return dataclasses.replace(
                    pool, state=state,
                    cache_len=pool.cache_len.at[slot].set(new_len),
                    done=pool.done.at[slot].set(True),
                ), jnp.zeros((), jnp.int32)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (new_len >= ecfg.max_len))
            return dataclasses.replace(
                pool, state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(new_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def _exec_dense_chunk(self, pool: PoolState, step: sched_mod.PrefillStep
                          ) -> Tuple[PoolState, jax.Array]:
        req = step.req
        n_pad = self._bucket_len(step.n_tokens, self.ecfg.max_len)
        if step.start + n_pad > self.ecfg.max_len:
            n_pad = step.n_tokens           # slab edge: exact tail length
        tokens = np.full((n_pad,), self.ecfg.pad_token, np.int32)
        tokens[:step.n_tokens] = np.asarray(req.prompt, np.int32)[
            step.start:step.start + step.n_tokens]
        key = (n_pad, step.final)
        if key not in self._dense_chunk_prefill_fns:
            self._dense_chunk_prefill_fns[key] = \
                self._make_dense_chunk_prefill_fn(n_pad, step.final)
        return self._dense_chunk_prefill_fns[key](
            self.params, tokens[None], jnp.asarray(step.start, jnp.int32),
            jnp.asarray(step.n_tokens, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(step.slot, jnp.int32), pool)

    def _tier_copy_fn(self):
        """ONE jitted layer-0 <-> layer-1 copy, shared by spill and restore
        (jit's shape-keyed cache traces each direction independently).

        Page pools move whole pages (gather by source ids, scatter at
        destination ids — padded entries route through the null pages);
        recurrent per-slot state moves one row between the slot axis and
        the spill seat axis. Everything stays on device.
        """
        if self._tier_copy is not None:
            return self._tier_copy
        from repro.models import transformer
        cfg = self.model.cfg

        def copy(src_caches, dst_caches, row_src, row_dst, pages_src,
                 pages_dst):
            def page_copy(s, d):
                return d.at[:, pages_dst].set(s[:, pages_src].astype(d.dtype))

            def row_copy(s, d):
                row = jax.lax.dynamic_slice_in_dim(s, row_src, 1, axis=1)
                return jax.lax.dynamic_update_slice_in_dim(
                    d, row.astype(d.dtype), row_dst, axis=1)

            out: Dict[str, Any] = {}
            for gname, key, is_paged in transformer.paged_cache_kinds(cfg):
                fn = page_copy if is_paged else row_copy
                out.setdefault(gname, {})[key] = jax.tree.map(
                    fn, src_caches[gname][key], dst_caches[gname][key])
            return out

        self._tier_copy = jax.jit(copy)
        return self._tier_copy

    @staticmethod
    def _pad_pages(pages, p_max: int) -> jax.Array:
        row = np.zeros((p_max,), np.int32)
        row[:len(pages)] = pages
        return jnp.asarray(row)

    def _exec_spill(self, pool: PoolState, spill: Dict[str, Any],
                    act: sched_mod.SpillAction, p_max: int) -> Dict[str, Any]:
        return self._tier_copy_fn()(
            pool.state["caches"], spill,
            jnp.asarray(act.slot, jnp.int32),
            jnp.asarray(act.seat, jnp.int32),
            self._pad_pages(act.src_pages, p_max),
            self._pad_pages(act.dst_pages, p_max))

    def _exec_restore(self, pool: PoolState, spill: Dict[str, Any],
                      act: sched_mod.RestoreAction, p_max: int) -> PoolState:
        """Copy a preempted sequence back into layer 0 and re-arm its slot.

        The per-slot vectors are rebuilt from the host mirror: the KV
        frontier is one behind the emitted count (the last token's K/V is
        written by its own upcoming decode step), so decode resumes
        bit-exactly where preemption cut it."""
        req = act.req
        caches = self._tier_copy_fn()(
            spill, pool.state["caches"],
            jnp.asarray(act.seat, jnp.int32),
            jnp.asarray(act.slot, jnp.int32),
            self._pad_pages(act.src_pages, p_max),
            self._pad_pages(req.pages[:len(act.src_pages)], p_max))
        slot = act.slot
        if req.status == sched_mod.PREFILLING:
            # restored mid-chunked-prefill: no output token exists yet, so
            # only the KV frontier is re-armed; done is FORCED True (the
            # slot may have been freed by a mid-decode preemption, leaving
            # done=False on device) so the slot stays masked until its
            # final chunk lands, and the cursor resumes at the NEXT
            # boundary's prefill phase (plan order contract)
            return dataclasses.replace(
                pool, state={**pool.state, "caches": caches},
                cache_len=pool.cache_len.at[slot].set(req.cache_len),
                done=pool.done.at[slot].set(True))
        return dataclasses.replace(
            pool, state={**pool.state, "caches": caches},
            tok=pool.tok.at[slot].set(int(req.tokens[-1])),
            cache_len=pool.cache_len.at[slot].set(req.cache_len),
            done=pool.done.at[slot].set(False),
            n_gen=pool.n_gen.at[slot].set(len(req.tokens)),
            budget=pool.budget.at[slot].set(req.max_new_tokens))

    def _serve_paged(self, sch: sched_mod.Scheduler,
                     max_steps: Optional[int] = None) -> ServeReport:
        """Continuous batching over the paged two-tier pool.

        Same drain-boundary discipline as the dense loop (ONE host read per
        chunk); what changes is the boundary work: the scheduler plans
        grow / preempt / restore / admit in pages, the engine executes the
        device copies in plan order and uploads the fresh block table, and
        the decode chunk walks block tables instead of slot slabs.
        """
        geom = sch.pages
        if sch.prefix_index is not None and self._has_ssm():
            raise ValueError(
                "prefix sharing requires attention-only models: recurrent "
                "SSM state is per-sequence, not per-page (docs/SERVING.md)")
        if sch.chunk_prefill_tokens is not None and self._has_ssm():
            raise ValueError(
                "chunked prefill requires attention-only models: recurrent "
                "SSM state has no resumable KV prefix (docs/SERVING.md)")
        self.last_stats = {"host_syncs": 0, "decode_steps": 0, "chunks": 0}
        spec_k = self.ecfg.speculate_tokens
        if spec_k:
            self.last_stats.update(speculate_tokens=spec_k,
                                   spec_proposed=0, spec_accepted=0)
        pool, spill = self.init_paged_pool(sch)
        pending_first: List[Tuple[sched_mod.Request, jax.Array]] = []
        boundary_wall: List[float] = []
        boundary_tokens: List[int] = []
        step_clock = 0
        n = self.ecfg.sync_interval
        p_max = geom.max_pages_per_slot
        while sch.has_work():
            t0 = time.perf_counter()
            # a speculative boundary advances a slot by up to k+1 tokens in
            # its one verify forward, so page growth is planned for k+1
            plan = sch.plan_boundary(
                chunk_tokens=(spec_k + 1 if spec_k else n),
                max_len=self.ecfg.max_len)
            for req in plan.rejects:
                req.finish_step = step_clock
            # spills FIRST: they read layer-0 pages that restores/admits may
            # reuse later this boundary (functional arrays keep this exact)
            for act in plan.spills:
                spill = self._timed("insert", self._exec_spill,
                                    pool, spill, act, p_max)
            for act in plan.restores:
                pool = self._timed("insert", self._exec_restore,
                                   pool, spill, act, p_max)
            for slot, req in plan.admits:
                req.admit_step = step_clock
                if req.prefill_pos >= 0:
                    continue    # chunked admission: runs via prefill_steps
                if req.prefix_len:      # prefix-index hit: suffix-only prefill
                    pool, first = self._timed(
                        "prefill", self._shared_paged_admit,
                        pool, slot, req, geom)
                else:
                    pool, first = self._timed("prefill", self._paged_admit,
                                              pool, slot, req, geom)
                req.status = sched_mod.DECODING
                pending_first.append((req, first))
            # chunk prefills AFTER every copy, in plan order (scheduler's
            # ordering contract); a final chunk arms its slot like an admit
            for step in plan.prefill_steps:
                pool, first = self._timed("prefill", self._exec_prefill_chunk,
                                          pool, step, geom)
                if step.final:
                    step.req.status = sched_mod.DECODING
                    pending_first.append((step.req, first))
            # the boundary's page moves, as one host->device upload
            pool = dataclasses.replace(
                pool, block_tables=jnp.asarray(sch.block_table()))
            if spec_k:
                # one verify forward replaces the sync_interval-step scan;
                # the boundary still costs exactly one host sync below
                drafts, dlen = self._build_drafts(sch, spec_k)
                pool, toks, valid = self._timed(
                    "generate", self._verify_fn(spec_k), self.params, pool,
                    jnp.asarray(drafts), jnp.asarray(dlen))
                step_clock += 1
                self.last_stats["decode_steps"] += 1
                self.last_stats["spec_proposed"] += int(dlen.sum())
            else:
                pool, toks, valid = self._timed(
                    "generate", self._pool_chunk(n), self.params, pool)
                step_clock += n
                self.last_stats["decode_steps"] += n
            self.last_stats["chunks"] += 1
            # ---- drain boundary: the single host sync of this iteration
            toks_h, valid_h, done_h, firsts = self._timed(
                "drain", self._fetch,
                (toks, valid, pool.done, [f for _, f in pending_first]))
            emitted = len(firsts)
            for (req, _), f in zip(pending_first, firsts):
                req.tokens.append(int(f))
                # the first token becomes real only at THIS drain — the
                # boundary clock has already advanced past the decode/verify
                # work, so ttft_emit_steps measures true first-token
                # availability instead of the admission-time clock (which is
                # 0 for anything admitted at the first boundary)
                req.first_step = step_clock
            pending_first.clear()
            for slot in sorted(sch.active):
                req = sch.active[slot]
                before = len(req.tokens)
                req.tokens.extend(
                    int(t) for t, v in zip(toks_h[:, slot], valid_h[:, slot])
                    if v)
                got = len(req.tokens) - before
                emitted += got
                if spec_k:
                    # a live slot's boundary emission is accepted drafts + 1
                    # correction token; just-admitted slots (dlen=0) emit
                    # exactly 1 and contribute 0 accepted
                    self.last_stats["spec_accepted"] += max(got - 1, 0)
                # a mid-prefill slot's device done flag is still the free
                # marker from before its admission — only DECODING slots
                # can drain
                if done_h[slot] and req.status != sched_mod.PREFILLING:
                    req.finish_step = step_clock
                    sch.complete(slot)
            boundary_wall.append(time.perf_counter() - t0)
            boundary_tokens.append(emitted)
            if max_steps is not None and step_clock >= max_steps:
                break
        self.last_stats["boundary_wall_s"] = boundary_wall
        self.last_stats["boundary_tokens"] = boundary_tokens
        self._finish_spec_stats()
        stats = dict(self.last_stats)
        stats.update(sch.stats())
        return ServeReport(requests=(sch.drained + list(sch.active.values())
                                     + list(sch.queue)),
                           stats=stats)

    def _finish_spec_stats(self) -> None:
        """Derive the acceptance summary counters once a serve run ends."""
        if "spec_proposed" not in self.last_stats:
            return
        prop = self.last_stats["spec_proposed"]
        acc = self.last_stats["spec_accepted"]
        self.last_stats["spec_rejected"] = prop - acc
        self.last_stats["spec_acceptance_rate"] = (
            acc / prop if prop else 0.0)

    # ------------------------------------------------------------ stream
    def serve(self, requests: Iterable[sched_mod.Request] = (),
              scheduler: Optional[sched_mod.Scheduler] = None, *,
              max_steps: Optional[int] = None) -> ServeReport:
        """Continuous batching over a request stream.

        Loop invariant: between drain boundaries everything is on-device.
        Each iteration (1) admits queued requests into free slots, (2) runs
        one ``sync_interval`` decode chunk over the whole pool, (3) performs
        ONE host sync to read the chunk's tokens + done mask, then frees
        drained slots so the next iteration refills them.
        """
        with self._mesh_scope():
            return self._serve_impl(requests, scheduler, max_steps=max_steps)

    def _serve_impl(self, requests: Iterable[sched_mod.Request] = (),
                    scheduler: Optional[sched_mod.Scheduler] = None, *,
                    max_steps: Optional[int] = None) -> ServeReport:
        sch = scheduler or sched_mod.Scheduler.for_model(
            self.model.cfg, self.ecfg.max_len)
        for req in requests:
            sch.submit_request(req)
        if sch.pages is not None:        # paged two-tier pool
            return self._serve_paged(sch, max_steps)
        chunked = sch.chunk_prefill_tokens is not None
        if chunked and self._has_ssm():
            raise ValueError(
                "chunked prefill requires attention-only models: recurrent "
                "SSM state has no resumable KV prefix (docs/SERVING.md)")
        self.last_stats = {"host_syncs": 0, "decode_steps": 0, "chunks": 0}
        spec_k = self.ecfg.speculate_tokens
        if spec_k:
            self.last_stats.update(speculate_tokens=spec_k,
                                   spec_proposed=0, spec_accepted=0)
        pool = self.init_pool(sch.n_slots)
        pending_first: List[Tuple[sched_mod.Request, jax.Array]] = []
        boundary_wall: List[float] = []
        boundary_tokens: List[int] = []
        step_clock = 0
        while sch.has_work():
            t0 = time.perf_counter()
            for slot, req in sch.admit():
                req.admit_step = step_clock
                if req.prompt_len > self.ecfg.max_len:
                    # reject cleanly: one bad request must not abort the
                    # stream or leak its slot
                    req.finish_step = step_clock
                    sch.complete(slot, status=sched_mod.REJECTED)
                    continue
                if chunked:
                    continue    # prefills by chunks via plan_prefill below
                pool, first = self._timed(
                    "prefill", self.admit_into_slot,
                    pool, slot, req.prompt, req.max_new_tokens)
                req.status = sched_mod.DECODING
                pending_first.append((req, first))
            if chunked:
                for step in sch.plan_prefill():
                    pool, first = self._timed(
                        "prefill", self._exec_dense_chunk, pool, step)
                    if step.final:
                        step.req.status = sched_mod.DECODING
                        pending_first.append((step.req, first))
            if spec_k:
                # one verify forward replaces the sync_interval-step scan;
                # the boundary still costs exactly one host sync below
                drafts, dlen = self._build_drafts(sch, spec_k)
                pool, toks, valid = self._timed(
                    "generate", self._verify_fn(spec_k), self.params, pool,
                    jnp.asarray(drafts), jnp.asarray(dlen))
                step_clock += 1
                self.last_stats["decode_steps"] += 1
                self.last_stats["spec_proposed"] += int(dlen.sum())
            else:
                n = self.ecfg.sync_interval
                pool, toks, valid = self._timed(
                    "generate", self._pool_chunk(n), self.params, pool)
                step_clock += n
                self.last_stats["decode_steps"] += n
            self.last_stats["chunks"] += 1
            # ---- drain boundary: the single host sync of this iteration
            toks_h, valid_h, done_h, firsts = self._timed(
                "drain", self._fetch,
                (toks, valid, pool.done, [f for _, f in pending_first]))
            emitted = len(firsts)
            for (req, _), f in zip(pending_first, firsts):
                req.tokens.append(int(f))
                # the first token becomes real only at THIS drain — the
                # boundary clock has already advanced past the decode/verify
                # work, so ttft_emit_steps measures true first-token
                # availability instead of the admission-time clock (which is
                # 0 for anything admitted at the first boundary)
                req.first_step = step_clock
            pending_first.clear()
            for slot in sorted(sch.active):
                req = sch.active[slot]
                before = len(req.tokens)
                req.tokens.extend(
                    int(t) for t, v in zip(toks_h[:, slot], valid_h[:, slot])
                    if v)
                got = len(req.tokens) - before
                emitted += got
                if spec_k:
                    # a live slot's boundary emission is accepted drafts + 1
                    # correction token; just-admitted slots (dlen=0) emit
                    # exactly 1 and contribute 0 accepted
                    self.last_stats["spec_accepted"] += max(got - 1, 0)
                # mid-prefill slots keep their stale free-marker done flag;
                # only DECODING slots can drain
                if done_h[slot] and req.status != sched_mod.PREFILLING:
                    req.finish_step = step_clock
                    sch.complete(slot)
            boundary_wall.append(time.perf_counter() - t0)
            boundary_tokens.append(emitted)
            if max_steps is not None and step_clock >= max_steps:
                break
        self.last_stats["boundary_wall_s"] = boundary_wall
        self.last_stats["boundary_tokens"] = boundary_tokens
        self._finish_spec_stats()
        stats = dict(self.last_stats)
        stats.update(sch.stats())
        return ServeReport(requests=sch.drained + list(sch.active.values()),
                           stats=stats)
