"""Batched serving engine: prefill + greedy decode over the pooled KV cache.

The cache layout is the pooled-memory design (DESIGN.md §Pooled KV cache):
sequence dim sharded across the `model` axis (and `data` for batch-1 long
contexts), so aggregate pod HBM is one big KV pool — MemPool's shared L1, at
cluster scale. Continuous batching (slot reuse) is kept minimal but real:
finished rows are immediately refillable via their slot mask.

Kernel block plans are obtained ONCE at engine construction from the model's
planner (sized for ``max_len`` on the current hardware target) and threaded
into every prefill/decode call — serving never re-plans per step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import Model


@dataclasses.dataclass
class EngineConfig:
    max_len: int
    eos_token: int = 1
    greedy: bool = True


class Engine:
    def __init__(self, model: Model, params: Any, ecfg: EngineConfig):
        self.model = model
        self.params = params
        self.ecfg = ecfg
        # one capacity-partitioned plan set for the whole engine lifetime
        self.plans = model.kernel_plans(ecfg.max_len, ecfg.max_len)
        self._decode = jax.jit(
            functools.partial(model.decode_step, plans=self.plans))

    def prefill(self, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        logits, state = self.model.prefill(self.params, batch,
                                           self.ecfg.max_len,
                                           plans=self.plans)
        return logits, state

    def generate(self, batch: Dict[str, jax.Array], n_steps: int,
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Greedy continuation. Returns (tokens (B, n_steps), final_state)."""
        cfg = self.model.cfg
        logits, state = self.prefill(batch)
        prompt_len = batch["tokens"].shape[1]
        if cfg.family != "encdec" and cfg.frontend_len:
            prompt_len += cfg.frontend_len
        cache_len = jnp.asarray(prompt_len, jnp.int32)
        b = batch["tokens"].shape[0]
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        done = tok == self.ecfg.eos_token
        out: List[jnp.ndarray] = [tok]
        for _ in range(n_steps - 1):
            logits, state = self._decode(self.params, tok[:, None], state,
                                         cache_len)
            cache_len = cache_len + 1
            nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
            tok = jnp.where(done, self.ecfg.eos_token, nxt)
            done = done | (tok == self.ecfg.eos_token)
            out.append(tok)
            if bool(done.all()):
                break
        return jnp.stack(out, axis=1), state
