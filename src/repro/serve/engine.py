"""Serving engine over the pooled KV cache: slot-based continuous batching.

Two serving surfaces share one decode substrate:

  * :meth:`Engine.generate` — one-shot batched greedy decode (every row
    shares a prompt length). The decode loop runs as jitted
    ``lax.scan`` chunks of ``sync_interval`` steps; done rows are masked
    ON-DEVICE with ``jnp.where`` and the host reads the done mask only at
    chunk boundaries (one explicit ``device_get`` per chunk, counted in
    ``last_stats["host_syncs"]``) — there is NO per-token device->host
    round-trip.
  * :meth:`Engine.serve` — continuous batching. The KV cache is a pool of
    ``n_slots`` sequence slots (:meth:`init_pool`); a
    :class:`~repro.serve.scheduler.Scheduler` admits queued requests into
    free slots at drain boundaries, a jitted admission step prefills the
    prompt and scatters its cache rows into the pool
    (:meth:`~repro.models.api.Model.slot_update`) without touching in-flight
    rows, and every chunk decodes ALL slots in one batched step with
    per-slot ``cache_len`` vectors. Finished sequences free their slots for
    immediate reuse.

The cache layout is the pooled-memory design (DESIGN.md §Pooled KV cache):
sequence dim sharded across the `model` axis, so aggregate pod HBM is one
big KV pool — MemPool's shared L1, at cluster scale. The slot count is
derived from the SAME CapacityPartition budget formula as kernel tiles
(:func:`repro.serve.scheduler.derive_n_slots`).

Kernel block plans are obtained ONCE at engine construction from the model's
planner (sized for ``max_len`` on the current hardware target) and threaded
into every prefill/decode call — serving never re-plans per step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve import scheduler as sched_mod


@dataclasses.dataclass
class EngineConfig:
    """max_len bounds prompt + generation (the KV slot depth).

    ``sync_interval`` is the decode-chunk length: how many on-device steps
    run between host syncs (batch-drain boundaries). ``prompt_pad_multiple``
    right-pads slot prompts up to a multiple to bound prefill recompiles;
    it must stay ``None`` (exact-length prefill) for models with recurrent
    SSM layers, whose state would integrate the pad tokens.
    """

    max_len: int
    eos_token: int = 1
    greedy: bool = True
    sync_interval: int = 8
    pad_token: int = 0
    prompt_pad_multiple: Optional[int] = None


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PoolState:
    """Device-side state of the KV slot pool (batch axis = slot index)."""

    state: Dict[str, Any]       # model caches (+aux), slot-major
    tok: jax.Array              # (S,) int32 — last emitted token per slot
    cache_len: jax.Array        # (S,) int32 — filled KV prefix per slot
    done: jax.Array             # (S,) bool — drained/empty slot mask
    n_gen: jax.Array            # (S,) int32 — tokens emitted per occupant
    budget: jax.Array           # (S,) int32 — occupant's max_new_tokens


@dataclasses.dataclass
class ServeReport:
    """Result of one :meth:`Engine.serve` run over a request stream."""

    requests: List[sched_mod.Request]
    stats: Dict[str, Any]

    @property
    def outputs(self) -> Dict[int, List[int]]:
        return {r.rid: r.tokens for r in self.requests}


class Engine:
    def __init__(self, model: Model, params: Any, ecfg: EngineConfig):
        self.model = model
        self.params = params
        self.ecfg = ecfg
        # one capacity-partitioned plan set for the whole engine lifetime
        self.plans = model.kernel_plans(ecfg.max_len, ecfg.max_len)
        self._chunk_fns: Dict[int, Any] = {}        # one-shot decode chunks
        self._pool_chunk_fns: Dict[int, Any] = {}   # pooled decode chunks
        self._admit = self._make_admit_fn()
        self.last_stats: Dict[str, Any] = {}
        if ecfg.prompt_pad_multiple and self._has_ssm():
            raise ValueError(
                "prompt_pad_multiple requires attention-only models: SSM "
                "recurrences integrate pad tokens (see EngineConfig)")

    def _has_ssm(self) -> bool:
        return any(kind.attn == "mamba"
                   for group in self.model.cfg.layer_groups()
                   for kind in group.pattern)

    # ------------------------------------------------------------ host IO
    def _fetch(self, tree):
        """The ONLY device->host read path. One explicit transfer per call,
        issued at batch-drain boundaries; counted for the regression test."""
        self.last_stats["host_syncs"] = self.last_stats.get("host_syncs", 0) + 1
        return jax.device_get(tree)

    # ---------------------------------------------------------- one-shot
    def prefill(self, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        logits, state = self.model.prefill(self.params, batch,
                                           self.ecfg.max_len,
                                           plans=self.plans)
        return logits, state

    def _decode_chunk(self, n: int):
        """Jitted: n decode steps with on-device EOS masking (lax.scan)."""
        if n not in self._chunk_fns:
            cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans

            def run(params, tok, state, cache_len, done):
                def step(carry, _):
                    tok, state, cache_len, done = carry
                    logits, state = self.model.decode_step(
                        params, tok[:, None], state, cache_len, plans=plans)
                    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
                    tok = jnp.where(done, ecfg.eos_token, nxt)
                    done = done | (tok == ecfg.eos_token)
                    return (tok, state, cache_len + 1, done), tok

                carry, toks = jax.lax.scan(step, (tok, state, cache_len, done),
                                           None, length=n)
                tok, state, cache_len, done = carry
                return jnp.moveaxis(toks, 0, 1), tok, state, cache_len, done

            self._chunk_fns[n] = jax.jit(run)
        return self._chunk_fns[n]

    def generate(self, batch: Dict[str, jax.Array], n_steps: int,
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Greedy continuation. Returns (tokens (B, <=n_steps), final_state).

        Rows that hit EOS are frozen on-device (EOS fill); the host checks
        the done mask once per ``sync_interval`` chunk and stops early at
        that granularity — never per token.
        """
        self.last_stats = {"host_syncs": 0, "decode_steps": 0}
        cfg = self.model.cfg
        logits, state = self.prefill(batch)
        prompt_len = batch["tokens"].shape[1]
        if cfg.family != "encdec" and cfg.frontend_len:
            prompt_len += cfg.frontend_len
        cache_len = jnp.asarray(prompt_len, jnp.int32)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        done = tok == self.ecfg.eos_token
        out: List[jnp.ndarray] = [tok[:, None]]
        left = n_steps - 1
        while left > 0:
            n = min(self.ecfg.sync_interval, left)
            toks, tok, state, cache_len, done = self._decode_chunk(n)(
                self.params, tok, state, cache_len, done)
            out.append(toks)
            left -= n
            self.last_stats["decode_steps"] += n
            # drain boundary: one explicit host read, then maybe early-exit
            if left > 0 and bool(self._fetch(done).all()):
                break
        return jnp.concatenate(out, axis=1), state

    # ------------------------------------------------------------- pool
    def init_pool(self, n_slots: int) -> PoolState:
        """Empty slot pool: all slots done (free), caches zeroed."""
        cfg = self.model.cfg
        if cfg.family == "encdec":
            raise NotImplementedError(
                "pooled serving targets decoder-only families; encdec "
                "requests go through one-shot generate()")
        if cfg.frontend_len:
            raise NotImplementedError(
                "pooled serving takes token prompts; frontend-embed "
                "requests go through one-shot generate()")
        from repro.models import transformer
        state = {"caches": transformer.init_caches(cfg, n_slots,
                                                   self.ecfg.max_len)}
        zeros = jnp.zeros((n_slots,), jnp.int32)
        return PoolState(state=state,
                         tok=jnp.full((n_slots,), self.ecfg.pad_token,
                                      jnp.int32),
                         cache_len=zeros,
                         done=jnp.ones((n_slots,), bool),
                         n_gen=zeros, budget=zeros)

    def _pad_prompt(self, prompt: np.ndarray) -> Tuple[np.ndarray, int]:
        true_len = int(prompt.shape[0])
        if true_len > self.ecfg.max_len:
            raise ValueError(
                f"prompt of {true_len} tokens exceeds the KV slot depth "
                f"(max_len={self.ecfg.max_len})")
        m = self.ecfg.prompt_pad_multiple
        if not m:
            return prompt, true_len
        # clamp: the padded buffer must still fit the slot's KV depth
        padded = min(-(-true_len // m) * m, self.ecfg.max_len)
        if padded == true_len:
            return prompt, true_len
        out = np.full((padded,), self.ecfg.pad_token, np.int32)
        out[:true_len] = prompt
        return out, true_len

    def _make_admit_fn(self):
        """Jitted admission: prefill one prompt row and scatter it into the
        pool at ``slot`` — in-flight slots are untouched (pure row insert).
        One function; jit's shape-keyed cache retraces per padded prompt
        length (bounded by ``prompt_pad_multiple`` bucketing)."""
        cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans

        def run(params, tokens, true_len, budget, slot, pool: PoolState):
            last = (true_len - 1)[None]                     # (1,) gather
            logits, row = self.model.prefill(
                params, {"tokens": tokens}, ecfg.max_len, plans=plans,
                last_pos=last)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            state = self.model.slot_update(pool.state, row, slot)
            kv_len = true_len                               # filled prefix
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (kv_len >= ecfg.max_len))
            return PoolState(
                state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(kv_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def admit_into_slot(self, pool: PoolState, slot: int,
                        prompt: np.ndarray, max_new_tokens: int
                        ) -> Tuple[PoolState, jax.Array]:
        """Prefill ``prompt`` into ``slot``. Returns (pool, first_token) —
        the token stays on device; callers fetch it at the next drain."""
        tokens, true_len = self._pad_prompt(np.asarray(prompt, np.int32))
        return self._admit(self.params, tokens[None],
                           jnp.asarray(true_len, jnp.int32),
                           jnp.asarray(max_new_tokens, jnp.int32),
                           jnp.asarray(slot, jnp.int32), pool)

    def _pool_chunk(self, n: int):
        """Jitted: n batched decode steps over ALL slots with per-slot
        cache_len vectors and on-device done masking. Emits per-step
        (token, was_active) pairs; the host sees them only after the chunk."""
        if n not in self._pool_chunk_fns:
            cfg, ecfg, plans = self.model.cfg, self.ecfg, self.plans

            def run(params, pool: PoolState):
                def step(pool: PoolState, _):
                    logits, state = self.model.decode_step(
                        params, pool.tok[:, None], pool.state, pool.cache_len,
                        plans=plans)
                    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
                    was_done = pool.done
                    tok = jnp.where(was_done, ecfg.eos_token,
                                    nxt).astype(jnp.int32)
                    n_gen = jnp.where(was_done, pool.n_gen, pool.n_gen + 1)
                    cache_len = jnp.where(was_done, pool.cache_len,
                                          pool.cache_len + 1)
                    done = (was_done | (tok == ecfg.eos_token)
                            | (n_gen >= pool.budget)
                            | (cache_len >= ecfg.max_len))
                    new = PoolState(state=state, tok=tok, cache_len=cache_len,
                                    done=done, n_gen=n_gen,
                                    budget=pool.budget)
                    return new, (tok, ~was_done)

                pool, (toks, valid) = jax.lax.scan(step, pool, None, length=n)
                return pool, toks, valid        # (n, S) each

            self._pool_chunk_fns[n] = jax.jit(run)
        return self._pool_chunk_fns[n]

    # ------------------------------------------------------------ stream
    def serve(self, requests: Iterable[sched_mod.Request] = (),
              scheduler: Optional[sched_mod.Scheduler] = None, *,
              max_steps: Optional[int] = None) -> ServeReport:
        """Continuous batching over a request stream.

        Loop invariant: between drain boundaries everything is on-device.
        Each iteration (1) admits queued requests into free slots, (2) runs
        one ``sync_interval`` decode chunk over the whole pool, (3) performs
        ONE host sync to read the chunk's tokens + done mask, then frees
        drained slots so the next iteration refills them.
        """
        sch = scheduler or sched_mod.Scheduler.for_model(
            self.model.cfg, self.ecfg.max_len)
        for req in requests:
            sch.submit_request(req)
        self.last_stats = {"host_syncs": 0, "decode_steps": 0, "chunks": 0}
        pool = self.init_pool(sch.n_slots)
        pending_first: List[Tuple[sched_mod.Request, jax.Array]] = []
        step_clock = 0
        while sch.has_work():
            for slot, req in sch.admit():
                req.admit_step = step_clock
                if req.prompt_len > self.ecfg.max_len:
                    # reject cleanly: one bad request must not abort the
                    # stream or leak its slot
                    req.finish_step = step_clock
                    sch.complete(slot, status=sched_mod.REJECTED)
                    continue
                pool, first = self.admit_into_slot(
                    pool, slot, req.prompt, req.max_new_tokens)
                req.status = sched_mod.DECODING
                pending_first.append((req, first))
            n = self.ecfg.sync_interval
            pool, toks, valid = self._pool_chunk(n)(self.params, pool)
            step_clock += n
            self.last_stats["decode_steps"] += n
            self.last_stats["chunks"] += 1
            # ---- drain boundary: the single host sync of this iteration
            toks_h, valid_h, done_h, firsts = self._fetch(
                (toks, valid, pool.done, [f for _, f in pending_first]))
            for (req, _), f in zip(pending_first, firsts):
                req.tokens.append(int(f))
            pending_first.clear()
            for slot in sorted(sch.active):
                req = sch.active[slot]
                req.tokens.extend(
                    int(t) for t, v in zip(toks_h[:, slot], valid_h[:, slot])
                    if v)
                if done_h[slot]:
                    req.finish_step = step_clock
                    sch.complete(slot)
            if max_steps is not None and step_clock >= max_steps:
                break
        stats = dict(self.last_stats)
        stats.update(sch.stats())
        return ServeReport(requests=sch.drained + list(sch.active.values()),
                           stats=stats)
