"""Batched serving engine: prefill + greedy decode over the pooled KV cache.

The cache layout is the pooled-memory design (DESIGN.md): sequence dim
sharded across the `model` axis (and `data` for batch-1 long contexts), so
aggregate pod HBM is one big KV pool — MemPool's shared L1, at cluster scale.
Continuous batching (slot reuse) is kept minimal but real: finished rows are
immediately refillable via their slot mask.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import Model


@dataclasses.dataclass
class EngineConfig:
    max_len: int
    eos_token: int = 1
    greedy: bool = True


class Engine:
    def __init__(self, model: Model, params: Any, ecfg: EngineConfig):
        self.model = model
        self.params = params
        self.ecfg = ecfg
        self._decode = jax.jit(model.decode_step)

    def prefill(self, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        logits, state = self.model.prefill(self.params, batch,
                                           self.ecfg.max_len)
        return logits, state

    def generate(self, batch: Dict[str, jax.Array], n_steps: int,
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Greedy continuation. Returns (tokens (B, n_steps), final_state)."""
        cfg = self.model.cfg
        logits, state = self.prefill(batch)
        prompt_len = batch["tokens"].shape[1]
        if cfg.family != "encdec" and cfg.frontend_len:
            prompt_len += cfg.frontend_len
        cache_len = jnp.asarray(prompt_len, jnp.int32)
        b = batch["tokens"].shape[0]
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        done = tok == self.ecfg.eos_token
        out: List[jnp.ndarray] = [tok]
        for _ in range(n_steps - 1):
            logits, state = self._decode(self.params, tok[:, None], state,
                                         cache_len)
            cache_len = cache_len + 1
            nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
            tok = jnp.where(done, self.ecfg.eos_token, nxt)
            done = done | (tok == self.ecfg.eos_token)
            out.append(tok)
            if bool(done.all()):
                break
        return jnp.stack(out, axis=1), state
