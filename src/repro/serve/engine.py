"""Serving engine over the pooled KV cache: slot-based continuous batching.

Two serving surfaces share one decode substrate:

  * :meth:`Engine.generate` — one-shot batched greedy decode (every row
    shares a prompt length). The decode loop runs as jitted
    ``lax.scan`` chunks of ``sync_interval`` steps; done rows are masked
    ON-DEVICE with ``jnp.where`` and the host reads the done mask only at
    chunk boundaries (one explicit ``device_get`` per chunk, counted in
    ``last_stats["host_syncs"]``) — there is NO per-token device->host
    round-trip.
  * :meth:`Engine.serve` — continuous batching. The KV cache is a pool of
    ``n_slots`` sequence slots (:meth:`init_pool`); a
    :class:`~repro.serve.scheduler.Scheduler` admits queued requests into
    free slots at drain boundaries, a jitted admission step prefills the
    prompt and scatters its cache rows into the pool
    (:meth:`~repro.models.api.Model.slot_update`) without touching in-flight
    rows, and every chunk decodes ALL slots in one batched step with
    per-slot ``cache_len`` vectors. Finished sequences free their slots for
    immediate reuse. When the scheduler carries a
    :class:`~repro.serve.scheduler.PageGeometry`, serving switches to the
    **paged two-tier pool** (:meth:`init_paged_pool`): KV storage is a flat
    layer-0 page pool addressed through per-slot block tables, admission
    reserves *pages* instead of ``max_len`` slabs, and when layer 0 runs
    out the youngest resident spills verbatim to the layer-1 tier — the
    paper's two-die capacity split, applied to serving. A scheduler built
    with ``prefix_share=True`` additionally executes prefix-index hits as
    **suffix-only prefills** over ref-counted shared pages
    (:meth:`PrefillRole.shared_paged_admit`), turning shared-prefix TTFT
    compute from O(prompt) into O(suffix) — DESIGN.md §Prefix sharing &
    copy-on-write.

The engine itself is a composition of *roles* over a shared pool
(DESIGN.md §Disaggregated serving):

  * :class:`EngineCore` — jit-fn caches, mesh scope, device placement,
    host IO (the single ``_fetch`` read path), timing, and the one
    bucketing rule every jit-cache key goes through.
  * :class:`PrefillRole` — admissions, suffix-only prefills, and chunked
    prefill steps (compute-heavy, prompt-shaped work).
  * :class:`DecodeRole` — the batched decode / speculative-verify chunks
    (pool-sweep, latency-shaped work).
  * :class:`~repro.serve.pool.PoolManager` — pool construction, the
    layer-0 <-> layer-1 tier copies, and slot ownership (the
    ``transfer_ownership`` page-handover primitive).

A combined :class:`Engine` runs both roles in one loop — every test and
benchmark goes through the role split. ``EngineConfig(disaggregate=True)``
routes the SAME loop by role: admissions and prompt chunks are issued (and
their host syncs attributed) by the prefill role, decode by the decode
role, and at a request's final prefill chunk the scheduler emits a
``HandoverStep`` the engine executes as a zero-copy ownership flip —
the slot's block-table row starts appearing in the decode role's uploaded
table; no KV bytes move. This is the serving analogue of the paper's
compute-die / memory-die split: one shared pool address space, physically
distinct engines for the two phases of the workload.

The cache layout is the pooled-memory design (DESIGN.md §Pooled KV cache):
sequence dim sharded across the `model` axis, so aggregate pod HBM is one
big KV pool — MemPool's shared L1, at cluster scale. The slot count is
derived from the SAME CapacityPartition budget formula as kernel tiles
(:func:`repro.serve.scheduler.derive_n_slots`).

Kernel block plans are obtained ONCE at engine construction from the model's
planner (sized for ``max_len`` on the current hardware target) and threaded
into every prefill/decode call — serving never re-plans per step.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models.api import Model
from repro.serve import scheduler as sched_mod
from repro.serve import speculate as spec_mod
from repro.serve.pool import (DECODE_ROLE, PREFILL_ROLE, PoolManager,
                              PoolState)

__all__ = ["Engine", "EngineConfig", "EngineCore", "PrefillRole",
           "DecodeRole", "PoolState", "ServeReport"]


@dataclasses.dataclass
class EngineConfig:
    """max_len bounds prompt + generation (the KV slot depth).

    ``sync_interval`` is the decode-chunk length: how many on-device steps
    run between host syncs (batch-drain boundaries). ``prompt_pad_multiple``
    right-pads slot prompts up to a multiple to bound prefill recompiles;
    it must stay ``None`` (exact-length prefill) for models with recurrent
    SSM layers, whose state would integrate the pad tokens.

    ``speculate_tokens`` (k) turns on self-drafting speculative decoding in
    the serve loops (DESIGN.md §Speculative decoding): each drain boundary
    proposes up to k draft tokens per live slot from the slot's own
    emitted+prompt history and scores them all in ONE width-(k+1) verify
    forward, emitting accepted-prefix + 1 tokens per slot per boundary.
    Greedy outputs are bit-exact with ``speculate_tokens=0``. Requires
    attention-only models (recurrent SSM state cannot roll back rejected
    draft tokens); size k with
    :func:`repro.serve.scheduler.derive_speculate_tokens`.

    ``phase_timing`` turns on the per-phase wall-clock breakdown
    (prefill / insert / generate / drain / handover) in ``last_stats`` —
    benchmark mode only: each phase blocks on its device work, which
    serializes the dispatch pipeline the serve loop otherwise overlaps.

    ``mesh`` (a ``jax.sharding.Mesh``, e.g. from
    :func:`repro.launch.mesh.make_host_mesh`) runs every jitted engine
    function under that mesh: model weights are placed tensor-parallel
    (``repro.distributed.sharding.named_shardings``), KV pools/pages are
    placed on the head axis when the model's heads divide the `model` axis
    (DESIGN.md §Sharded serving), and GSPMD partitions the admission /
    decode / verify computations. ``None`` (default) is today's
    single-device path, bit-identical by construction; a 1x1 mesh is also
    bit-identical (every constraint resolves to replication). The
    one-host-sync-per-drain-boundary discipline is mesh-invariant: the
    block-table upload (host->device) and the drain fetch are the only
    host <-> device edges per boundary, regardless of mesh size.

    ``disaggregate`` splits serving into prefill-role and decode-role
    engines over the shared paged pool (DESIGN.md §Disaggregated serving):
    the scheduler routes PREFILLING slots to the prefill role and emits a
    page handover at each request's final prefill chunk; each role issues
    at most ONE host sync per drain boundary, and outputs stay
    bit-identical to the combined engine. Requires the paged pool
    (``Scheduler(pages=...)``).
    """

    max_len: int
    eos_token: int = 1
    greedy: bool = True
    sync_interval: int = 8
    pad_token: int = 0
    prompt_pad_multiple: Optional[int] = None
    speculate_tokens: int = 0
    phase_timing: bool = False
    mesh: Optional[Any] = None
    disaggregate: bool = False


@dataclasses.dataclass
class ServeReport:
    """Result of one :meth:`Engine.serve` run over a request stream."""

    requests: List[sched_mod.Request]
    stats: Dict[str, Any]

    @property
    def outputs(self) -> Dict[int, List[int]]:
        return {r.rid: r.tokens for r in self.requests}


class EngineCore:
    """The substrate both engine roles share: parameters (mesh-placed),
    kernel plans, jit-fn caches, device placement, host IO, and timing.

    Keeping every jit cache here — not on the roles — means a combined
    engine and a disaggregated one compile the SAME function set, and the
    equivalence matrix's bit-identity cells reuse compilations across
    modes. The core is deliberately thin: it never looks at a scheduler
    and runs no serve loop.
    """

    def __init__(self, model: Model, params: Any, ecfg: EngineConfig):
        self.model = model
        self.mesh = ecfg.mesh
        if self.mesh is not None:
            # tensor-parallel weight placement; cache pools are placed by
            # _place at init and the jitted fns run under _mesh_scope
            params = jax.device_put(
                params, shd.named_shardings(params, self.mesh))
        self.params = params
        self.ecfg = ecfg
        # one capacity-partitioned plan set for the whole engine lifetime
        self.plans = model.kernel_plans(ecfg.max_len, ecfg.max_len)
        self._chunk_fns: Dict[int, Any] = {}        # one-shot decode chunks
        self._pool_chunk_fns: Dict[int, Any] = {}   # pooled decode chunks
        self._verify_fns: Dict[int, Any] = {}       # speculative verify, by k
        self._admit = None                          # dense admission
        self._paged_admit_fns: Dict[Any, Any] = {}  # keyed by page geometry
        self._suffix_admit_fns: Dict[Any, Any] = {}  # + static prefix_len
        # chunked prefill (DESIGN.md §Chunked prefill): jit variants keyed
        # by POWER-OF-TWO padded chunk length (+ emit_first), never by the
        # runtime cursor — O(log chunk_tokens) compiles total
        self._chunk_prefill_fns: Dict[Any, Any] = {}        # paged
        self._dense_chunk_prefill_fns: Dict[Any, Any] = {}  # dense
        self.last_stats: Dict[str, Any] = {}

    def _has_ssm(self) -> bool:
        return any(kind.attn == "mamba"
                   for group in self.model.cfg.layer_groups()
                   for kind in group.pattern)

    # -------------------------------------------------------------- mesh
    def _mesh_scope(self):
        """Ambient-mesh context for every traced/jitted engine call.

        With ``EngineConfig(mesh=...)`` set, entering the scope makes the
        ``repro.distributed.sharding.shard`` constraints inside the model
        live (head-axis KV placement, batch sharding); without one it is a
        null context and every constraint no-ops — the single-device path
        is untouched."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return shd.use_mesh(self.mesh)

    def _place(self, tree):
        """Commit a cache/pool tree to its mesh shardings (identity without
        a mesh): head-axis placement for GQA caches/pages, replication for
        latent/SSM state and scalars (``spec_for_cache``)."""
        if self.mesh is None:
            return tree
        return jax.device_put(tree, shd.named_shardings(tree, self.mesh))

    # ------------------------------------------------------------ host IO
    def _fetch(self, tree, role: Optional[str] = None):
        """The ONLY device->host read path. One explicit transfer per call,
        issued at batch-drain boundaries; counted for the regression test.
        ``role`` attributes the sync when serving disaggregated — the
        per-role sync discipline is each role issues at most one fetch per
        boundary."""
        self.last_stats["host_syncs"] = self.last_stats.get("host_syncs", 0) + 1
        if role is not None:
            by = self.last_stats.setdefault("host_syncs_by_role", {})
            by[role] = by.get(role, 0) + 1
        return jax.device_get(tree)

    def _timed(self, phase: str, fn, *args, role: Optional[str] = None):
        """Run ``fn`` and, in ``phase_timing`` mode, charge its wall time
        (blocked on device completion) to ``last_stats['phase_s'][phase]``
        — and, when ``role`` is given, to ``last_stats['role_s'][role]``
        (the disaggregated per-role busy breakdown). Off by default:
        blocking per phase would serialize the dispatch pipeline the serve
        loop overlaps."""
        if not self.ecfg.phase_timing:
            return fn(*args)
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        acc = self.last_stats.setdefault("phase_s", {})
        acc[phase] = acc.get(phase, 0.0) + dt
        if role is not None:
            racc = self.last_stats.setdefault("role_s", {})
            racc[role] = racc.get(role, 0.0) + dt
        return out

    # --------------------------------------------------------- bucketing
    @staticmethod
    def bucket_len(n: int, limit: int, *, start: int = 0,
                   multiple: Optional[int] = None) -> int:
        """THE bucketing rule for every shape-keyed jit cache.

        Default mode: next power of two >= ``n``, clamped to ``limit`` —
        the static lengths chunk prefill compiles for. When the padded
        chunk would overrun the cache depth from ``start`` (the slot-depth
        edge), the exact length is used instead: a traced-start cache
        write would clamp backwards over earlier chunks (rare tail
        variant; never hit while prompt + chunk fit the depth).

        ``multiple`` mode: round up to a multiple instead (the
        ``prompt_pad_multiple`` admission bucketing), clamped to ``limit``
        so the padded buffer still fits the slot's KV depth.

        One helper, three former call sites (`_bucket_len`, `_pad_prompt`,
        the chunk-prefill edge) — the compile-cache key sequence is pinned
        by ``tests/test_chunked_prefill.py``.
        """
        n = int(n)
        if multiple:
            return min(-(-n // multiple) * multiple, limit)
        padded = min(1 << (n - 1).bit_length(), limit)
        if start + padded > limit:
            return n
        return padded


class PrefillRole:
    """The prefill-role engine: admissions (whole-prompt, suffix-only, and
    chunked) over the shared pool. Prompt-shaped, compute-heavy work — the
    "logic die" half of the role split. Owns no device state: pools come
    in and go out of every call; jitted fns live in the shared core."""

    name = PREFILL_ROLE

    def __init__(self, core: EngineCore, pools: PoolManager):
        self.core = core
        self.pools = pools

    def _pad_prompt(self, prompt: np.ndarray) -> Tuple[np.ndarray, int]:
        core = self.core
        true_len = int(prompt.shape[0])
        if true_len > core.ecfg.max_len:
            raise ValueError(
                f"prompt of {true_len} tokens exceeds the KV slot depth "
                f"(max_len={core.ecfg.max_len})")
        m = core.ecfg.prompt_pad_multiple
        if not m:
            return prompt, true_len
        padded = core.bucket_len(true_len, core.ecfg.max_len, multiple=m)
        if padded == true_len:
            return prompt, true_len
        out = np.full((padded,), core.ecfg.pad_token, np.int32)
        out[:true_len] = prompt
        return out, true_len

    def _make_admit_fn(self):
        """Jitted admission: prefill one prompt row and scatter it into the
        pool at ``slot`` — in-flight slots are untouched (pure row insert).
        One function; jit's shape-keyed cache retraces per padded prompt
        length (bounded by ``prompt_pad_multiple`` bucketing)."""
        core = self.core
        cfg, ecfg, plans = core.model.cfg, core.ecfg, core.plans

        def run(params, tokens, true_len, budget, slot, pool: PoolState):
            last = (true_len - 1)[None]                     # (1,) gather
            logits, row = core.model.prefill(
                params, {"tokens": tokens}, ecfg.max_len, plans=plans,
                last_pos=last)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            state = core.model.slot_update(pool.state, row, slot)
            kv_len = true_len                               # filled prefix
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (kv_len >= ecfg.max_len))
            return PoolState(
                state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(kv_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def admit_into_slot(self, pool: PoolState, slot: int,
                        prompt: np.ndarray, max_new_tokens: int
                        ) -> Tuple[PoolState, jax.Array]:
        """Prefill ``prompt`` into ``slot``. Returns (pool, first_token) —
        the token stays on device; callers fetch it at the next drain."""
        core = self.core
        if core._admit is None:
            core._admit = self._make_admit_fn()
        tokens, true_len = self._pad_prompt(np.asarray(prompt, np.int32))
        return core._admit(core.params, tokens[None],
                           jnp.asarray(true_len, jnp.int32),
                           jnp.asarray(max_new_tokens, jnp.int32),
                           jnp.asarray(slot, jnp.int32), pool)

    # ------------------------------------------------------ paged admission
    def _make_paged_admit_fn(self, geom: sched_mod.PageGeometry):
        """Jitted paged admission: prefill one prompt row at the pool's
        page-aligned depth, cut it into pages and scatter them at the
        slot's block-table row. In-flight pages are untouched."""
        core = self.core
        cfg, ecfg, plans = core.model.cfg, core.ecfg, core.plans
        depth, pt = geom.depth, geom.page_tokens

        def run(params, tokens, true_len, budget, slot, block_row,
                pool: PoolState):
            last = (true_len - 1)[None]                 # (1,) gather
            logits, row = core.model.prefill(
                params, {"tokens": tokens}, depth, plans=plans, last_pos=last)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            state = core.model.slot_update_paged(pool.state, row, slot,
                                                 block_row, pt)
            kv_len = true_len
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (kv_len >= ecfg.max_len))
            return dataclasses.replace(
                pool, state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(kv_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def paged_admit(self, pool: PoolState, slot: int,
                    req: sched_mod.Request, geom: sched_mod.PageGeometry
                    ) -> Tuple[PoolState, jax.Array]:
        core = self.core
        tokens, true_len = self._pad_prompt(np.asarray(req.prompt, np.int32))
        block_row = self.pools.pad_pages(req.pages, geom.max_pages_per_slot)
        key = (geom.depth, geom.page_tokens)
        if key not in core._paged_admit_fns:
            core._paged_admit_fns[key] = self._make_paged_admit_fn(geom)
        return core._paged_admit_fns[key](
            core.params, tokens[None], jnp.asarray(true_len, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(slot, jnp.int32), block_row, pool)

    def _make_suffix_admit_fn(self, geom: sched_mod.PageGeometry,
                              prefix_len: int):
        """Jitted cache-hit admission: prefill ONLY the unmatched suffix.

        The shared prefix pages (plus the copy-on-write source, when the
        match ends mid-page) are gathered into a dense batch-1 view, the
        suffix runs through ``Model.prefill`` at a static ``prefix_len``
        offset (RoPE positions and causal masks continue where the shared
        prefix ends — bit-identical to the same rows of a full prefill),
        and the result is scattered back through ``write_row``, whose
        entries for shared pages point at null page 0: shared history is
        never written, and the frontier page lands in the request's fresh
        private page (the COW copy rides the gather->scatter cycle).
        TTFT compute drops from O(prompt) to O(suffix).
        """
        core = self.core
        cfg, ecfg, plans = core.model.cfg, core.ecfg, core.plans
        depth, pt = geom.depth, geom.page_tokens

        def run(params, tokens, true_len, budget, slot, read_row, write_row,
                pool: PoolState):
            prefix = core.model.gather_row_paged(pool.state, read_row, pt)
            last = (true_len - 1)[None]                 # (1,) gather
            logits, row = core.model.prefill(
                params, {"tokens": tokens}, depth, plans=plans, last_pos=last,
                prefix_len=prefix_len, prefix_state=prefix)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            state = core.model.slot_update_paged(pool.state, row, slot,
                                                 write_row, pt)
            kv_len = true_len + prefix_len
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (kv_len >= ecfg.max_len))
            return dataclasses.replace(
                pool, state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(kv_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def shared_paged_admit(self, pool: PoolState, slot: int,
                           req: sched_mod.Request,
                           geom: sched_mod.PageGeometry
                           ) -> Tuple[PoolState, jax.Array]:
        """Execute a prefix-index-hit admission planned by the scheduler.

        ``read_row`` maps the pages the suffix attends over: the shared
        full pages, plus — when the match ends mid-page — the COW *source*
        page at the frontier index. ``write_row`` maps where suffix K/V
        lands: null (page 0) under the shared prefix, the request's own
        fresh pages from the frontier on. The frontier page is therefore
        read from the canonical copy but written to a private one.
        """
        core = self.core
        pt, p_max = geom.page_tokens, geom.max_pages_per_slot
        suffix = np.asarray(req.prompt, np.int32)[req.prefix_len:]
        tokens, true_len = self._pad_prompt(suffix)
        if req.prefix_len + tokens.shape[0] > geom.depth:
            tokens = tokens[:geom.depth - req.prefix_len]   # trim pad only
        f_w = req.prefix_len // pt                  # frontier logical page
        read = np.zeros((p_max,), np.int32)
        read[:req.n_shared] = req.pages[:req.n_shared]
        if req.cow_src >= 0:
            read[f_w] = req.cow_src
        write = np.zeros((p_max,), np.int32)
        write[f_w:len(req.pages)] = req.pages[f_w:]
        key = (geom.depth, pt, req.prefix_len, tokens.shape[0])
        if key not in core._suffix_admit_fns:
            core._suffix_admit_fns[key] = self._make_suffix_admit_fn(
                geom, req.prefix_len)
        return core._suffix_admit_fns[key](
            core.params, tokens[None], jnp.asarray(true_len, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(slot, jnp.int32), jnp.asarray(read),
            jnp.asarray(write), pool)

    # ------------------------------------------------- chunked prefill
    def _make_chunk_prefill_fn(self, geom: sched_mod.PageGeometry,
                               n_tok: int, emit_first: bool):
        """Jitted partial-prefill step: run ONE chunk of a prompt and
        scatter its K/V into the request's pages (DESIGN.md §Chunked
        prefill).

        The chunk cursor ``start`` and true length ``true_n`` are TRACED
        int32 scalars — the jit cache is keyed only by the power-of-two
        padded chunk length (plus ``emit_first``), never by where in the
        prompt the chunk lands, so a 4k-token prompt compiles the same
        O(log chunk_tokens) variants as a 64-token one. A traced cursor
        rides the same resumed-prefill path as the static-offset suffix
        admission: positions and causal masks continue at ``start``
        (bit-identical rows), and the traced offset forces the jnp
        reference attention (the Pallas kernel needs a static grid
        offset). Non-final chunks only advance ``cache_len`` — the slot
        stays done-masked, so the interleaved decode chunk freezes it for
        free. The final chunk emits the first output token and arms the
        slot exactly like an unchunked admission.
        """
        core = self.core
        cfg, ecfg, plans = core.model.cfg, core.ecfg, core.plans
        depth, pt = geom.depth, geom.page_tokens

        def run(params, tokens, start, true_n, budget, slot, read_row,
                write_row, pool: PoolState):
            prefix = core.model.gather_row_paged(pool.state, read_row, pt)
            last = (true_n - 1)[None]                   # (1,) gather
            logits, row = core.model.prefill(
                params, {"tokens": tokens}, depth, plans=plans, last_pos=last,
                prefix_len=start, prefix_state=prefix)
            state = core.model.slot_update_paged(pool.state, row, slot,
                                                 write_row, pt)
            new_len = start + true_n
            if not emit_first:
                # done=True is NOT redundant: a slot freed by preempting a
                # mid-decode request still carries done=False on device —
                # without the mask the interleaved decode chunk would
                # decode the half-prefilled slot
                return dataclasses.replace(
                    pool, state=state,
                    cache_len=pool.cache_len.at[slot].set(new_len),
                    done=pool.done.at[slot].set(True),
                ), jnp.zeros((), jnp.int32)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (new_len >= ecfg.max_len))
            return dataclasses.replace(
                pool, state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(new_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def exec_prefill_chunk(self, pool: PoolState,
                           step: sched_mod.PrefillStep,
                           geom: sched_mod.PageGeometry
                           ) -> Tuple[PoolState, jax.Array]:
        """Execute one planned :class:`~repro.serve.scheduler.PrefillStep`.

        ``read_row`` maps every page holding KV the chunk attends over:
        the request's own pages below the cursor — which are the SHARED
        prefix pages for its leading entries — plus the copy-on-write
        source when the first chunk starts at a mid-page prefix match.
        ``write_row`` maps the pages the chunk's K/V lands in, from the
        cursor's page on (whole-page scatter re-writes the frontier page's
        earlier tokens with the very content just gathered, so a COW source
        is copied private on the first chunk for free)."""
        core = self.core
        req = step.req
        pt, p_max = geom.page_tokens, geom.max_pages_per_slot
        n_pad = core.bucket_len(step.n_tokens, geom.depth, start=step.start)
        tokens = np.full((n_pad,), core.ecfg.pad_token, np.int32)
        tokens[:step.n_tokens] = np.asarray(req.prompt, np.int32)[
            step.start:step.start + step.n_tokens]
        f_r = -(-step.start // pt)              # pages covering [0, start)
        read = np.zeros((p_max,), np.int32)
        read[:f_r] = req.pages[:f_r]
        if step.start == req.prefix_len and req.cow_src >= 0:
            read[step.start // pt] = req.cow_src
        f_w = step.start // pt                  # cursor's (frontier) page
        end_pages = geom.pages_for(step.start + step.n_tokens)
        write = np.zeros((p_max,), np.int32)
        write[f_w:end_pages] = req.pages[f_w:end_pages]
        key = (geom.depth, pt, n_pad, step.final)
        if key not in core._chunk_prefill_fns:
            core._chunk_prefill_fns[key] = self._make_chunk_prefill_fn(
                geom, n_pad, step.final)
        return core._chunk_prefill_fns[key](
            core.params, tokens[None], jnp.asarray(step.start, jnp.int32),
            jnp.asarray(step.n_tokens, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(step.slot, jnp.int32), jnp.asarray(read),
            jnp.asarray(write), pool)

    def _make_dense_chunk_prefill_fn(self, n_tok: int, emit_first: bool):
        """Dense-pool analog of :meth:`_make_chunk_prefill_fn`: the chunk
        attends over the slot's own slab (earlier chunks' K/V gathered by
        :meth:`~repro.models.api.Model.gather_row`) and the whole updated
        row is scattered back. Same traced cursor, same bucketed jit key."""
        core = self.core
        cfg, ecfg, plans = core.model.cfg, core.ecfg, core.plans

        def run(params, tokens, start, true_n, budget, slot,
                pool: PoolState):
            prefix = core.model.gather_row(pool.state, slot)
            last = (true_n - 1)[None]                   # (1,) gather
            logits, row = core.model.prefill(
                params, {"tokens": tokens}, ecfg.max_len, plans=plans,
                last_pos=last, prefix_len=start, prefix_state=prefix)
            state = core.model.slot_update(pool.state, row, slot)
            new_len = start + true_n
            if not emit_first:
                return dataclasses.replace(
                    pool, state=state,
                    cache_len=pool.cache_len.at[slot].set(new_len),
                    done=pool.done.at[slot].set(True),
                ), jnp.zeros((), jnp.int32)
            first = jnp.argmax(logits[0, -1, :cfg.vocab_size])
            first = first.astype(jnp.int32)
            done0 = ((first == ecfg.eos_token) | (budget <= 1)
                     | (new_len >= ecfg.max_len))
            return dataclasses.replace(
                pool, state=state,
                tok=pool.tok.at[slot].set(first),
                cache_len=pool.cache_len.at[slot].set(new_len),
                done=pool.done.at[slot].set(done0),
                n_gen=pool.n_gen.at[slot].set(1),
                budget=pool.budget.at[slot].set(budget)), first

        return jax.jit(run)

    def exec_dense_chunk(self, pool: PoolState, step: sched_mod.PrefillStep
                         ) -> Tuple[PoolState, jax.Array]:
        core = self.core
        req = step.req
        n_pad = core.bucket_len(step.n_tokens, core.ecfg.max_len,
                                start=step.start)
        tokens = np.full((n_pad,), core.ecfg.pad_token, np.int32)
        tokens[:step.n_tokens] = np.asarray(req.prompt, np.int32)[
            step.start:step.start + step.n_tokens]
        key = (n_pad, step.final)
        if key not in core._dense_chunk_prefill_fns:
            core._dense_chunk_prefill_fns[key] = \
                self._make_dense_chunk_prefill_fn(n_pad, step.final)
        return core._dense_chunk_prefill_fns[key](
            core.params, tokens[None], jnp.asarray(step.start, jnp.int32),
            jnp.asarray(step.n_tokens, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(step.slot, jnp.int32), pool)


class DecodeRole:
    """The decode-role engine: batched decode and speculative-verify
    chunks over the shared pool. Pool-sweep, latency-shaped work — the
    "memory die" half of the role split. In disaggregated mode its
    uploaded block table carries rows ONLY for slots it owns (handover
    makes a row appear); done-masked slots it does not own write their
    junk K/V to the null page instead of their own pages — positions at
    or past a prefill cursor are never read, so outputs are unchanged."""

    name = DECODE_ROLE

    def __init__(self, core: EngineCore, pools: PoolManager):
        self.core = core
        self.pools = pools

    def decode_chunk(self, n: int):
        """Jitted: n decode steps with on-device EOS masking (lax.scan) —
        the one-shot :meth:`Engine.generate` substrate."""
        core = self.core
        if n not in core._chunk_fns:
            cfg, ecfg, plans = core.model.cfg, core.ecfg, core.plans

            def run(params, tok, state, cache_len, done):
                def step(carry, _):
                    tok, state, cache_len, done = carry
                    logits, state = core.model.decode_step(
                        params, tok[:, None], state, cache_len, plans=plans)
                    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
                    tok = jnp.where(done, ecfg.eos_token, nxt)
                    done = done | (tok == ecfg.eos_token)
                    return (tok, state, cache_len + 1, done), tok

                carry, toks = jax.lax.scan(step, (tok, state, cache_len, done),
                                           None, length=n)
                tok, state, cache_len, done = carry
                return jnp.moveaxis(toks, 0, 1), tok, state, cache_len, done

            core._chunk_fns[n] = jax.jit(run)
        return core._chunk_fns[n]

    def pool_chunk(self, n: int):
        """Jitted: n batched decode steps over ALL slots with per-slot
        cache_len vectors and on-device done masking. Emits per-step
        (token, was_active) pairs; the host sees them only after the chunk."""
        core = self.core
        if n not in core._pool_chunk_fns:
            cfg, ecfg, plans = core.model.cfg, core.ecfg, core.plans

            def run(params, pool: PoolState):
                def step(pool: PoolState, _):
                    logits, state = core.model.decode_step(
                        params, pool.tok[:, None], pool.state, pool.cache_len,
                        plans=plans, block_tables=pool.block_tables)
                    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
                    was_done = pool.done
                    tok = jnp.where(was_done, ecfg.eos_token,
                                    nxt).astype(jnp.int32)
                    n_gen = jnp.where(was_done, pool.n_gen, pool.n_gen + 1)
                    cache_len = jnp.where(was_done, pool.cache_len,
                                          pool.cache_len + 1)
                    done = (was_done | (tok == ecfg.eos_token)
                            | (n_gen >= pool.budget)
                            | (cache_len >= ecfg.max_len))
                    new = PoolState(state=state, tok=tok, cache_len=cache_len,
                                    done=done, n_gen=n_gen,
                                    budget=pool.budget,
                                    block_tables=pool.block_tables)
                    return new, (tok, ~was_done)

                pool, (toks, valid) = jax.lax.scan(step, pool, None, length=n)
                return pool, toks, valid        # (n, S) each

            core._pool_chunk_fns[n] = jax.jit(run)
        return core._pool_chunk_fns[n]

    # ------------------------------------------- speculative verify chunk
    def verify_fn(self, k: int):
        """Jitted speculative boundary: ONE width-(k+1) verify forward over
        ALL slots, folded into the pool's done-masked updates (DESIGN.md
        §Speculative decoding).

        Each slot's verify row is its last emitted token followed by its k
        host-proposed drafts, so the forward's argmax column j is exactly
        what the j-th sequential :meth:`pool_chunk` step would have
        produced — :func:`repro.serve.speculate.fold_acceptance` then
        emits the longest agreeing prefix plus one correction token and
        rolls ``cache_len`` back over the rejected suffix. Output shape
        matches :meth:`pool_chunk`'s ``(steps, S)`` tokens/valid pair
        (steps = k+1 candidate positions), so the drain loop is unchanged.
        Done slots emit nothing; their junk K/V writes land in their own
        slab/pages (or the null page) exactly like the single-token path's
        frozen decode.
        """
        core = self.core
        if k not in core._verify_fns:
            cfg, ecfg, plans = core.model.cfg, core.ecfg, core.plans

            def run(params, pool: PoolState, drafts, dlen):
                tokens = jnp.concatenate([pool.tok[:, None], drafts], axis=1)
                logits, state = core.model.verify_step(
                    params, tokens, pool.state, pool.cache_len, plans=plans,
                    block_tables=pool.block_tables)
                targets = jnp.argmax(logits[:, :, :cfg.vocab_size],
                                     axis=-1).astype(jnp.int32)   # (S, k+1)
                fold = spec_mod.fold_acceptance(
                    targets, drafts, dlen, done=pool.done, n_gen=pool.n_gen,
                    budget=pool.budget, cache_len=pool.cache_len,
                    max_len=ecfg.max_len, eos_token=ecfg.eos_token)
                toks = jnp.where(fold.valid, targets, ecfg.eos_token)
                new = PoolState(state=state, tok=fold.tok,
                                cache_len=fold.cache_len, done=fold.done,
                                n_gen=fold.n_gen, budget=pool.budget,
                                block_tables=pool.block_tables)
                return new, toks.astype(jnp.int32).T, fold.valid.T

            core._verify_fns[k] = jax.jit(run)
        return core._verify_fns[k]

    def build_drafts(self, sch: sched_mod.Scheduler, k: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side draft proposal for every live slot (drain boundary).

        Proposes from the slot's host-mirrored prompt+emitted context via
        :func:`repro.serve.speculate.propose_ngram`. Slots without a
        proposable context — free, mid-chunked-prefill, or admitted this
        very boundary (first token still on device in ``pending_first``) —
        get ``dlen = 0``, which the fold degrades to an ordinary
        single-token step.
        """
        drafts = np.zeros((sch.n_slots, k), np.int32)
        dlen = np.zeros((sch.n_slots,), np.int32)
        for slot, req in sch.active.items():
            if req.status != sched_mod.DECODING or not req.tokens:
                continue
            ctx = np.concatenate([np.asarray(req.prompt, np.int32),
                                  np.asarray(req.tokens, np.int32)])
            d = spec_mod.propose_ngram(ctx, k)
            drafts[slot, :d.shape[0]] = d
            dlen[slot] = d.shape[0]
        return drafts, dlen


class Engine:
    """The combined engine: one :class:`EngineCore`, one
    :class:`~repro.serve.pool.PoolManager`, a :class:`PrefillRole` and a
    :class:`DecodeRole` — plus the serve loops that drive them. With
    ``EngineConfig(disaggregate=True)`` (paged pool required) the same
    loop routes work, host syncs, and timing by role and executes the
    scheduler's page handovers; otherwise both roles run as one engine
    with byte-identical behavior to the pre-split code."""

    def __init__(self, model: Model, params: Any, ecfg: EngineConfig):
        self.core = EngineCore(model, params, ecfg)
        self.pools = PoolManager(model, ecfg, self.core._place)
        self.prefill_role = PrefillRole(self.core, self.pools)
        self.decode_role = DecodeRole(self.core, self.pools)
        # ---- layer-2 host tier (DESIGN.md §Tiered KV compression & host
        # parking): the paged pool of the LAST serve() call, kept so idle
        # sessions can be parked between calls, and resume content staged
        # by rid until the scheduler re-admits the session
        self._last_pool: Optional[PoolState] = None
        self._last_spill: Optional[Dict[str, Any]] = None
        self._park_pending: Dict[int, Dict[str, np.ndarray]] = {}
        if ecfg.prompt_pad_multiple and self.core._has_ssm():
            raise ValueError(
                "prompt_pad_multiple requires attention-only models: SSM "
                "recurrences integrate pad tokens (see EngineConfig)")
        if ecfg.speculate_tokens and self.core._has_ssm():
            raise ValueError(
                "speculative decoding requires attention-only models: "
                "recurrent SSM state cannot roll back rejected draft "
                "tokens (docs/SERVING.md)")

    # ----------------------------------------------- shared-core surface
    # The public attribute surface predates the role split; tests, the
    # benchmarks, and the stream driver reach these through the engine.
    @property
    def model(self) -> Model:
        return self.core.model

    @property
    def params(self):
        return self.core.params

    @property
    def mesh(self):
        return self.core.mesh

    @property
    def ecfg(self) -> EngineConfig:
        return self.core.ecfg

    @property
    def plans(self):
        return self.core.plans

    @property
    def last_stats(self) -> Dict[str, Any]:
        return self.core.last_stats

    @last_stats.setter
    def last_stats(self, value: Dict[str, Any]) -> None:
        self.core.last_stats = value

    @property
    def _chunk_prefill_fns(self) -> Dict[Any, Any]:
        return self.core._chunk_prefill_fns

    @property
    def _dense_chunk_prefill_fns(self) -> Dict[Any, Any]:
        return self.core._dense_chunk_prefill_fns

    def _has_ssm(self) -> bool:
        return self.core._has_ssm()

    def _mesh_scope(self):
        return self.core._mesh_scope()

    def init_pool(self, n_slots: int) -> PoolState:
        return self.pools.init_pool(n_slots)

    def init_paged_pool(self, sch: sched_mod.Scheduler
                        ) -> Tuple[PoolState, Dict[str, Any]]:
        return self.pools.init_paged_pool(sch)

    def admit_into_slot(self, pool: PoolState, slot: int,
                        prompt: np.ndarray, max_new_tokens: int
                        ) -> Tuple[PoolState, jax.Array]:
        return self.prefill_role.admit_into_slot(pool, slot, prompt,
                                                 max_new_tokens)

    # ---------------------------------------------------------- one-shot
    def prefill(self, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        logits, state = self.model.prefill(self.params, batch,
                                           self.ecfg.max_len,
                                           plans=self.plans)
        return logits, state

    def generate(self, batch: Dict[str, jax.Array], n_steps: int,
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Greedy continuation. Returns (tokens (B, <=n_steps), final_state).

        Rows that hit EOS are frozen on-device (EOS fill); the host checks
        the done mask once per ``sync_interval`` chunk and stops early at
        that granularity — never per token.
        """
        with self._mesh_scope():
            return self._generate_impl(batch, n_steps)

    def _generate_impl(self, batch: Dict[str, jax.Array], n_steps: int,
                       ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        self.last_stats = {"host_syncs": 0, "decode_steps": 0}
        cfg = self.model.cfg
        logits, state = self.prefill(batch)
        prompt_len = batch["tokens"].shape[1]
        if cfg.family != "encdec" and cfg.frontend_len:
            prompt_len += cfg.frontend_len
        cache_len = jnp.asarray(prompt_len, jnp.int32)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        done = tok == self.ecfg.eos_token
        out: List[jnp.ndarray] = [tok[:, None]]
        left = n_steps - 1
        while left > 0:
            n = min(self.ecfg.sync_interval, left)
            toks, tok, state, cache_len, done = \
                self.decode_role.decode_chunk(n)(
                    self.params, tok, state, cache_len, done)
            out.append(toks)
            left -= n
            self.last_stats["decode_steps"] += n
            # drain boundary: one explicit host read, then maybe early-exit
            if left > 0 and bool(self.core._fetch(done).all()):
                break
        return jnp.concatenate(out, axis=1), state

    # ------------------------------------------- layer-2 host tier (park)
    def park_request(self, sch: sched_mod.Scheduler, rid: int) -> bytes:
        """Park an active DECODING session to the layer-2 host tier.

        Gathers the contents of every page the session maps (codes AND
        per-page scales, verbatim — lossless at any codec) plus its
        per-slot rows, serializes them with the scheduler residue through
        :mod:`repro.serve.park`, then releases the slot and all device
        resources via :meth:`Scheduler.park`. The returned blob is the
        session; feed it to :meth:`resume_parked` to continue the decode
        as a resume, never a re-prefill. fp16 pools round-trip
        byte-identically (raw-bytes serialization, no recompute)."""
        from repro.models import transformer
        from repro.serve import park as park_mod
        slot = next((s for s, r in sch.active.items() if r.rid == rid), None)
        if slot is None:
            raise KeyError(f"rid {rid} is not active; only resident "
                           f"sessions park")
        req = sch.active[slot]
        if req.status != sched_mod.DECODING:
            raise ValueError(
                "only decoding sessions park — a mid-prefill request has "
                "no emitted token to resume from; requeue it instead")
        if self._last_pool is None:
            raise RuntimeError("park_request follows a serve() call — no "
                               "paged pool state is staged")
        pool, cfg = self._last_pool, self.core.model.cfg
        pages = np.asarray(req.pages, np.int32)
        arrays: Dict[str, Any] = {}
        for gname, gkey, is_paged in transformer.paged_cache_kinds(cfg):
            for name, arr in pool.state["caches"][gname][gkey].items():
                key = f"{gname}/{gkey}/{name}"
                if is_paged:
                    arrays["pages/" + key] = arr[:, pages]
                else:
                    arrays["rows/" + key] = jax.lax.dynamic_slice_in_dim(
                        arr, slot, 1, axis=1)
        meta = {"prompt": [int(t) for t in req.prompt],
                "tokens": [int(t) for t in req.tokens],
                "max_new_tokens": int(req.max_new_tokens),
                "cache_len": int(req.cache_len),
                "n_pages": len(req.pages)}
        blob = park_mod.pack_parked(meta, arrays)
        sch.park(slot)
        self.pools.release(slot)
        return blob

    def resume_parked(self, sch: sched_mod.Scheduler,
                      blob: bytes) -> sched_mod.Request:
        """Re-enter a parked session: rebuild the scheduler residue
        (:meth:`Scheduler.submit_parked`) and stage the page contents so
        the next serve() boundary that admits it scatters them back."""
        from repro.serve import park as park_mod
        meta, arrays = park_mod.unpack_parked(blob)
        req = sch.submit_parked(meta["prompt"], meta["max_new_tokens"],
                                meta["tokens"])
        self._park_pending[req.rid] = arrays
        return req

    def _exec_resume(self, pool: PoolState, rs: sched_mod.ResumeStep,
                     geom: sched_mod.PageGeometry) -> PoolState:
        """Scatter a parked session's staged content into its freshly
        mapped pages and re-arm the slot for decode.

        Only PRIVATE logical pages are written: shared (prefix-matched)
        pages already hold the canonical bytes — at fp16 bit-identical to
        the parked copies, which is what keeps park/resume bit-exact even
        through sharing. Parked pages beyond the new mapping (old growth
        margin) sit past the KV frontier and are dropped; freshly mapped
        pages beyond the parked coverage stay zero until decode writes
        them (scale reset at offset 0 keeps int8 clean)."""
        from repro.models import transformer
        req, slot = rs.req, rs.slot
        arrays = self._park_pending.pop(req.rid)
        cfg = self.core.model.cfg
        n_shared = req.n_shared
        parked_n = next((v.shape[1] for key, v in arrays.items()
                         if key.startswith("pages/")), len(req.pages))
        k = min(parked_n, len(req.pages)) - n_shared
        priv = np.asarray(req.pages[n_shared:n_shared + k], np.int32)
        new_caches: Dict[str, Any] = {}
        for gname, gkey, is_paged in transformer.paged_cache_kinds(cfg):
            leaf = pool.state["caches"][gname][gkey]
            new_leaf = dict(leaf)
            for name, arr in leaf.items():
                if is_paged:
                    src = jnp.asarray(
                        arrays[f"pages/{gname}/{gkey}/{name}"])
                    new_leaf[name] = arr.at[:, priv].set(
                        src[:, n_shared:n_shared + k].astype(arr.dtype))
                else:
                    src = jnp.asarray(arrays[f"rows/{gname}/{gkey}/{name}"])
                    new_leaf[name] = jax.lax.dynamic_update_slice_in_dim(
                        arr, src.astype(arr.dtype), slot, axis=1)
            new_caches.setdefault(gname, {})[gkey] = new_leaf
        return dataclasses.replace(
            pool, state={**pool.state, "caches": new_caches},
            tok=pool.tok.at[slot].set(int(req.tokens[-1])),
            cache_len=pool.cache_len.at[slot].set(req.cache_len),
            done=pool.done.at[slot].set(False),
            n_gen=pool.n_gen.at[slot].set(len(req.tokens)),
            budget=pool.budget.at[slot].set(req.max_new_tokens))

    # -------------------------------------------------------- paged serve
    @staticmethod
    def _owner_role(req: sched_mod.Request) -> str:
        """Which role a request's pool work belongs to: mid-prefill (the
        cursor short of the prompt, or freshly PREFILLING) is prefill-role
        work; everything decoding is decode-role work."""
        if (req.status == sched_mod.PREFILLING
                or 0 <= req.prefill_pos < req.prompt_len):
            return PREFILL_ROLE
        return DECODE_ROLE

    def _serve_paged(self, sch: sched_mod.Scheduler,
                     max_steps: Optional[int] = None) -> ServeReport:
        """Continuous batching over the paged two-tier pool.

        Same drain-boundary discipline as the dense loop (ONE host read per
        chunk — per ROLE when disaggregated); what changes is the boundary
        work: the scheduler plans grow / preempt / restore / admit in
        pages, the engine executes the device copies in plan order and
        uploads the fresh block table, and the decode chunk walks block
        tables instead of slot slabs.

        Disaggregated boundary order: spills -> restores -> admissions and
        prefill chunks (prefill role) -> page handovers (the zero-copy
        ownership flips for this boundary's final chunks) -> decode-view
        block-table upload -> decode/verify chunk (decode role) -> decode
        drain fetch -> prefill drain fetch (pending first tokens, only on
        boundaries that completed a prompt). Outputs are bit-identical to
        the combined loop; only issue order and attribution change.
        """
        core, pools = self.core, self.pools
        pre, dec = self.prefill_role, self.decode_role
        geom = sch.pages
        disagg = self.ecfg.disaggregate or sch.disaggregate
        if disagg and not sch.disaggregate:
            sch.enable_disaggregation()
        if sch.prefix_index is not None and core._has_ssm():
            raise ValueError(
                "prefix sharing requires attention-only models: recurrent "
                "SSM state is per-sequence, not per-page (docs/SERVING.md)")
        if sch.chunk_prefill_tokens is not None and core._has_ssm():
            raise ValueError(
                "chunked prefill requires attention-only models: recurrent "
                "SSM state has no resumable KV prefix (docs/SERVING.md)")
        self.last_stats = {"host_syncs": 0, "decode_steps": 0, "chunks": 0}
        pre_role = dec_role = None
        if disagg:
            self.last_stats["host_syncs_by_role"] = {PREFILL_ROLE: 0,
                                                     DECODE_ROLE: 0}
            self.last_stats["decode_tokens"] = 0
            pre_role, dec_role = PREFILL_ROLE, DECODE_ROLE
        spec_k = self.ecfg.speculate_tokens
        if spec_k:
            self.last_stats.update(speculate_tokens=spec_k,
                                   spec_proposed=0, spec_accepted=0)
        pool, spill = pools.init_paged_pool(sch)
        pending_first: List[Tuple[sched_mod.Request, jax.Array]] = []
        boundary_wall: List[float] = []
        boundary_tokens: List[int] = []
        boundary_decode_wall: List[float] = []
        step_clock = 0
        n = self.ecfg.sync_interval
        p_max = geom.max_pages_per_slot
        while sch.has_work():
            t0 = time.perf_counter()
            # a speculative boundary advances a slot by up to k+1 tokens in
            # its one verify forward, so page growth is planned for k+1
            plan = sch.plan_boundary(
                chunk_tokens=(spec_k + 1 if spec_k else n),
                max_len=self.ecfg.max_len)
            for req in plan.rejects:
                req.finish_step = step_clock
            # spills FIRST: they read layer-0 pages that restores/admits may
            # reuse later this boundary (functional arrays keep this exact)
            for act in plan.spills:
                spill = core._timed(
                    "insert", pools.exec_spill, pool, spill, act, p_max,
                    role=self._owner_role(act.req) if disagg else None)
            for act in plan.restores:
                role = self._owner_role(act.req) if disagg else None
                if disagg:
                    pools.claim(act.slot, role)
                pool = core._timed("insert", pools.exec_restore,
                                   pool, spill, act, p_max, role=role)
            # layer-2 resumes BEFORE admissions/prefill chunks: a resumed
            # session's pages were registered in the prefix index at plan
            # time, so their bytes must be resident before any same-
            # boundary matcher's suffix chunk reads them
            for rs in plan.resumes:
                if disagg:
                    pools.claim(rs.slot, DECODE_ROLE)
                pool = core._timed("insert", self._exec_resume, pool, rs,
                                   geom, role=dec_role)
            for slot, req in plan.admits:
                req.admit_step = step_clock
                if disagg:
                    pools.claim(slot, PREFILL_ROLE)
                if req.prefill_pos >= 0:
                    continue    # chunked admission: runs via prefill_steps
                if req.prefix_len:      # prefix-index hit: suffix-only prefill
                    pool, first = core._timed(
                        "prefill", pre.shared_paged_admit,
                        pool, slot, req, geom, role=pre_role)
                else:
                    pool, first = core._timed("prefill", pre.paged_admit,
                                              pool, slot, req, geom,
                                              role=pre_role)
                req.status = sched_mod.DECODING
                pending_first.append((req, first))
            # chunk prefills AFTER every copy, in plan order (scheduler's
            # ordering contract); a final chunk arms its slot like an admit
            for step in plan.prefill_steps:
                pool, first = core._timed("prefill", pre.exec_prefill_chunk,
                                          pool, step, geom, role=pre_role)
                if step.final:
                    step.req.status = sched_mod.DECODING
                    pending_first.append((step.req, first))
            # page handover: each request whose prompt completed this
            # boundary moves prefill -> decode by a zero-copy ownership
            # flip; the decode role's table upload below carries its row
            for h in plan.handovers:
                core._timed("handover", pools.transfer_ownership,
                            h.slot, h.pages)
            # the boundary's page moves, as one host->device upload; the
            # decode role uploads only the rows it owns (handover is what
            # makes a row appear)
            pool = dataclasses.replace(pool, block_tables=jnp.asarray(
                sch.block_table(role=DECODE_ROLE) if disagg
                else sch.block_table()))
            t_dec = time.perf_counter()
            if spec_k:
                # one verify forward replaces the sync_interval-step scan;
                # the boundary still costs exactly one host sync below
                drafts, dlen = dec.build_drafts(sch, spec_k)
                pool, toks, valid = core._timed(
                    "generate", dec.verify_fn(spec_k), core.params, pool,
                    jnp.asarray(drafts), jnp.asarray(dlen), role=dec_role)
                step_clock += 1
                self.last_stats["decode_steps"] += 1
                self.last_stats["spec_proposed"] += int(dlen.sum())
            else:
                pool, toks, valid = core._timed(
                    "generate", dec.pool_chunk(n), core.params, pool,
                    role=dec_role)
                step_clock += n
                self.last_stats["decode_steps"] += n
            self.last_stats["chunks"] += 1
            # ---- drain boundary: ONE host sync per role (decode always;
            # prefill only on boundaries that completed a prompt)
            if disagg:
                toks_h, valid_h, done_h = core._timed(
                    "drain", core._fetch, (toks, valid, pool.done),
                    DECODE_ROLE, role=DECODE_ROLE)
                boundary_decode_wall.append(time.perf_counter() - t_dec)
                firsts = []
                if pending_first:
                    firsts = core._timed(
                        "drain", core._fetch,
                        [f for _, f in pending_first], PREFILL_ROLE,
                        role=PREFILL_ROLE)
            else:
                toks_h, valid_h, done_h, firsts = core._timed(
                    "drain", core._fetch,
                    (toks, valid, pool.done, [f for _, f in pending_first]))
            emitted = len(firsts)
            for (req, _), f in zip(pending_first, firsts):
                req.tokens.append(int(f))
                # the first token becomes real only at THIS drain — the
                # boundary clock has already advanced past the decode/verify
                # work, so ttft_emit_steps measures true first-token
                # availability instead of the admission-time clock (which is
                # 0 for anything admitted at the first boundary)
                req.first_step = step_clock
            pending_first.clear()
            for slot in sorted(sch.active):
                req = sch.active[slot]
                before = len(req.tokens)
                req.tokens.extend(
                    int(t) for t, v in zip(toks_h[:, slot], valid_h[:, slot])
                    if v)
                got = len(req.tokens) - before
                emitted += got
                if disagg:
                    self.last_stats["decode_tokens"] += got
                if spec_k:
                    # a live slot's boundary emission is accepted drafts + 1
                    # correction token; just-admitted slots (dlen=0) emit
                    # exactly 1 and contribute 0 accepted
                    self.last_stats["spec_accepted"] += max(got - 1, 0)
                # a mid-prefill slot's device done flag is still the free
                # marker from before its admission — only DECODING slots
                # can drain
                if done_h[slot] and req.status != sched_mod.PREFILLING:
                    req.finish_step = step_clock
                    pools.release(slot)
                    sch.complete(slot)
            boundary_wall.append(time.perf_counter() - t0)
            boundary_tokens.append(emitted)
            if max_steps is not None and step_clock >= max_steps:
                break
        self.last_stats["boundary_wall_s"] = boundary_wall
        self.last_stats["boundary_tokens"] = boundary_tokens
        if disagg:
            # decode-role boundary wall: decode dispatch + its drain only
            # (meaningful under phase_timing, where the prefill phase has
            # blocked before t_dec) — the inter-token clock a decode
            # consumer experiences when prefill runs on its own engine
            self.last_stats["boundary_decode_wall_s"] = boundary_decode_wall
        self._finish_spec_stats()
        # stage the pool for park_request between serve() calls (the next
        # serve() builds a fresh pool — parking is how a still-active
        # session's KV survives the gap)
        self._last_pool, self._last_spill = pool, spill
        stats = dict(self.last_stats)
        stats.update(sch.stats())
        return ServeReport(requests=(sch.drained + list(sch.active.values())
                                     + list(sch.queue)),
                           stats=stats)

    def _finish_spec_stats(self) -> None:
        """Derive the acceptance summary counters once a serve run ends."""
        if "spec_proposed" not in self.last_stats:
            return
        prop = self.last_stats["spec_proposed"]
        acc = self.last_stats["spec_accepted"]
        self.last_stats["spec_rejected"] = prop - acc
        self.last_stats["spec_acceptance_rate"] = (
            acc / prop if prop else 0.0)

    # ------------------------------------------------------------ stream
    def serve(self, requests: Iterable[sched_mod.Request] = (),
              scheduler: Optional[sched_mod.Scheduler] = None, *,
              max_steps: Optional[int] = None) -> ServeReport:
        """Continuous batching over a request stream.

        Loop invariant: between drain boundaries everything is on-device.
        Each iteration (1) admits queued requests into free slots, (2) runs
        one ``sync_interval`` decode chunk over the whole pool, (3) performs
        ONE host sync to read the chunk's tokens + done mask, then frees
        drained slots so the next iteration refills them.
        """
        with self._mesh_scope():
            return self._serve_impl(requests, scheduler, max_steps=max_steps)

    def _serve_impl(self, requests: Iterable[sched_mod.Request] = (),
                    scheduler: Optional[sched_mod.Scheduler] = None, *,
                    max_steps: Optional[int] = None) -> ServeReport:
        sch = scheduler or sched_mod.Scheduler.for_model(
            self.model.cfg, self.ecfg.max_len)
        for req in requests:
            sch.submit_request(req)
        if sch.pages is not None:        # paged two-tier pool
            return self._serve_paged(sch, max_steps)
        if self.ecfg.disaggregate or sch.disaggregate:
            raise ValueError(
                "disaggregated serving requires the paged pool: page "
                "handover moves block-table rows, which the dense "
                "slot-slab pool does not have (DESIGN.md §Disaggregated "
                "serving)")
        chunked = sch.chunk_prefill_tokens is not None
        if chunked and self._has_ssm():
            raise ValueError(
                "chunked prefill requires attention-only models: recurrent "
                "SSM state has no resumable KV prefix (docs/SERVING.md)")
        self.last_stats = {"host_syncs": 0, "decode_steps": 0, "chunks": 0}
        spec_k = self.ecfg.speculate_tokens
        if spec_k:
            self.last_stats.update(speculate_tokens=spec_k,
                                   spec_proposed=0, spec_accepted=0)
        core, pre, dec = self.core, self.prefill_role, self.decode_role
        pool = self.init_pool(sch.n_slots)
        pending_first: List[Tuple[sched_mod.Request, jax.Array]] = []
        boundary_wall: List[float] = []
        boundary_tokens: List[int] = []
        step_clock = 0
        while sch.has_work():
            t0 = time.perf_counter()
            for slot, req in sch.admit():
                req.admit_step = step_clock
                if req.prompt_len > self.ecfg.max_len:
                    # reject cleanly: one bad request must not abort the
                    # stream or leak its slot
                    req.finish_step = step_clock
                    sch.complete(slot, status=sched_mod.REJECTED)
                    continue
                if chunked:
                    continue    # prefills by chunks via plan_prefill below
                pool, first = core._timed(
                    "prefill", pre.admit_into_slot,
                    pool, slot, req.prompt, req.max_new_tokens)
                req.status = sched_mod.DECODING
                pending_first.append((req, first))
            if chunked:
                for step in sch.plan_prefill():
                    pool, first = core._timed(
                        "prefill", pre.exec_dense_chunk, pool, step)
                    if step.final:
                        step.req.status = sched_mod.DECODING
                        pending_first.append((step.req, first))
            if spec_k:
                # one verify forward replaces the sync_interval-step scan;
                # the boundary still costs exactly one host sync below
                drafts, dlen = dec.build_drafts(sch, spec_k)
                pool, toks, valid = core._timed(
                    "generate", dec.verify_fn(spec_k), core.params, pool,
                    jnp.asarray(drafts), jnp.asarray(dlen))
                step_clock += 1
                self.last_stats["decode_steps"] += 1
                self.last_stats["spec_proposed"] += int(dlen.sum())
            else:
                n = self.ecfg.sync_interval
                pool, toks, valid = core._timed(
                    "generate", dec.pool_chunk(n), core.params, pool)
                step_clock += n
                self.last_stats["decode_steps"] += n
            self.last_stats["chunks"] += 1
            # ---- drain boundary: the single host sync of this iteration
            toks_h, valid_h, done_h, firsts = core._timed(
                "drain", core._fetch,
                (toks, valid, pool.done, [f for _, f in pending_first]))
            emitted = len(firsts)
            for (req, _), f in zip(pending_first, firsts):
                req.tokens.append(int(f))
                # the first token becomes real only at THIS drain — the
                # boundary clock has already advanced past the decode/verify
                # work, so ttft_emit_steps measures true first-token
                # availability instead of the admission-time clock (which is
                # 0 for anything admitted at the first boundary)
                req.first_step = step_clock
            pending_first.clear()
            for slot in sorted(sch.active):
                req = sch.active[slot]
                before = len(req.tokens)
                req.tokens.extend(
                    int(t) for t, v in zip(toks_h[:, slot], valid_h[:, slot])
                    if v)
                got = len(req.tokens) - before
                emitted += got
                if spec_k:
                    # a live slot's boundary emission is accepted drafts + 1
                    # correction token; just-admitted slots (dlen=0) emit
                    # exactly 1 and contribute 0 accepted
                    self.last_stats["spec_accepted"] += max(got - 1, 0)
                # mid-prefill slots keep their stale free-marker done flag;
                # only DECODING slots can drain
                if done_h[slot] and req.status != sched_mod.PREFILLING:
                    req.finish_step = step_clock
                    sch.complete(slot)
            boundary_wall.append(time.perf_counter() - t0)
            boundary_tokens.append(emitted)
            if max_steps is not None and step_clock >= max_steps:
                break
        self.last_stats["boundary_wall_s"] = boundary_wall
        self.last_stats["boundary_tokens"] = boundary_tokens
        self._finish_spec_stats()
        stats = dict(self.last_stats)
        stats.update(sch.stats())
        return ServeReport(requests=sch.drained + list(sch.active.values()),
                           stats=stats)
