"""Continuous-batching scheduler: request queue, slot table, admission policy.

The serving engine treats the KV cache as a *pool of slots* — one resident
sequence per slot, all slots decoded in a single batched step. This module
owns everything about slots that is NOT device math:

  * :class:`Request` — one user request and its lifecycle
    (``queued -> prefilling -> decoding -> drained``).
  * :class:`SlotTable` — which request occupies which KV slot, with per-slot
    allocation counters (slot *reuse* is the whole point: a drained slot is
    immediately refilled from the queue without touching in-flight rows).
  * :class:`Scheduler` — admission policy. ``fcfs`` admits in arrival order
    (the fairness default); ``shortest`` admits the shortest queued prompt
    first (throughput-greedy, can starve long prompts — benchmarks only).

Slot budget = the paper's capacity partition, applied to serving. The number
of KV slots is derived from the active :class:`~repro.core.target.
HardwareTarget` through the SAME :class:`~repro.core.target.
CapacityPartition` budget formula the tile planner uses for kernel blocks:
the KV pool level (HBM on TPU, the shared-L1 cluster SPM on MemPool) is
partitioned, and ``required_bytes(streamed=kv_bytes_per_token * max_len,
resident=recurrent state)`` prices one slot. MemPool's lesson — one logical
pool, explicitly partitioned — decides how many sequences may be resident.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.target import CapacityPartition, HardwareTarget, get_target
from repro.models.config import ModelConfig

#: Request lifecycle states (DESIGN.md §Serving — slot lifecycle).
QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"
DRAINED = "drained"
REJECTED = "rejected"      # invalid for the pool (e.g. prompt > max_len)


@dataclasses.dataclass
class Request:
    """One generation request moving through the slot lifecycle."""

    rid: int
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int
    status: str = QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    # lifecycle clocks, in decode steps of the serve loop (latency accounting)
    submit_step: int = 0
    admit_step: int = -1
    finish_step: int = -1

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[0])


# ---------------------------------------------------------------------------
# Slot budget — CapacityPartition applied to the KV pool
# ---------------------------------------------------------------------------


def kv_bytes_per_token(cfg: ModelConfig, cache_dtype_bytes: int = 2) -> int:
    """KV-pool bytes one resident sequence streams per cached token.

    Attention layers scale with sequence length (this function); recurrent
    SSM state does not and is priced separately by
    :func:`resident_bytes_per_slot`.
    """
    total = 0
    for group in cfg.layer_groups():
        for kind in group.pattern:
            if kind.attn == "mamba":
                continue
            if kind.attn == "mla":
                per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            else:
                per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
            total += group.n_repeat * per_tok * cache_dtype_bytes
    return total


def resident_bytes_per_slot(cfg: ModelConfig, state_dtype_bytes: int = 4) -> int:
    """Sequence-length-independent per-slot state (conv + SSM recurrences)."""
    total = 0
    for group in cfg.layer_groups():
        for kind in group.pattern:
            if kind.attn != "mamba":
                continue
            conv = (cfg.ssm_conv - 1) * cfg.ssm_d_inner
            ssm = cfg.ssm_d_inner * cfg.ssm_d_state
            total += group.n_repeat * (conv + ssm) * state_dtype_bytes
    return total


def pool_partition(target: Optional[HardwareTarget] = None, *,
                   fraction: float = 0.8) -> CapacityPartition:
    """A :class:`CapacityPartition` of the target's KV-pool memory level.

    The pool level is the level that *feeds* the scratchpad: HBM on TPU
    targets, the whole shared-L1 cluster SPM on MemPool (where the paper's
    pool IS the scratchpad). ``n_buffers=1``: KV rows are resident for a
    sequence's lifetime, not double-buffered tiles — but the budget formula
    (``required = ceil(mult * streamed) + resident <= capacity * fraction``)
    is the same contract the tile planner enforces.
    """
    target = target or get_target()
    names = target.hierarchy.names
    level = target.hierarchy.level(
        "hbm" if "hbm" in names else target.scratchpad_level)
    assert level.capacity_bytes is not None, level.name
    return CapacityPartition(
        capacity_bytes=level.capacity_bytes, fraction=fraction, n_buffers=1,
        db_margin=0.0, align=target.tile_align, word_bytes=target.word_bytes)


def derive_n_slots(cfg: ModelConfig, max_len: int, *,
                   target: Optional[HardwareTarget] = None,
                   fraction: float = 0.8, max_slots: int = 64,
                   cache_dtype_bytes: int = 2) -> int:
    """How many KV slots the pool sustains at ``max_len`` per sequence."""
    part = pool_partition(target, fraction=fraction)
    per_slot = part.required_bytes(
        kv_bytes_per_token(cfg, cache_dtype_bytes) * max_len,
        resident_bytes_per_slot(cfg))
    n = part.budget_bytes // max(per_slot, 1)
    return int(max(1, min(n, max_slots)))


def synthetic_stream(n_requests: int, prompt_len: int, gen_len: int,
                     vocab: int, seed: int = 0) -> List[Dict[str, Any]]:
    """The canonical mixed-length synthetic workload: prompt lengths in
    [prompt_len/2, prompt_len], budgets in [gen_len/2, gen_len]. Shared by
    the stream driver and the serving benchmark so the serve_bench.json
    datapoint measures exactly what ``--stream`` drives."""
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n_requests):
        plen = int(rng.randint(max(1, prompt_len // 2), prompt_len + 1))
        glen = int(rng.randint(max(1, gen_len // 2), gen_len + 1))
        out.append({"prompt": rng.randint(2, vocab,
                                          size=plen).astype(np.int32),
                    "max_new_tokens": glen})
    return out


# ---------------------------------------------------------------------------
# Slot table
# ---------------------------------------------------------------------------


class SlotTable:
    """Occupancy of the pooled KV cache: slot index -> resident request id."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._occupant: List[Optional[int]] = [None] * n_slots
        #: how many times each slot has been (re)allocated — reuse evidence
        self.allocations = [0] * n_slots

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._occupant) if r is None]

    def occupant(self, slot: int) -> Optional[int]:
        return self._occupant[slot]

    def allocate(self, rid: int) -> int:
        for i, r in enumerate(self._occupant):
            if r is None:
                self._occupant[i] = rid
                self.allocations[i] += 1
                return i
        raise RuntimeError("no free slot (admission must check free_slots)")

    def release(self, slot: int) -> int:
        rid = self._occupant[slot]
        if rid is None:
            raise RuntimeError(f"slot {slot} already free")
        self._occupant[slot] = None
        return rid

    @property
    def n_occupied(self) -> int:
        return sum(r is not None for r in self._occupant)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


class Scheduler:
    """Admission control between the request queue and the slot table."""

    POLICIES = ("fcfs", "shortest")

    def __init__(self, n_slots: int, policy: str = "fcfs"):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; have {self.POLICIES}")
        self.n_slots = n_slots
        self.policy = policy
        self.table = SlotTable(n_slots)
        self.queue: Deque[Request] = collections.deque()
        self.active: Dict[int, Request] = {}      # slot -> request
        self.drained: List[Request] = []
        self._next_rid = 0
        self.admit_order: List[int] = []          # rids in admission order

    @classmethod
    def for_model(cls, cfg: ModelConfig, max_len: int, *,
                  target: Optional[HardwareTarget] = None,
                  policy: str = "fcfs", fraction: float = 0.8,
                  max_slots: int = 64) -> "Scheduler":
        """Size the slot table from the target's CapacityPartition budget."""
        return cls(derive_n_slots(cfg, max_len, target=target,
                                  fraction=fraction, max_slots=max_slots),
                   policy=policy)

    # ------------------------------------------------------------- queue
    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               submit_step: int = 0) -> Request:
        return self.submit_request(Request(
            rid=0, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=int(max_new_tokens), submit_step=submit_step))

    def submit_request(self, req: Request) -> Request:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1 (a request always emits its "
                f"prefill token), got {req.max_new_tokens}")
        req.rid = self._next_rid
        self._next_rid += 1
        req.status = QUEUED
        self.queue.append(req)
        return req

    # --------------------------------------------------------- admission
    def _pop_next(self) -> Request:
        if self.policy == "shortest":
            idx = min(range(len(self.queue)),
                      key=lambda i: self.queue[i].prompt_len)
            req = self.queue[idx]
            del self.queue[idx]
            return req
        return self.queue.popleft()               # fcfs

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the queue; returns (slot, request) pairs.

        Called at batch-drain boundaries only — admission never interrupts
        the in-flight decode chunk, it refills slots between chunks.
        """
        placed: List[Tuple[int, Request]] = []
        while self.queue and self.table.n_occupied < self.n_slots:
            req = self._pop_next()
            slot = self.table.allocate(req.rid)
            req.status = PREFILLING
            self.active[slot] = req
            self.admit_order.append(req.rid)
            placed.append((slot, req))
        return placed

    def complete(self, slot: int, status: str = DRAINED) -> Request:
        """Mark the slot's request drained (or rejected) and free the slot
        for reuse."""
        req = self.active.pop(slot)
        self.table.release(slot)
        req.status = status
        self.drained.append(req)
        return req

    # ------------------------------------------------------------- state
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    def stats(self) -> Dict[str, Any]:
        allocs = self.table.allocations
        return {
            "n_slots": self.n_slots,
            "policy": self.policy,
            "queued": len(self.queue),
            "active": len(self.active),
            "drained": sum(r.status == DRAINED for r in self.drained),
            "rejected": sum(r.status == REJECTED for r in self.drained),
            "slot_allocations": list(allocs),
            "max_slot_reuse": max(allocs) if allocs else 0,
        }
