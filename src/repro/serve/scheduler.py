"""Continuous-batching scheduler: request queue, slot table, page allocator.

The serving engine treats the KV cache as a *pool of slots* — one resident
sequence per slot, all slots decoded in a single batched step. This module
owns everything about slots and pages that is NOT device math:

  * :class:`Request` — one user request and its lifecycle
    (``queued -> prefilling -> decoding -> drained``, with a
    ``preempted`` detour in paged mode).
  * :class:`SlotTable` — which request occupies which KV slot, with per-slot
    allocation counters (slot *reuse* is the whole point: a drained slot is
    immediately refilled from the queue without touching in-flight rows).
  * :class:`PagePool` / :class:`PageGeometry` — the paged two-tier KV pool:
    KV storage is a flat pool of fixed-size pages; each slot maps logical
    page indices to physical pages through a block table. Admission is by
    *pages*, not slots (pages for ``prompt + chunk`` only, grown at each
    boundary), so short requests stop paying worst-case ``max_len``
    reservations. When layer 0 (the hot tier) is exhausted, the youngest
    resident sequence is preempted: its pages spill verbatim to layer 1
    (the stacked spill tier) and are dereferenced; a later restore copies
    them back and decoding resumes bit-exactly.
  * :class:`PrefixIndex` — content index over resident full pages for
    ref-counted prefix sharing: admissions whose prompt prefix is already
    cached map the shared pages read-only and prefill only the suffix,
    with the frontier page copied-on-write so decode never mutates another
    request's history (DESIGN.md §Prefix sharing & copy-on-write).
  * :class:`Scheduler` — admission policy. ``fcfs`` admits in arrival order
    (the fairness default); ``shortest`` admits the shortest queued prompt
    first (throughput-greedy, can starve long prompts — benchmarks only).

Slot and page budgets = the paper's capacity partition, applied to serving.
The dense slot count is derived from the active :class:`~repro.core.target.
HardwareTarget` through the SAME :class:`~repro.core.target.
CapacityPartition` budget formula the tile planner uses for kernel blocks.
The paged pool stacks that partition across two memory layers
(:class:`~repro.core.target.TieredPartition` — MemPool-3D's logic-die /
memory-die split): layer 0 prices the hot page pool, layer 1 the spill
pool. MemPool's lesson — one logical pool, explicitly partitioned — decides
how many sequences may be resident, and the 3D lesson — stack a second
layer instead of stretching the first — decides where preempted sequences
park.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.target import (CapacityPartition, HardwareTarget,
                               TieredPartition, get_target)
from repro.models.config import ModelConfig

#: Request lifecycle states (DESIGN.md §Serving — slot lifecycle).
QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"
DRAINED = "drained"
REJECTED = "rejected"      # invalid for the pool (e.g. prompt > max_len)
PREEMPTED = "preempted"    # spilled to layer 1, waiting to be restored
PARKED = "parked"          # serialized to the layer-2 host tier; a resumed
                           # submission re-enters admission with its KV intact

#: Engine role names (DESIGN.md §Disaggregated serving). Routing a slot to
#: a role is a *scheduling* decision, so the canonical definitions live
#: here; ``serve/pool.py`` and the engine re-export them. The prefill role
#: runs admissions and prompt chunks; the decode role runs the batched
#: decode/verify forwards; a combined engine is both at once.
PREFILL_ROLE = "prefill"
DECODE_ROLE = "decode"


@dataclasses.dataclass
class Request:
    """One generation request moving through the slot lifecycle."""

    rid: int
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int
    status: str = QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    # lifecycle clocks, in decode steps of the serve loop (latency accounting)
    submit_step: int = 0
    admit_step: int = -1
    first_step: int = -1                # first output token produced
    finish_step: int = -1
    # chunked prefill (DESIGN.md §Chunked prefill): how many prompt tokens
    # (including any shared prefix) have KV cached so far. -1 = unchunked
    # admission, which prefills the whole prompt in one boundary. The cursor
    # survives preemption: a restored request resumes its next chunk here.
    prefill_pos: int = -1
    # paged mode: physical pages mapped to this request (layer 0 / layer 1)
    pages: List[int] = dataclasses.field(default_factory=list)
    spill_pages: List[int] = dataclasses.field(default_factory=list)
    spill_seat: int = -1                # layer-1 seat for resident SSM state
    preemptions: int = 0
    # prefix sharing (DESIGN.md §Prefix sharing & copy-on-write): tokens of
    # the prompt served from already-resident shared pages, how many leading
    # entries of ``pages`` are shared (refcounted, read-only) mappings, and
    # the source page a partially-matched frontier page is COW-copied from.
    prefix_len: int = 0
    n_shared: int = 0
    cow_src: int = -1
    # disaggregated serving (DESIGN.md §Disaggregated serving): which engine
    # role this request's pool work is routed to. "" in combined mode; set
    # to PREFILL_ROLE at admission and flipped to DECODE_ROLE by the
    # HandoverStep at the final prefill chunk. Survives preemption — a
    # mid-decode spill restores straight into the decode role.
    owner: str = ""

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[0])

    @property
    def cache_len(self) -> int:
        """Host-side mirror of the device ``cache_len``: the filled KV
        prefix. The last emitted token's K/V is written by the NEXT decode
        step, so the frontier is one behind the emitted count. Mid-chunked-
        prefill (no tokens yet, cursor short of the prompt) the frontier is
        the cursor itself."""
        if not self.tokens and 0 <= self.prefill_pos < self.prompt_len:
            return self.prefill_pos
        return self.prompt_len + max(len(self.tokens) - 1, 0)


# ---------------------------------------------------------------------------
# Slot budget — CapacityPartition applied to the KV pool
# ---------------------------------------------------------------------------


def kv_bytes_per_token(cfg: ModelConfig, cache_dtype_bytes: int = 2) -> int:
    """KV-pool bytes one resident sequence streams per cached token.

    Attention layers scale with sequence length (this function); recurrent
    SSM state does not and is priced separately by
    :func:`resident_bytes_per_slot`.
    """
    total = 0
    for group in cfg.layer_groups():
        for kind in group.pattern:
            if kind.attn == "mamba":
                continue
            if kind.attn == "mla":
                per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            else:
                per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
            total += group.n_repeat * per_tok * cache_dtype_bytes
    return total


def kv_scale_bytes_per_page(cfg: ModelConfig) -> int:
    """Per-page overhead of a *scaled* codec (DESIGN.md §Tiered KV
    compression): one f32 scale per page per KV leaf — two leaves per
    attention layer (k/v, or the MLA ckv/krope pair), stored alongside the
    block table and priced into the page so quantized geometry never
    overcommits the byte budget."""
    total = 0
    for group in cfg.layer_groups():
        for kind in group.pattern:
            if kind.attn == "mamba":
                continue
            total += group.n_repeat * 2 * 4
    return total


def kv_shards(cfg: ModelConfig, model_shards: int = 1) -> int:
    """How many ways the KV pool actually shards over the `model` axis.

    Head-axis page placement (DESIGN.md §Sharded serving) only scales pool
    capacity when EVERY seq-scaling cache in the model carries a head axis
    the mesh divides: GQA caches shard ``n_kv_heads`` ways at best; the MLA
    latent and recurrent SSM state have no head axis and replicate. Mirrors
    the divisibility gate in ``repro.models.attention`` (heads_divide) — a
    pool priced ``m``-ways-bigger than the arrays actually shard would
    OOM layer 0, so this is deliberately all-or-nothing.
    """
    if model_shards <= 1:
        return 1
    saw_attention = False
    for group in cfg.layer_groups():
        for kind in group.pattern:
            if kind.attn == "mamba":
                continue                      # per-slot state, replicated
            if kind.attn == "mla":
                return 1                      # latent pages replicate
            if cfg.n_kv_heads % model_shards != 0:
                return 1
            saw_attention = True
    return model_shards if saw_attention else 1


def resident_bytes_per_slot(cfg: ModelConfig, state_dtype_bytes: int = 4) -> int:
    """Sequence-length-independent per-slot state (conv + SSM recurrences)."""
    total = 0
    for group in cfg.layer_groups():
        for kind in group.pattern:
            if kind.attn != "mamba":
                continue
            conv = (cfg.ssm_conv - 1) * cfg.ssm_d_inner
            ssm = cfg.ssm_d_inner * cfg.ssm_d_state
            total += group.n_repeat * (conv + ssm) * state_dtype_bytes
    return total


def pool_partition(target: Optional[HardwareTarget] = None, *,
                   fraction: float = 0.8) -> CapacityPartition:
    """A :class:`CapacityPartition` of the target's KV-pool memory level.

    The pool level is the level that *feeds* the scratchpad: HBM on TPU
    targets, the whole shared-L1 cluster SPM on MemPool (where the paper's
    pool IS the scratchpad). ``n_buffers=1``: KV rows are resident for a
    sequence's lifetime, not double-buffered tiles — but the budget formula
    (``required = ceil(mult * streamed) + resident <= capacity * fraction``)
    is the same contract the tile planner enforces.
    """
    target = target or get_target()
    names = target.hierarchy.names
    level = target.hierarchy.level(
        "hbm" if "hbm" in names else target.scratchpad_level)
    assert level.capacity_bytes is not None, level.name
    return CapacityPartition(
        capacity_bytes=level.capacity_bytes, fraction=fraction, n_buffers=1,
        db_margin=0.0, align=target.tile_align, word_bytes=target.word_bytes)


def derive_n_slots(cfg: ModelConfig, max_len: int, *,
                   target: Optional[HardwareTarget] = None,
                   fraction: float = 0.8, max_slots: int = 64,
                   cache_dtype_bytes: int = 2,
                   pages: Optional["PageGeometry"] = None,
                   model_shards: int = 1, data_shards: int = 1) -> int:
    """How many KV slots the pool sustains.

    Dense (``pages=None``): every slot reserves a full ``max_len`` KV slab,
    so slots = budget // slab. Paged: a slot only needs one mapped page to
    be resident, so the same byte budget carries ``n_data_pages`` slots in
    the best case — the two-tier pool's capacity win. Admission by pages
    keeps actual residency honest.

    Mesh shards scale the budget, not the per-slot price: a ``model_shards``
    mesh holds ``kv_shards`` pool slices (head-axis placement), a
    ``data_shards`` mesh splits the batch axis, so the aggregate is
    ``device_count * per_device`` slots (the MaxText decode-microbenchmark
    shape) — with ``max_slots`` scaled the same way so a single shard's cap
    stays what it was. Both default to 1 = single-device budgets unchanged.
    """
    scale = kv_shards(cfg, model_shards) * max(1, data_shards)
    cap = max_slots * scale
    if pages is not None:
        return int(max(1, min(pages.n_data_pages, cap)))
    part = pool_partition(target, fraction=fraction).scaled(scale)
    per_slot = part.required_bytes(
        kv_bytes_per_token(cfg, cache_dtype_bytes) * max_len,
        resident_bytes_per_slot(cfg))
    n = part.budget_bytes // max(per_slot, 1)
    return int(max(1, min(n, cap)))


def derive_prefill_chunk(cfg: ModelConfig, *,
                         target: Optional[HardwareTarget] = None,
                         fraction: float = 0.25, max_chunk: int = 512,
                         cache_dtype_bytes: int = 2) -> int:
    """Per-boundary prefill-token budget (DESIGN.md §Chunked prefill).

    Priced through the SAME :class:`CapacityPartition` formula that prices
    tiles, slots, and pages — here over the compute tier (the scratchpad
    level): one prefill token streams its KV write row plus one activation
    row, double-buffered like a kernel tile (``n_buffers=2``: the next
    chunk stages while the current one computes). The budget is the
    largest power of two whose streamed bytes fit ``fraction`` of the
    level, so derived chunk lengths land exactly on the engine's bucketed
    jit variants (O(log) compiled shapes).
    """
    target = target or get_target()
    level = target.hierarchy.level(target.scratchpad_level)
    assert level.capacity_bytes is not None, level.name
    part = CapacityPartition(
        capacity_bytes=level.capacity_bytes, fraction=fraction, n_buffers=2,
        db_margin=0.0, align=target.tile_align, word_bytes=target.word_bytes)
    per_tok = (kv_bytes_per_token(cfg, cache_dtype_bytes)
               + target.word_bytes * cfg.d_model)
    n = 1
    while n * 2 <= max_chunk and part.fits(per_tok * n * 2):
        n *= 2
    return n


def derive_speculate_tokens(cfg: ModelConfig, *,
                            target: Optional[HardwareTarget] = None,
                            fraction: float = 0.0625, max_tokens: int = 8,
                            cache_dtype_bytes: int = 2) -> int:
    """Per-boundary draft budget k (DESIGN.md §Speculative decoding).

    The verify forward is a width-(k+1) decode chunk, so k is priced
    exactly like :func:`derive_prefill_chunk` — each speculated position
    streams a KV write row plus an activation row through the compute
    tier, double-buffered — just against a much smaller ``fraction`` of
    the scratchpad level: the verify chunk rides alongside decode's
    full-pool KV sweep instead of owning the boundary the way a prefill
    chunk does. The budget is the largest power of two that fits, so
    verify-chunk widths (k+1) land on a handful of compiled shapes; k=0
    on a target too small to fit even one draft token disables
    speculation rather than thrashing the scratchpad.
    """
    target = target or get_target()
    level = target.hierarchy.level(target.scratchpad_level)
    assert level.capacity_bytes is not None, level.name
    part = CapacityPartition(
        capacity_bytes=level.capacity_bytes, fraction=fraction, n_buffers=2,
        db_margin=0.0, align=target.tile_align, word_bytes=target.word_bytes)
    per_tok = (kv_bytes_per_token(cfg, cache_dtype_bytes)
               + target.word_bytes * cfg.d_model)
    if not part.fits(per_tok):
        return 0
    n = 1
    while n * 2 <= max_tokens and part.fits(per_tok * n * 2):
        n *= 2
    return n


# ---------------------------------------------------------------------------
# Paged two-tier pool — PageGeometry, tiers, and the page allocator
# ---------------------------------------------------------------------------


def pool_tiers(target: Optional[HardwareTarget] = None, *,
               fraction: float = 0.8,
               layer1_fraction: Optional[float] = None) -> TieredPartition:
    """The KV pool partition, stacked across two memory layers.

    Layer 0 is :func:`pool_partition`'s budget (the hot tier resident
    sequences decode against); layer 1 is the stacked spill tier. The
    default split mirrors the paper's die split: a MemPool-3D target gets a
    full second layer (``layer1_fraction=1.0`` — the bonded memory die
    doubles capacity at iso-footprint); 2D and TPU targets get a half-layer
    spill budget (cold capacity behind the same port).
    """
    target = target or get_target()
    if layer1_fraction is None:
        flow = getattr(target.profile, "flow", None)
        layer1_fraction = 1.0 if flow == "3D" else 0.5
    return pool_partition(target, fraction=fraction).stacked(layer1_fraction)


@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """Shape of the paged two-tier KV pool.

    Physical page 0 of EACH tier is the reserved *null page*: block-table
    entries of free or out-of-range positions point at it, so stray writes
    (a drained slot's frozen decode, scatter tails past a prompt) land in
    memory no live sequence ever reads. Allocators hand out pages
    ``1..n_pages-1``.
    """

    page_tokens: int            # tokens per page
    n_pages: int                # layer-0 physical pages, incl. null page 0
    n_spill_pages: int          # layer-1 physical pages, incl. null page 0
    max_pages_per_slot: int     # block-table width: ceil(max_len/page_tokens)
    page_bytes: int             # KV bytes of one layer-0 page, at its codec
    # tier codecs (DESIGN.md §Tiered KV compression): how each tier encodes
    # page bytes. "fp16" is the identity (bit-exact, the default); quantized
    # codecs shrink page_bytes so the same budget holds more pages. The
    # spill tier may encode differently (spill_page_bytes prices it).
    layer0_codec: str = "fp16"
    layer1_codec: str = "fp16"
    spill_page_bytes: Optional[int] = None   # None -> same as page_bytes

    @property
    def depth(self) -> int:
        """Per-slot logical KV depth (>= max_len, page-aligned)."""
        return self.max_pages_per_slot * self.page_tokens

    @property
    def n_data_pages(self) -> int:
        return self.n_pages - 1

    @property
    def n_spill_data_pages(self) -> int:
        return self.n_spill_pages - 1

    @property
    def layer0_bytes(self) -> int:
        return self.n_data_pages * self.page_bytes

    @property
    def layer1_bytes(self) -> int:
        per = (self.spill_page_bytes if self.spill_page_bytes is not None
               else self.page_bytes)
        return self.n_spill_data_pages * per

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to map ``n_tokens`` of KV (at least one)."""
        return max(1, -(-int(n_tokens) // self.page_tokens))


def derive_page_geometry(cfg: ModelConfig, max_len: int, *,
                         target: Optional[HardwareTarget] = None,
                         fraction: float = 0.8,
                         layer1_fraction: Optional[float] = None,
                         page_tokens: int = 16, max_slots: int = 64,
                         cache_dtype_bytes: int = 2,
                         layer0_bytes: Optional[int] = None,
                         layer1_bytes: Optional[int] = None,
                         model_shards: int = 1,
                         kv_quant: Optional[str] = None) -> PageGeometry:
    """Page count, page size, and spill budget from the two-tier partition.

    ``layer0_bytes``/``layer1_bytes`` override the derived tier budgets —
    benchmarks use them to compare dense and paged pools inside the SAME
    layer-0 byte budget, and to force the spill tier into play on small
    smoke runs. Page counts are capped at ``max_slots`` full-depth
    sequences so host-scale targets do not allocate absurd pools.

    ``model_shards > 1`` prices pages against the mesh's aggregate pool:
    head-axis placement (when :func:`kv_shards` says the caches actually
    shard) means each shard physically holds ``1/kv_shards`` of every
    page's bytes, so the same per-shard layer-0 budget carries
    ``kv_shards``x the pages — the paper's die-level capacity split across
    chips. Byte overrides are per-shard budgets and scale the same way;
    the per-slot cap scales so one shard's worst case is unchanged.

    ``kv_quant`` picks the tier codecs (DESIGN.md §Tiered KV compression):
    each tier's page is priced at ITS codec's bytes-per-value (plus the
    per-page scale overhead for scaled codecs), so a quantized layer 0
    yields ~2x the pages in the same byte budget — the residency win the
    paper's capacity-per-byte argument predicts.
    """
    from repro.serve.pool import CODECS, quant_policy   # pool imports us
    l0_name, l1_name = quant_policy(kv_quant)
    l0, l1 = CODECS[l0_name], CODECS[l1_name]
    if (l0.name != "fp16" or l1.name != "fp16") and any(
            kind.attn == "mamba"
            for group in cfg.layer_groups() for kind in group.pattern):
        raise ValueError(
            "quantized KV pages require attention-only models: recurrent "
            "SSM state integrates every step and has no bounded per-page "
            "error story (docs/SERVING.md)")
    pt = int(max(1, min(page_tokens, max_len)))
    p_max = -(-int(max_len) // pt)

    def tier_page_bytes(codec) -> int:
        bpv = codec.bytes_per_value if kv_quant else cache_dtype_bytes
        per = kv_bytes_per_token(cfg, bpv) * pt
        if codec.scaled:
            per += kv_scale_bytes_per_page(cfg)
        return per

    page_bytes = tier_page_bytes(l0)
    spill_page_bytes = tier_page_bytes(l1)
    shards = kv_shards(cfg, model_shards)
    tiers = pool_tiers(target, fraction=fraction,
                       layer1_fraction=layer1_fraction).scaled(shards)
    resident = resident_bytes_per_slot(cfg) * max_slots
    n0, n1 = tiers.units_per_tier((page_bytes, spill_page_bytes), resident)
    if layer0_bytes is not None:
        n0 = (layer0_bytes * shards) // max(page_bytes, 1)
    if layer1_bytes is not None:
        n1 = (layer1_bytes * shards) // max(spill_page_bytes, 1)
    cap = max_slots * p_max * shards
    n0, n1 = min(int(n0), cap), min(int(n1), cap)
    if n0 < p_max:
        raise ValueError(
            f"layer-0 budget holds {n0} pages but one full-depth sequence "
            f"needs {p_max} (max_len={max_len}, page_tokens={pt}); raise the "
            f"budget or shrink max_len")
    return PageGeometry(page_tokens=pt, n_pages=n0 + 1,
                        n_spill_pages=max(n1, 0) + 1,
                        max_pages_per_slot=p_max, page_bytes=page_bytes,
                        layer0_codec=l0.name, layer1_codec=l1.name,
                        spill_page_bytes=spill_page_bytes)


class PagePool:
    """Ref-counted free-list allocator over a tier's pages (1..n_pages-1).

    Page 0 is the reserved null page and is never handed out. Allocation is
    all-or-nothing and hands out pages at refcount 1; :meth:`share` adds a
    reader to an already-mapped page (prefix sharing — DESIGN.md §Prefix
    sharing & copy-on-write); :meth:`free` drops one reference per page and
    only returns a page to the free list (LIFO, so reuse stays hot) when its
    refcount hits zero — a shared page stays resident for its other readers.
    Double-free (freeing an unmapped page) and foreign pages raise.

    ``in_use`` counts *physical* pages off the free list; ``mapped`` counts
    *logical* mappings (the sum of refcounts == block-table entries across
    all readers). ``mapped / in_use`` is the sharing factor.
    """

    def __init__(self, n_pages: int, name: str = "layer0"):
        if n_pages < 1:
            raise ValueError(f"need at least the null page, got {n_pages}")
        self.n_pages = n_pages
        self.name = name
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._free_set = set(self._free)
        self._refs = [0] * n_pages
        self.high_water = 0
        self.mapped = 0
        self.mapped_high_water = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` pages or None (all-or-nothing; never partial)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        for p in out:
            self._refs[p] = 1
        self.mapped += n
        self.high_water = max(self.high_water, self.in_use)
        self.mapped_high_water = max(self.mapped_high_water, self.mapped)
        return out

    def share(self, pages: Sequence[int]) -> None:
        """Add one reader to each (already-mapped) page."""
        for p in pages:
            if not 1 <= p < self.n_pages:
                raise ValueError(f"page {p} outside {self.name} pool "
                                 f"(1..{self.n_pages - 1})")
            if self._refs[p] < 1:
                raise RuntimeError(
                    f"sharing unmapped {self.name} page {p} (refcount 0)")
            self._refs[p] += 1
        self.mapped += len(pages)
        self.mapped_high_water = max(self.mapped_high_water, self.mapped)

    def free(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the pages actually released
        to the free list (refcount reached zero) so callers can drop any
        content-index entries for them."""
        released: List[int] = []
        for p in pages:
            if not 1 <= p < self.n_pages:
                raise ValueError(f"page {p} outside {self.name} pool "
                                 f"(1..{self.n_pages - 1})")
            if p in self._free_set or self._refs[p] < 1:
                raise RuntimeError(f"double free of {self.name} page {p}")
            self._refs[p] -= 1
            self.mapped -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                self._free_set.add(p)
                released.append(p)
        return released


class PrefixIndex:
    """Content index over resident full KV pages: chained token-id hash per
    full page -> the physical layer-0 page caching exactly that prefix.

    The key of logical page ``i`` hashes page ``i``'s token ids together
    with page ``i-1``'s key, so a hit at page ``i`` implies the WHOLE
    prefix up to ``(i+1) * page_tokens`` tokens matches — matching is a walk
    from page 0 that stops at the first miss. Only *full* pages are ever
    indexed (a partial tail page will receive decode writes and is never
    shareable), and an entry lives exactly as long as its page is mapped:
    the scheduler calls :meth:`forget` with whatever :meth:`PagePool.free`
    released. See DESIGN.md §Prefix sharing & copy-on-write.
    """

    _SEED = b"kv-prefix-index-v1"

    def __init__(self, page_tokens: int):
        self.page_tokens = int(page_tokens)
        self._by_key: Dict[bytes, int] = {}
        self._by_page: Dict[int, bytes] = {}

    def __len__(self) -> int:
        return len(self._by_key)

    def keys_for(self, prompt: Sequence[int]) -> List[bytes]:
        """One chained key per full page of ``prompt``."""
        toks = np.asarray(prompt, np.int32)
        out: List[bytes] = []
        prev = self._SEED
        for i in range(toks.shape[0] // self.page_tokens):
            page = toks[i * self.page_tokens:(i + 1) * self.page_tokens]
            prev = hashlib.blake2b(prev + page.tobytes(),
                                   digest_size=16).digest()
            out.append(prev)
        return out

    def match(self, prompt: Sequence[int]) -> List[int]:
        """Physical pages of the longest indexed full-page prefix."""
        pages: List[int] = []
        for key in self.keys_for(prompt):
            page = self._by_key.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def register(self, prompt: Sequence[int], pages: Sequence[int]) -> int:
        """Index a freshly admitted request's full prompt pages.

        ``pages[i]`` is the physical page at logical index ``i``. Keys that
        are already indexed keep their canonical page (the new request maps
        that very page when it was a hit, or holds a duplicate it prefilled
        itself when admitted in the same boundary as the canonical).
        Returns the number of newly indexed pages.
        """
        n = 0
        for key, page in zip(self.keys_for(prompt), pages):
            if key in self._by_key or page in self._by_page:
                continue
            self._by_key[key] = page
            self._by_page[page] = key
            n += 1
        return n

    def forget(self, pages: Sequence[int]) -> None:
        """Drop entries for pages released back to the free list."""
        for p in pages:
            key = self._by_page.pop(p, None)
            if key is not None:
                self._by_key.pop(key, None)


@dataclasses.dataclass
class PrefillStep:
    """One chunk of a request's prompt to prefill this boundary
    (DESIGN.md §Chunked prefill): tokens ``[start, start + n_tokens)`` of
    ``req.prompt``, written into the request's own pages (dense mode: its
    slot slab). ``final`` marks the chunk that reaches the end of the
    prompt — it emits the request's first output token and arms the slot
    for decode, exactly like an unchunked admission."""

    slot: int
    req: Request
    start: int
    n_tokens: int
    final: bool


@dataclasses.dataclass
class HandoverStep:
    """One page handover (DESIGN.md §Disaggregated serving): at a request's
    final prefill chunk its slot — and every page mapped to it — moves from
    the prefill role to the decode role. Zero KV copies: the pages already
    live in the shared layer-0 arrays both roles compute against, so the
    engine executes this as one ownership-table flip
    (:meth:`repro.serve.pool.PoolManager.transfer_ownership`) and the
    decode role's next block-table upload carries the row."""

    slot: int
    req: Request
    pages: List[int]


@dataclasses.dataclass
class SpillAction:
    """One preemption: copy ``src_pages`` (layer 0) to ``dst_pages``
    (layer 1) and, for models with resident SSM state, slot row -> seat."""

    slot: int
    req: Request
    src_pages: List[int]
    dst_pages: List[int]
    seat: int


@dataclasses.dataclass
class RestoreAction:
    """The inverse copy: layer-1 ``src_pages`` back into the request's
    freshly allocated layer-0 pages (``req.pages`` prefix), seat -> slot."""

    slot: int
    req: Request
    src_pages: List[int]
    seat: int


@dataclasses.dataclass
class ResumeStep:
    """One layer-2 resume (DESIGN.md §Tiered KV compression & host
    parking): a parked session re-admitted with its KV intact. The engine
    scatters the parked page contents (held host-side since
    ``Engine.park_request``) into the PRIVATE tail of ``req.pages`` —
    logical pages ``req.n_shared..`` — and re-arms the slot vectors; the
    leading ``n_shared`` pages were re-matched through the prefix index
    and map read-only, exactly like a shared admission."""

    slot: int
    req: Request


@dataclasses.dataclass
class PagePlan:
    """Everything one drain boundary decided; the engine executes the device
    copies in EXACTLY this order (spills read layer 0 before any restore or
    admission writes it; restores read layer 1 before later spills could
    reuse freed spill pages — the allocator's alloc-before-free discipline
    inside :meth:`Scheduler.plan_boundary` guarantees id-disjointness)."""

    spills: List[SpillAction] = dataclasses.field(default_factory=list)
    restores: List[RestoreAction] = dataclasses.field(default_factory=list)
    admits: List[Tuple[int, Request]] = dataclasses.field(default_factory=list)
    rejects: List[Request] = dataclasses.field(default_factory=list)
    # chunked prefill only: executed AFTER spills/restores/admit bookkeeping,
    # in list order (residents resume oldest-first before fresh admissions,
    # so a canonical prefix finishes before a same-boundary matcher reads it)
    prefill_steps: List[PrefillStep] = dataclasses.field(default_factory=list)
    # disaggregated serving only: ownership flips for requests whose prompt
    # completes THIS boundary — executed after their final prefill chunk,
    # before the decode role's block-table upload
    handovers: List[HandoverStep] = dataclasses.field(default_factory=list)
    # layer-2 host tier only: parked sessions re-admitted this boundary —
    # executed after restores (their scatters write freshly allocated pages)
    # and before admits (a same-boundary admission may prefix-match pages a
    # resume just repopulated)
    resumes: List[ResumeStep] = dataclasses.field(default_factory=list)


def percentile(xs: Sequence[float], q: float) -> float:
    """Percentile with an empty-list guard — shared by the stream driver
    and serve_bench so their latency columns agree on the edge cases."""
    return float(np.percentile(np.asarray(xs, np.float64), q)) if len(xs) \
        else 0.0


def synthetic_stream(n_requests: int, prompt_len: int, gen_len: int,
                     vocab: int, seed: int = 0) -> List[Dict[str, Any]]:
    """The canonical mixed-length synthetic workload: prompt lengths in
    [prompt_len/2, prompt_len], budgets in [gen_len/2, gen_len]. Shared by
    the stream driver and the serving benchmark so the serve_bench.json
    datapoint measures exactly what ``--stream`` drives."""
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n_requests):
        plen = int(rng.randint(max(1, prompt_len // 2), prompt_len + 1))
        glen = int(rng.randint(max(1, gen_len // 2), gen_len + 1))
        out.append({"prompt": rng.randint(2, vocab,
                                          size=plen).astype(np.int32),
                    "max_new_tokens": glen})
    return out


def shared_prefix_stream(n_requests: int, system_len: int, suffix_len: int,
                         gen_len: int, vocab: int,
                         seed: int = 0) -> List[Dict[str, Any]]:
    """The shared-system-prompt workload: every request is one common
    ``system_len``-token prefix followed by a unique tail of up to
    ``suffix_len`` tokens — the traffic shape prefix sharing is built for
    (shared system prompts, few-shot templates). Shared by the stream
    driver and ``serve_bench --prefix-share`` so the benchmark's
    residency/TTFT datapoints measure exactly what ``--stream`` drives."""
    rng = np.random.RandomState(seed)
    system = rng.randint(2, vocab, size=int(system_len)).astype(np.int32)
    out = []
    for _ in range(n_requests):
        slen = int(rng.randint(max(1, suffix_len // 2), suffix_len + 1))
        glen = int(rng.randint(max(1, gen_len // 2), gen_len + 1))
        tail = rng.randint(2, vocab, size=slen).astype(np.int32)
        out.append({"prompt": np.concatenate([system, tail]),
                    "max_new_tokens": glen})
    return out


def repetitive_stream(n_requests: int, prompt_len: int, gen_len: int,
                      vocab: int, seed: int = 0,
                      motif_len: int = 8) -> List[Dict[str, Any]]:
    """The self-similar workload speculative decoding is built for: each
    prompt tiles a per-request random ``motif_len``-token motif out to its
    length, so the n-gram proposer's prompt lookup keeps finding the
    trailing pattern earlier in the context (templated agent turns, code,
    looping greedy continuations). Shared by the stream driver and
    ``serve_bench --speculate`` so the benchmark's acceptance-rate and
    decode-throughput datapoints measure exactly what ``--stream``
    drives."""
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n_requests):
        plen = int(rng.randint(max(motif_len, prompt_len // 2),
                               prompt_len + 1))
        glen = int(rng.randint(max(1, gen_len // 2), gen_len + 1))
        motif = rng.randint(2, vocab, size=motif_len).astype(np.int32)
        prompt = np.tile(motif, -(-plen // motif_len))[:plen]
        out.append({"prompt": prompt, "max_new_tokens": glen})
    return out


# ---------------------------------------------------------------------------
# Slot table
# ---------------------------------------------------------------------------


class SlotTable:
    """Occupancy of the pooled KV cache: slot index -> resident request id."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._occupant: List[Optional[int]] = [None] * n_slots
        #: how many times each slot has been (re)allocated — reuse evidence
        self.allocations = [0] * n_slots

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._occupant) if r is None]

    def occupant(self, slot: int) -> Optional[int]:
        return self._occupant[slot]

    def allocate(self, rid: int) -> int:
        for i, r in enumerate(self._occupant):
            if r is None:
                self._occupant[i] = rid
                self.allocations[i] += 1
                return i
        raise RuntimeError("no free slot (admission must check free_slots)")

    def release(self, slot: int) -> int:
        rid = self._occupant[slot]
        if rid is None:
            raise RuntimeError(f"slot {slot} already free")
        self._occupant[slot] = None
        return rid

    @property
    def n_occupied(self) -> int:
        return sum(r is not None for r in self._occupant)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


class Scheduler:
    """Admission control between the request queue and the slot table.

    With ``pages`` set, the scheduler also owns the paged two-tier pool's
    host state: the layer-0 and layer-1 :class:`PagePool` free lists, the
    per-request page mappings, and the preempt-and-spill policy
    (:meth:`plan_boundary`). The engine mirrors the mappings into the
    device block-table array and executes the planned copies.

    With ``prefix_share`` additionally set, admission consults a
    :class:`PrefixIndex` of resident full pages: a queued prompt whose
    longest full-page prefix is already cached maps those pages read-only
    (refcounted) and reserves fresh pages only for the unmatched tail —
    the engine then prefills only the suffix (DESIGN.md §Prefix sharing &
    copy-on-write).
    """

    POLICIES = ("fcfs", "shortest")

    def __init__(self, n_slots: int, policy: str = "fcfs",
                 pages: Optional[PageGeometry] = None,
                 prefix_share: bool = False,
                 chunk_prefill_tokens: Optional[int] = None,
                 disaggregate: bool = False):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; have {self.POLICIES}")
        if prefix_share and pages is None:
            raise ValueError("prefix_share requires the paged pool (pages=)")
        if disaggregate and pages is None:
            raise ValueError(
                "disaggregate requires the paged pool (pages=): page "
                "handover moves block-table rows between roles")
        if chunk_prefill_tokens is not None and chunk_prefill_tokens < 1:
            raise ValueError(f"chunk_prefill_tokens must be >= 1, got "
                             f"{chunk_prefill_tokens}")
        self.n_slots = n_slots
        #: per-boundary prefill-token budget; None -> whole-prompt admission
        self.chunk_prefill_tokens = chunk_prefill_tokens
        self.prefill_chunks = 0
        #: prefill tokens each boundary actually planned (admission stall
        #: evidence: unchunked mode books a whole prompt in one entry)
        self.boundary_prefill_tokens: List[int] = []
        self.policy = policy
        self.table = SlotTable(n_slots)
        self.queue: Deque[Request] = collections.deque()
        self.active: Dict[int, Request] = {}      # slot -> request
        self.drained: List[Request] = []
        self._next_rid = 0
        self.admit_order: List[int] = []          # rids in admission order
        self._active_order: List[int] = []        # slots, oldest admit first
        # ---- paged two-tier pool (None -> dense slot-slab mode)
        self.pages = pages
        self.page_pool: Optional[PagePool] = None
        self.spill_pool: Optional[PagePool] = None
        self.seat_pool: Optional[PagePool] = None
        self.preemptions = 0
        self.spilled_pages = 0
        self.restores = 0
        # ---- layer-2 host tier (DESIGN.md §Tiered KV compression & host
        # parking): idle sessions serialized off-device and re-admitted
        self.parks = 0
        self.park_resumes = 0
        #: most sequences concurrently resident in layer 0 at any boundary —
        #: the numerator of the residents-per-byte gate
        self.resident_high_water = 0
        # ---- disaggregated roles (DESIGN.md §Disaggregated serving)
        self.disaggregate = disaggregate
        self.handovers = 0
        self.handover_pages = 0
        # ---- prefix sharing (None -> every admission prefills in full)
        self.prefix_index: Optional[PrefixIndex] = None
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.shared_prefix_tokens = 0   # prompt tokens served from the index
        self.cow_copies = 0
        if prefix_share:
            self.prefix_index = PrefixIndex(pages.page_tokens)
        if pages is not None:
            self.page_pool = PagePool(pages.n_pages, "layer0")
            self.spill_pool = PagePool(pages.n_spill_pages, "layer1")
            # one layer-1 seat per spill page: each spilled request holds at
            # least one page, so seats can never run out before pages do
            self.seat_pool = PagePool(pages.n_spill_pages, "seats")

    @classmethod
    def for_model(cls, cfg: ModelConfig, max_len: int, *,
                  target: Optional[HardwareTarget] = None,
                  policy: str = "fcfs", fraction: float = 0.8,
                  max_slots: int = 64, paged: bool = False,
                  page_tokens: int = 16,
                  layer1_fraction: Optional[float] = None,
                  layer0_bytes: Optional[int] = None,
                  layer1_bytes: Optional[int] = None,
                  prefix_share: bool = False,
                  chunk_prefill_tokens: Optional[int] = None,
                  disaggregate: bool = False,
                  model_shards: int = 1,
                  data_shards: int = 1) -> "Scheduler":
        """Size the slot table (and, when ``paged``, the two-tier page
        pools) from the target's CapacityPartition budget.

        ``chunk_prefill_tokens=0`` derives the per-boundary prefill budget
        from the same target via :func:`derive_prefill_chunk`; a positive
        value pins it; None keeps whole-prompt admission.
        ``model_shards``/``data_shards`` are the mesh axis sizes the engine
        serves under: the budgets scale to the aggregate pool
        (:func:`kv_shards`, :func:`derive_n_slots`) but the scheduler stays
        otherwise mesh-oblivious — block tables, free lists and the prefix
        index are global logical state, identical on every shard."""
        pages = None
        if paged:
            pages = derive_page_geometry(
                cfg, max_len, target=target, fraction=fraction,
                layer1_fraction=layer1_fraction, page_tokens=page_tokens,
                max_slots=max_slots, layer0_bytes=layer0_bytes,
                layer1_bytes=layer1_bytes, model_shards=model_shards)
        if chunk_prefill_tokens == 0:
            chunk_prefill_tokens = derive_prefill_chunk(cfg, target=target)
        return cls(derive_n_slots(cfg, max_len, target=target,
                                  fraction=fraction, max_slots=max_slots,
                                  pages=pages, model_shards=model_shards,
                                  data_shards=data_shards),
                   policy=policy, pages=pages, prefix_share=prefix_share,
                   chunk_prefill_tokens=chunk_prefill_tokens,
                   disaggregate=disaggregate)

    def enable_disaggregation(self) -> None:
        """Switch on role routing after construction (the engine calls this
        when ``EngineConfig(disaggregate=True)`` meets a scheduler built
        without the flag). Must happen before the first boundary is
        planned — a mid-stream flip would leave earlier admissions
        unrouted."""
        if self.pages is None:
            raise ValueError(
                "disaggregate requires the paged pool (pages=): page "
                "handover moves block-table rows between roles")
        if self.admit_order:
            raise RuntimeError(
                "enable_disaggregation() must precede the first admission; "
                "requests already admitted have no role routing")
        self.disaggregate = True

    # ------------------------------------------------------------- queue
    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               submit_step: int = 0) -> Request:
        return self.submit_request(Request(
            rid=0, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=int(max_new_tokens), submit_step=submit_step))

    def submit_request(self, req: Request) -> Request:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1 (a request always emits its "
                f"prefill token), got {req.max_new_tokens}")
        req.rid = self._next_rid
        self._next_rid += 1
        req.status = QUEUED
        self.queue.append(req)
        return req

    def submit_parked(self, prompt: Sequence[int], max_new_tokens: int,
                      tokens: Sequence[int], *,
                      submit_step: int = 0) -> Request:
        """Enqueue a session resumed from the layer-2 host tier
        (DESIGN.md §Tiered KV compression & host parking).

        ``tokens`` are the outputs already emitted before the park, so the
        request's host-side ``cache_len`` mirror lands exactly where the
        parked pool bytes left it. The request enters admission with status
        ``PARKED`` and takes the resume branch of :meth:`plan_boundary`:
        pages are re-allocated (full prompt pages re-matched through the
        prefix index when sharing is on) and the engine scatters the parked
        page contents back — a resume, never a re-prefill."""
        if self.pages is None:
            raise ValueError("park/resume requires the paged pool (pages=)")
        if not tokens:
            raise ValueError("a parked session has emitted at least its "
                             "first token; got an empty token list")
        req = Request(rid=self._next_rid,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=int(max_new_tokens),
                      tokens=list(int(t) for t in tokens),
                      submit_step=submit_step)
        self._next_rid += 1
        req.status = PARKED
        self.queue.append(req)
        return req

    # --------------------------------------------------------- admission
    def _pop_next(self) -> Request:
        if self.policy == "shortest":
            idx = min(range(len(self.queue)),
                      key=lambda i: self.queue[i].prompt_len)
            req = self.queue[idx]
            del self.queue[idx]
            return req
        return self.queue.popleft()               # fcfs

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the queue; returns (slot, request) pairs.

        Called at batch-drain boundaries only — admission never interrupts
        the in-flight decode chunk, it refills slots between chunks
        (DESIGN.md §Serving). Dense slot-slab mode only; paged admission —
        by pages, with optional prefix sharing — goes through
        :meth:`plan_boundary` (DESIGN.md §Paged two-tier pool).
        """
        placed: List[Tuple[int, Request]] = []
        while self.queue and self.table.n_occupied < self.n_slots:
            req = self._pop_next()
            slot = self.table.allocate(req.rid)
            req.status = PREFILLING
            self.active[slot] = req
            self.admit_order.append(req.rid)
            self._active_order.append(slot)
            placed.append((slot, req))
        if self.chunk_prefill_tokens is None:
            self.boundary_prefill_tokens.append(
                sum(r.prompt_len for _, r in placed))
        return placed

    def plan_prefill(self) -> List[PrefillStep]:
        """Dense-mode chunked prefill: spend the per-boundary token budget
        on in-prefill residents, oldest admission first (paged mode plans
        its steps inside :meth:`plan_boundary` instead). Call after
        :meth:`admit` each boundary; a freshly admitted request enters
        in-prefill (``prefill_pos=0``) and takes its first chunk from
        whatever budget remains."""
        budget = self.chunk_prefill_tokens
        assert budget is not None, "plan_prefill needs chunk_prefill_tokens"
        steps: List[PrefillStep] = []
        left = budget
        for slot in list(self._active_order):
            if left <= 0:
                break
            req = self.active[slot]
            if req.prefill_pos < 0 and req.status == PREFILLING:
                req.prefill_pos = 0               # fresh dense admission
            if not 0 <= req.prefill_pos < req.prompt_len:
                continue
            n = min(left, req.prompt_len - req.prefill_pos)
            final = req.prefill_pos + n == req.prompt_len
            steps.append(PrefillStep(slot=slot, req=req,
                                     start=req.prefill_pos, n_tokens=n,
                                     final=final))
            req.prefill_pos += n
            left -= n
            self.prefill_chunks += 1
        self.boundary_prefill_tokens.append(budget - left)
        return steps

    def complete(self, slot: int, status: str = DRAINED) -> Request:
        """Mark the slot's request drained (or rejected) and free the slot
        for reuse. In paged mode this drops one reference on each of the
        request's pages: a private page returns to the free list, a shared
        page stays resident for its other readers, and pages that actually
        released fall out of the prefix index."""
        req = self.active.pop(slot)
        self.table.release(slot)
        self._active_order.remove(slot)
        if self.page_pool is not None and req.pages:
            released = self.page_pool.free(req.pages)
            if self.prefix_index is not None:
                self.prefix_index.forget(released)
            req.pages = []
        req.status = status
        self.drained.append(req)
        return req

    def park(self, slot: int) -> Request:
        """Evict ``slot`` to the layer-2 host tier (DESIGN.md §Tiered KV
        compression & host parking). The caller (the engine) has already
        gathered the session's page bytes into a host-side blob; this
        releases every device resource the slot held. Pages drop one
        reference exactly like :meth:`complete` — a shared page stays
        resident for its other readers, so parking never yanks history out
        from under a live matcher. The returned request is neither drained
        nor queued: it re-enters admission via :meth:`submit_parked` when
        its blob comes back."""
        req = self.active.pop(slot)
        self.table.release(slot)
        self._active_order.remove(slot)
        if self.page_pool is not None and req.pages:
            released = self.page_pool.free(req.pages)
            if self.prefix_index is not None:
                self.prefix_index.forget(released)
            req.pages = []
        req.prefix_len, req.n_shared, req.cow_src = 0, 0, -1
        req.status = PARKED
        self.parks += 1
        return req

    def requeue(self, slot: int) -> Request:
        """Return a mid-prefill resident to the queue from scratch.

        The park path needs a decoded token to resume from, so a request
        caught mid-prefill when the engine idles out cannot park — it
        releases its pages and restarts its prefill on re-admission (it
        has emitted nothing, so nothing is lost but the partial prompt
        work). Queued at the FRONT: it was admitted once already."""
        req = self.active.pop(slot)
        self.table.release(slot)
        self._active_order.remove(slot)
        if self.page_pool is not None and req.pages:
            released = self.page_pool.free(req.pages)
            if self.prefix_index is not None:
                self.prefix_index.forget(released)
            req.pages = []
        req.prefill_pos = -1
        req.prefix_len, req.n_shared, req.cow_src = 0, 0, -1
        req.status = QUEUED
        self.queue.appendleft(req)
        return req

    # --------------------------------------------------- paged admission
    def _admissible_index(self) -> int:
        """Queue index the policy would admit next. Preempted requests are
        restored first (they hold layer-1 resources), in queue order."""
        for i, req in enumerate(self.queue):
            if req.status == PREEMPTED:
                return i
        if self.policy == "shortest":
            return min(range(len(self.queue)),
                       key=lambda i: self.queue[i].prompt_len)
        return 0

    def _preempt(self, slot: int) -> SpillAction:
        """Spill ``slot`` to layer 1. Allocates the layer-1 resources FIRST
        so a spill-tier-exhausted failure leaves the scheduler untouched."""
        req = self.active[slot]
        dst = self.spill_pool.alloc(len(req.pages))
        seat = self.seat_pool.alloc(1)
        if dst is None or seat is None:
            if dst is not None:
                self.spill_pool.free(dst)
            if seat is not None:
                self.seat_pool.free(seat)
            raise RuntimeError(
                f"layer-1 spill tier exhausted ({self.spill_pool.in_use}/"
                f"{self.pages.n_spill_data_pages} pages in use) — raise "
                f"layer1_fraction / layer1_bytes")
        self.active.pop(slot)
        self.table.release(slot)
        self._active_order.remove(slot)
        src = req.pages
        # dereference: private pages release (and leave the prefix index);
        # a shared page stays resident for its other readers — the layer-1
        # copy below still reads it, since nobody writes shared pages
        released = self.page_pool.free(src)
        if self.prefix_index is not None:
            self.prefix_index.forget(released)
        req.pages = []
        req.spill_pages = dst
        req.spill_seat = seat[0]
        req.status = PREEMPTED
        req.preemptions += 1
        self.preemptions += 1
        self.spilled_pages += len(src)
        self.queue.appendleft(req)        # restored before fresh admissions
        return SpillAction(slot=slot, req=req, src_pages=src, dst_pages=dst,
                           seat=req.spill_seat)

    def plan_boundary(self, *, chunk_tokens: int, max_len: int) -> PagePlan:
        """Paged-mode drain-boundary plan: grow, preempt, restore, admit.

        1. **Growth** (oldest resident first): every active slot gets pages
           covering its next ``chunk_tokens`` of decode. If layer 0 is
           exhausted, the YOUNGEST resident is preempted and its pages
           spill to layer 1 — repeatedly, until the grow fits. When the
           grower is itself the youngest, IT spills rather than evicting
           an older sequence (oldest-first growth always wins), and its
           restore reallocates the full need — so an older resident is
           never sacrificed for a younger one, and every boundary makes
           progress on the oldest resident.
        2. **Restores + admissions** (policy order, preempted first): a
           restore reallocates layer-0 pages and schedules the copy back; a
           fresh admission reserves pages for ``prompt + chunk`` only — the
           whole point of paging: no worst-case ``max_len`` slab. With
           prefix sharing, the admission first matches the longest indexed
           full-page prefix (:meth:`_match_prefix`) and allocates fresh
           pages only for the unmatched tail. Admission stops at the first
           request that does not fit (no queue-jumping beyond the policy's
           pick). Admission never preempts; only growth of already-resident
           sequences does.

        With ``chunk_prefill_tokens`` set (DESIGN.md §Chunked prefill), a
        phase runs between growth and restores/admissions: the per-boundary
        prefill-token budget is spent on in-prefill residents oldest-first
        (:meth:`_plan_prefill_chunk` — page growth with the same
        youngest-first preemption), and fresh admissions reserve pages for
        their FIRST chunk only, taking it from whatever budget remains.
        Prefix-index registration is deferred to the final chunk.

        Ordering contract with the engine (DESIGN.md §Paged two-tier pool):
        spills are planned before restores/admissions so their device
        copies read layer-0 pages before anything reuses them; restored
        spill pages are freed only after this boundary's spills allocated
        theirs, keeping read and write page ids disjoint. Prefill chunks
        execute after all copies, in plan order.
        """
        assert self.pages is not None, "plan_boundary is paged-mode only"
        geom = self.pages
        plan = PagePlan()
        budget = self.chunk_prefill_tokens
        left = budget if budget is not None else 0
        for slot in list(self._active_order):
            if slot not in self.active:
                continue                 # preempted earlier this boundary
            req = self.active[slot]
            if 0 <= req.prefill_pos < req.prompt_len:
                continue                 # mid-prefill: grown by its chunk
            target_tokens = min(req.cache_len + chunk_tokens, max_len)
            while True:
                need = geom.pages_for(target_tokens) - len(req.pages)
                if need <= 0:
                    break
                got = self.page_pool.alloc(need)
                if got is not None:
                    req.pages.extend(got)
                    break
                if self._active_order[-1] != slot:
                    # victim: the most recently (re)admitted resident —
                    # always strictly younger than the grower here
                    plan.spills.append(self._preempt(self._active_order[-1]))
                    continue
                # the grower IS the youngest: spill it instead of evicting
                # an older sequence; its restore reallocates the full need
                plan.spills.append(self._preempt(slot))
                break
        # ---- resume in-prefill residents (oldest first) under the budget;
        # planned BEFORE restores/admissions so any preemption their page
        # growth forces still precedes every layer-1 free of this boundary
        # (the id-disjointness contract), and so a canonical prefix always
        # finishes before a same-boundary matcher's suffix chunk reads it.
        if budget is not None:
            for slot in list(self._active_order):
                if left <= 0:
                    break
                if slot not in self.active:
                    continue
                req = self.active[slot]
                if not 0 <= req.prefill_pos < req.prompt_len:
                    continue
                left = self._plan_prefill_chunk(plan, slot, req, left,
                                                chunk_tokens, max_len)
        while self.queue and self.table.free_slots():
            idx = self._admissible_index()
            req = self.queue[idx]
            if req.status == PREEMPTED:
                need = max(geom.pages_for(
                    min(req.cache_len + chunk_tokens, max_len)),
                    len(req.spill_pages))
                got = self.page_pool.alloc(need)
                if got is None:
                    break
                del self.queue[idx]
                slot = self.table.allocate(req.rid)
                src, seat = req.spill_pages, req.spill_seat
                req.pages, req.spill_pages, req.spill_seat = got, [], -1
                # a request preempted mid-chunked-prefill resumes its
                # cursor at the NEXT boundary (this one's chunk budget was
                # committed before the restore was planned)
                req.status = (PREFILLING
                              if 0 <= req.prefill_pos < req.prompt_len
                              else DECODING)
                self.active[slot] = req
                self.admit_order.append(req.rid)
                self._active_order.append(slot)
                self.restores += 1
                plan.restores.append(RestoreAction(slot=slot, req=req,
                                                   src_pages=src, seat=seat))
                # freed only now — after this boundary's spills allocated
                # theirs, so restore-read and spill-write ids are disjoint
                self.spill_pool.free(src)
                self.seat_pool.free([seat])
                continue
            if req.status == PARKED:
                # layer-2 resume (DESIGN.md §Tiered KV compression & host
                # parking): the session's bytes live in a host blob, so
                # admission only re-maps layer-0 page ids — the engine
                # scatters the parked contents back; never a re-prefill.
                # Prefix re-match covers FULL prompt pages only: a resumed
                # session's write frontier is past its prompt, so matched
                # pages are history it merely reads, but a mid-page match
                # would need the COW copy the resume scatter path
                # deliberately avoids.
                shared = []
                if self.prefix_index is not None:
                    matched = self.prefix_index.match(req.prompt)
                    full = min(len(matched),
                               (req.prompt_len - 1) // geom.page_tokens)
                    shared = matched[:full]
                need = max(geom.pages_for(
                    min(req.cache_len + chunk_tokens, max_len)),
                    geom.pages_for(req.cache_len))
                got = self.page_pool.alloc(need - len(shared))
                if got is None:
                    break
                if shared:
                    self.page_pool.share(shared)
                del self.queue[idx]
                slot = self.table.allocate(req.rid)
                req.pages = shared + got
                req.prefix_len = len(shared) * geom.page_tokens
                req.n_shared, req.cow_src = len(shared), -1
                if self.prefix_index is not None:
                    if req.prefix_len:
                        self.prefix_hits += 1
                        self.shared_prefix_tokens += req.prefix_len
                    else:
                        self.prefix_misses += 1
                    # register at plan time: the engine executes resumes
                    # before this boundary's admissions prefill anything,
                    # so a same-boundary matcher reads settled bytes
                    self.prefix_index.register(req.prompt, req.pages)
                req.status = DECODING
                if self.disaggregate:
                    req.owner = DECODE_ROLE
                self.active[slot] = req
                self.admit_order.append(req.rid)
                self._active_order.append(slot)
                self.park_resumes += 1
                plan.resumes.append(ResumeStep(slot=slot, req=req))
                continue
            if req.prompt_len > max_len:
                del self.queue[idx]
                req.status = REJECTED
                self.drained.append(req)
                plan.rejects.append(req)
                continue
            if budget is not None and left <= 0:
                break                     # no budget to start its first chunk
            shared, prefix_len, cow_src = self._match_prefix(req)
            if budget is not None:
                first_end = prefix_len + min(left,
                                             req.prompt_len - prefix_len)
                cover = (min(first_end + chunk_tokens, max_len)
                         if first_end == req.prompt_len else first_end)
                need = geom.pages_for(cover)
            else:
                need = geom.pages_for(
                    min(req.prompt_len + chunk_tokens, max_len))
            got = self.page_pool.alloc(need - len(shared))
            if got is None:
                break
            if shared:
                self.page_pool.share(shared)
            del self.queue[idx]
            slot = self.table.allocate(req.rid)
            req.pages = shared + got
            req.prefix_len, req.n_shared, req.cow_src = (prefix_len,
                                                         len(shared), cow_src)
            if self.prefix_index is not None:
                if prefix_len:
                    self.prefix_hits += 1
                    self.shared_prefix_tokens += prefix_len
                    self.cow_copies += cow_src >= 0
                else:
                    self.prefix_misses += 1
                if budget is None:
                    self.prefix_index.register(req.prompt, req.pages)
            req.status = PREFILLING
            if self.disaggregate:
                req.owner = PREFILL_ROLE
            self.active[slot] = req
            self.admit_order.append(req.rid)
            self._active_order.append(slot)
            plan.admits.append((slot, req))
            if budget is not None:
                # first chunk rides this boundary's remaining budget; the
                # pages above already cover it, so this never preempts
                req.prefill_pos = prefix_len
                left = self._plan_prefill_chunk(plan, slot, req, left,
                                                chunk_tokens, max_len)
            elif self.disaggregate:
                # unchunked admission prefills the whole prompt this
                # boundary, so the handover follows immediately
                self._plan_handover(plan, slot, req)
        if budget is not None:
            self.boundary_prefill_tokens.append(budget - left)
        else:
            self.boundary_prefill_tokens.append(sum(
                r.prompt_len - r.prefix_len for _, r in plan.admits))
        self.resident_high_water = max(self.resident_high_water,
                                       len(self.active))
        return plan

    def _plan_prefill_chunk(self, plan: PagePlan, slot: int, req: Request,
                            left: int, chunk_tokens: int,
                            max_len: int) -> int:
        """Plan one prompt chunk for an in-prefill resident: grow its pages
        to cover the chunk (a final chunk also covers the next decode
        chunk), preempting youngest-first exactly like decode growth, then
        append the :class:`PrefillStep` and advance the cursor. Returns
        the remaining token budget. A resident that had to spill ITSELF
        (it was the youngest) consumes no budget; its cursor survives the
        preemption and resumes a boundary after its restore."""
        geom = self.pages
        n = min(left, req.prompt_len - req.prefill_pos)
        end = req.prefill_pos + n
        final = end == req.prompt_len
        cover = min(end + chunk_tokens, max_len) if final else end
        while True:
            need = geom.pages_for(cover) - len(req.pages)
            if need <= 0:
                break
            got = self.page_pool.alloc(need)
            if got is not None:
                req.pages.extend(got)
                break
            if self._active_order[-1] != slot:
                plan.spills.append(self._preempt(self._active_order[-1]))
                continue
            plan.spills.append(self._preempt(slot))
            return left
        plan.prefill_steps.append(PrefillStep(
            slot=slot, req=req, start=req.prefill_pos, n_tokens=n,
            final=final))
        req.prefill_pos = end
        self.prefill_chunks += 1
        if final and self.prefix_index is not None:
            # deferred from admission: a chunked request's pages hold real
            # content only once the last chunk lands — registering earlier
            # could hand a concurrent admission pages still being filled
            self.prefix_index.register(req.prompt, req.pages)
        if final and self.disaggregate:
            self._plan_handover(plan, slot, req)
        return left - n

    def _plan_handover(self, plan: PagePlan, slot: int,
                       req: Request) -> None:
        """Route a prompt-complete request to the decode role: emit the
        :class:`HandoverStep` the engine executes as a zero-copy ownership
        flip. Safe within one plan: preemption picks the YOUNGEST resident,
        so a spill planned after this (by a later chunk or admission) can
        never hit an older, already-handed-over slot before the engine
        executes both — and if THIS slot spills in a later boundary, its
        restore re-enters straight into the decode role (owner survives
        preemption)."""
        req.owner = DECODE_ROLE
        self.handovers += 1
        self.handover_pages += len(req.pages)
        plan.handovers.append(HandoverStep(slot=slot, req=req,
                                           pages=list(req.pages)))

    def _match_prefix(self, req: Request) -> Tuple[List[int], int, int]:
        """Prefix-index lookup for a fresh admission.

        Returns ``(shared_pages, prefix_len, cow_src)``: the physical pages
        to map read-only at logical indices ``0..len(shared)-1``, how many
        prompt tokens they cover, and — when the match ends mid-page — the
        source page the engine COW-copies the frontier page from (else -1).

        The match is capped at ``prompt_len - 1`` tokens: at least one
        prompt token is always prefilled (the request's first output token
        is the argmax at the last prompt position). When the cap bites (a
        page-aligned prompt fully covered by the index), the final matched
        page would hold the write frontier — it is NEVER shared; the engine
        copies it into a fresh private page instead (the copy-on-write rule:
        decode writes must not mutate another request's history)."""
        if self.prefix_index is None:
            return [], 0, -1
        matched = self.prefix_index.match(req.prompt)
        pt = self.pages.page_tokens
        prefix_len = min(len(matched) * pt, req.prompt_len - 1)
        full = prefix_len // pt
        cow_src = matched[full] if prefix_len % pt else -1
        return matched[:full], prefix_len, cow_src

    def block_table(self, role: Optional[str] = None) -> np.ndarray:
        """The (n_slots, max_pages_per_slot) int32 block table implied by
        the current page mappings; unmapped entries point at null page 0.

        With ``role`` set (disaggregated serving), only slots OWNED by that
        role get rows — everything else maps to the null page. The decode
        role's view therefore routes done-masked junk writes for
        mid-prefill slots into page 0 instead of their real pages, which is
        safe by construction: positions at or past a prefill cursor are
        never read, and the next prefill chunk's whole-page scatter
        rewrites the frontier page anyway. Handover is exactly the moment a
        slot's row appears in the decode view."""
        assert self.pages is not None
        bt = np.zeros((self.n_slots, self.pages.max_pages_per_slot), np.int32)
        for slot, req in self.active.items():
            if role is not None and req.owner != role:
                continue
            bt[slot, :len(req.pages)] = req.pages
        return bt

    # ------------------------------------------------------------- state
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    def stats(self) -> Dict[str, Any]:
        allocs = self.table.allocations
        done = [r for r in self.drained if r.status == DRAINED]
        out = {
            "n_slots": self.n_slots,
            "policy": self.policy,
            "queued": len(self.queue),
            "active": len(self.active),
            "drained": len(done),
            "rejected": sum(r.status == REJECTED for r in self.drained),
            "slot_allocations": list(allocs),
            "max_slot_reuse": max(allocs) if allocs else 0,
            # per-request latency, in decode-step clock units: time to first
            # token (admission wait) and end-to-end (submit -> drain).
            # ttft_emit_steps counts to the FIRST OUTPUT TOKEN — under
            # chunked prefill that is the final chunk's boundary, later
            # than the admission the slot-wait ttft_steps measures.
            "ttft_steps": [r.admit_step - r.submit_step for r in done],
            "ttft_emit_steps": [
                (r.first_step if r.first_step >= 0 else r.admit_step)
                - r.submit_step for r in done],
            "e2e_steps": [r.finish_step - r.submit_step for r in done
                          if r.finish_step >= 0],
            "preemptions": self.preemptions,
            "spilled_pages": self.spilled_pages,
            "restores": self.restores,
            # chunked prefill (DESIGN.md §Chunked prefill)
            "chunk_prefill_tokens": self.chunk_prefill_tokens,
            "prefill_chunks": self.prefill_chunks,
            "max_boundary_prefill_tokens": (
                max(self.boundary_prefill_tokens)
                if self.boundary_prefill_tokens else 0),
        }
        if self.pages is not None:
            geom = self.pages
            out.update({
                "paged": True,
                "page_tokens": geom.page_tokens,
                "n_pages": geom.n_data_pages,
                "n_spill_pages": geom.n_spill_data_pages,
                "pages_in_use": self.page_pool.in_use,
                "pages_high_water": self.page_pool.high_water,
                "spill_pages_in_use": self.spill_pool.in_use,
                "spill_high_water": self.spill_pool.high_water,
                "pool_bytes": geom.layer0_bytes,
                "spill_bytes": geom.layer1_bytes,
                # prefix sharing: logical mappings vs physical pages — the
                # ratio is the concurrent-residency win per layer-0 byte
                "prefix_sharing": self.prefix_index is not None,
                "prefix_hits": self.prefix_hits,
                "prefix_misses": self.prefix_misses,
                "shared_prefix_tokens": self.shared_prefix_tokens,
                "cow_copies": self.cow_copies,
                "mapped_pages": self.page_pool.mapped,
                "mapped_high_water": self.page_pool.mapped_high_water,
                "indexed_pages": (len(self.prefix_index)
                                  if self.prefix_index is not None else 0),
                # disaggregated roles (DESIGN.md §Disaggregated serving):
                # always reported so dashboards need no key probing — both
                # stay 0 in combined mode
                "disaggregate": self.disaggregate,
                "handovers": self.handovers,
                "handover_pages": self.handover_pages,
                # tiered codecs + layer-2 host tier (DESIGN.md §Tiered KV
                # compression & host parking)
                "layer0_codec": geom.layer0_codec,
                "layer1_codec": geom.layer1_codec,
                "parks": self.parks,
                "park_resumes": self.park_resumes,
                "resident_high_water": self.resident_high_water,
            })
        else:
            out["paged"] = False
        return out
