"""Pool ownership for the serving engine: construction, tier copies, and
the page-handover primitive (DESIGN.md §Disaggregated serving).

The KV pool is ONE device-side address space — MemPool-3D's premise,
applied to serving: whatever engine role computes against it, the pages
live in the same flat layer-0/layer-1 arrays. This module owns everything
about that pool that is not a model forward:

  * :class:`PoolState` — the device arrays (moved here from
    ``serve/engine.py``; the engine re-exports it for compatibility).
  * :class:`PoolManager` — constructs empty pools (:meth:`init_pool` /
    :meth:`init_paged_pool`), executes the layer-0 <-> layer-1 tier
    copies planned by the scheduler (:meth:`exec_spill` /
    :meth:`exec_restore`), and tracks which engine *role* owns each
    slot when serving runs disaggregated.
  * :meth:`PoolManager.transfer_ownership` — the handover primitive. At
    a request's final prefill chunk, its slot moves from the prefill
    role to the decode role by flipping ONE host-side table entry: the
    slot's block-table row starts appearing in the decode role's
    uploaded table, and the prefill role stops issuing work for it. No
    KV bytes move — the pages were always in the shared pool; only the
    table row and cursor change hands (the invariant the equivalence
    matrix pins: a page row moves, bytes never copy).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import scheduler as sched_mod

#: Engine role names (DESIGN.md §Disaggregated serving). The prefill role
#: runs admissions and prompt chunks; the decode role runs the batched
#: decode/verify forwards. A combined engine is both at once. Canonical
#: definitions live in the scheduler (routing is a scheduling decision).
PREFILL_ROLE = sched_mod.PREFILL_ROLE
DECODE_ROLE = sched_mod.DECODE_ROLE


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PoolState:
    """Device-side state of the KV slot pool (batch axis = slot index).

    ``block_tables`` is ``None`` for the dense slot-slab pool; in paged
    mode it is the ``(S, P)`` int32 map from each slot's logical page index
    to a physical page of the flat layer-0 page pool (null page 0 for
    unmapped entries). The host rebuilds and uploads it at every drain
    boundary from the scheduler's page mappings.
    """

    state: Dict[str, Any]       # model caches (+aux), slot- or page-major
    tok: jax.Array              # (S,) int32 — last emitted token per slot
    cache_len: jax.Array        # (S,) int32 — filled KV prefix per slot
    done: jax.Array             # (S,) bool — drained/empty slot mask
    n_gen: jax.Array            # (S,) int32 — tokens emitted per occupant
    budget: jax.Array           # (S,) int32 — occupant's max_new_tokens
    block_tables: Optional[jax.Array] = None    # (S, P) int32, paged only


class PoolManager:
    """Owns PoolState construction, tier copies, and slot ownership.

    Exactly ONE PoolManager backs an engine, shared by its prefill and
    decode roles — the pool is a single address space (the paper's shared
    L1), the roles are just who computes against it. ``place`` is the
    engine core's mesh-placement function so pools land on the same
    shardings as every jitted fn's output.
    """

    def __init__(self, model: Any, ecfg: Any,
                 place: Callable[[Any], Any]):
        self.model = model
        self.ecfg = ecfg
        self._place = place
        self._tier_copy = None      # jitted layer-0 <-> layer-1 copy
        # ---- disaggregated slot ownership (role name per occupied slot).
        # Empty in combined mode: a single engine owns everything and the
        # bookkeeping would only add per-boundary host work.
        self.owner: Dict[int, str] = {}
        self.handovers = 0
        self.handover_pages = 0

    # ------------------------------------------------------- construction
    def init_pool(self, n_slots: int) -> PoolState:
        """Empty slot pool: all slots done (free), caches zeroed."""
        cfg = self.model.cfg
        if cfg.family == "encdec":
            raise NotImplementedError(
                "pooled serving targets decoder-only families; encdec "
                "requests go through one-shot generate()")
        if cfg.frontend_len:
            raise NotImplementedError(
                "pooled serving takes token prompts; frontend-embed "
                "requests go through one-shot generate()")
        from repro.models import transformer
        state = {"caches": transformer.init_caches(cfg, n_slots,
                                                   self.ecfg.max_len)}
        zeros = jnp.zeros((n_slots,), jnp.int32)
        return self._place(PoolState(
            state=state,
            tok=jnp.full((n_slots,), self.ecfg.pad_token, jnp.int32),
            cache_len=zeros,
            done=jnp.ones((n_slots,), bool),
            n_gen=zeros, budget=zeros))

    def init_paged_pool(self, sch: sched_mod.Scheduler
                        ) -> Tuple[PoolState, Dict[str, Any]]:
        """Empty paged pool + the layer-1 spill tier's device arrays.

        Layer 0 is a flat page pool shared by all slots (block tables map
        slots to pages); layer 1 mirrors it at the spill budget, plus one
        resident "seat" per spill page for recurrent SSM state (a spilled
        sequence holds at least one page, so seats cannot run out first).
        """
        geom = sch.pages
        assert geom is not None, "init_paged_pool needs a paged scheduler"
        cfg = self.model.cfg
        if cfg.family == "encdec" or cfg.frontend_len:
            raise NotImplementedError(
                "paged serving targets decoder-only token-prompt models; "
                "others go through one-shot generate()")
        from repro.models import transformer
        n_slots = sch.n_slots
        state = {"caches": transformer.init_paged_caches(
            cfg, n_slots, geom.n_pages, geom.page_tokens)}
        spill = transformer.init_paged_caches(
            cfg, geom.n_spill_pages, geom.n_spill_pages, geom.page_tokens)
        zeros = jnp.zeros((n_slots,), jnp.int32)
        pool = PoolState(
            state=state,
            tok=jnp.full((n_slots,), self.ecfg.pad_token, jnp.int32),
            cache_len=zeros, done=jnp.ones((n_slots,), bool),
            n_gen=zeros, budget=zeros,
            block_tables=jnp.zeros((n_slots, geom.max_pages_per_slot),
                                   jnp.int32))
        return self._place(pool), self._place(spill)

    # -------------------------------------------------------- tier copies
    def tier_copy_fn(self):
        """ONE jitted layer-0 <-> layer-1 copy, shared by spill and restore
        (jit's shape-keyed cache traces each direction independently).

        Page pools move whole pages (gather by source ids, scatter at
        destination ids — padded entries route through the null pages);
        recurrent per-slot state moves one row between the slot axis and
        the spill seat axis. Everything stays on device.
        """
        if self._tier_copy is not None:
            return self._tier_copy
        from repro.models import transformer
        cfg = self.model.cfg

        def copy(src_caches, dst_caches, row_src, row_dst, pages_src,
                 pages_dst):
            def page_copy(s, d):
                return d.at[:, pages_dst].set(s[:, pages_src].astype(d.dtype))

            def row_copy(s, d):
                row = jax.lax.dynamic_slice_in_dim(s, row_src, 1, axis=1)
                return jax.lax.dynamic_update_slice_in_dim(
                    d, row.astype(d.dtype), row_dst, axis=1)

            out: Dict[str, Any] = {}
            for gname, key, is_paged in transformer.paged_cache_kinds(cfg):
                fn = page_copy if is_paged else row_copy
                out.setdefault(gname, {})[key] = jax.tree.map(
                    fn, src_caches[gname][key], dst_caches[gname][key])
            return out

        self._tier_copy = jax.jit(copy)
        return self._tier_copy

    @staticmethod
    def pad_pages(pages, p_max: int) -> jax.Array:
        row = np.zeros((p_max,), np.int32)
        row[:len(pages)] = pages
        return jnp.asarray(row)

    def exec_spill(self, pool: PoolState, spill: Dict[str, Any],
                   act: sched_mod.SpillAction, p_max: int) -> Dict[str, Any]:
        self.owner.pop(act.slot, None)      # preempted: the slot frees
        return self.tier_copy_fn()(
            pool.state["caches"], spill,
            jnp.asarray(act.slot, jnp.int32),
            jnp.asarray(act.seat, jnp.int32),
            self.pad_pages(act.src_pages, p_max),
            self.pad_pages(act.dst_pages, p_max))

    def exec_restore(self, pool: PoolState, spill: Dict[str, Any],
                     act: sched_mod.RestoreAction, p_max: int) -> PoolState:
        """Copy a preempted sequence back into layer 0 and re-arm its slot.

        The per-slot vectors are rebuilt from the host mirror: the KV
        frontier is one behind the emitted count (the last token's K/V is
        written by its own upcoming decode step), so decode resumes
        bit-exactly where preemption cut it."""
        req = act.req
        caches = self.tier_copy_fn()(
            spill, pool.state["caches"],
            jnp.asarray(act.seat, jnp.int32),
            jnp.asarray(act.slot, jnp.int32),
            self.pad_pages(act.src_pages, p_max),
            self.pad_pages(req.pages[:len(act.src_pages)], p_max))
        slot = act.slot
        if req.status == sched_mod.PREFILLING:
            # restored mid-chunked-prefill: no output token exists yet, so
            # only the KV frontier is re-armed; done is FORCED True (the
            # slot may have been freed by a mid-decode preemption, leaving
            # done=False on device) so the slot stays masked until its
            # final chunk lands, and the cursor resumes at the NEXT
            # boundary's prefill phase (plan order contract)
            return dataclasses.replace(
                pool, state={**pool.state, "caches": caches},
                cache_len=pool.cache_len.at[slot].set(req.cache_len),
                done=pool.done.at[slot].set(True))
        return dataclasses.replace(
            pool, state={**pool.state, "caches": caches},
            tok=pool.tok.at[slot].set(int(req.tokens[-1])),
            cache_len=pool.cache_len.at[slot].set(req.cache_len),
            done=pool.done.at[slot].set(False),
            n_gen=pool.n_gen.at[slot].set(len(req.tokens)),
            budget=pool.budget.at[slot].set(req.max_new_tokens))

    # ---------------------------------------------------------- ownership
    def claim(self, slot: int, role: str) -> None:
        """Record which role a slot's work is issued by (disaggregated
        serving only; combined engines never populate the map)."""
        self.owner[slot] = role

    def release(self, slot: int) -> None:
        self.owner.pop(slot, None)

    def transfer_ownership(self, slot: int, pages: List[int], *,
                           src: str = PREFILL_ROLE,
                           dst: str = DECODE_ROLE) -> None:
        """Hand a slot (and its mapped pages) from ``src`` to ``dst``.

        This is pure bookkeeping — the zero-copy invariant of the shared
        pool: the slot's pages already live in the layer-0 arrays both
        roles compute against, so handover flips one table entry and the
        decode role's next block-table upload carries the row. Raises if
        ``src`` does not own the slot (a handover for a slot the prefill
        role lost to preemption would silently corrupt routing).
        """
        cur = self.owner.get(slot)
        if cur != src:
            raise RuntimeError(
                f"handover of slot {slot}: owned by {cur!r}, expected "
                f"{src!r} — page handover must follow the final prefill "
                f"chunk of the owning role")
        self.owner[slot] = dst
        self.handovers += 1
        self.handover_pages += len(pages)
