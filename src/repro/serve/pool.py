"""Pool ownership for the serving engine: construction, tier copies, and
the page-handover primitive (DESIGN.md §Disaggregated serving).

The KV pool is ONE device-side address space — MemPool-3D's premise,
applied to serving: whatever engine role computes against it, the pages
live in the same flat layer-0/layer-1 arrays. This module owns everything
about that pool that is not a model forward:

  * :class:`PoolState` — the device arrays (moved here from
    ``serve/engine.py``; the engine re-exports it for compatibility).
  * :class:`PoolManager` — constructs empty pools (:meth:`init_pool` /
    :meth:`init_paged_pool`), executes the layer-0 <-> layer-1 tier
    copies planned by the scheduler (:meth:`exec_spill` /
    :meth:`exec_restore`), and tracks which engine *role* owns each
    slot when serving runs disaggregated.
  * :meth:`PoolManager.transfer_ownership` — the handover primitive. At
    a request's final prefill chunk, its slot moves from the prefill
    role to the decode role by flipping ONE host-side table entry: the
    slot's block-table row starts appearing in the decode role's
    uploaded table, and the prefill role stops issuing work for it. No
    KV bytes move — the pages were always in the shared pool; only the
    table row and cursor change hands (the invariant the equivalence
    matrix pins: a page row moves, bytes never copy).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import scheduler as sched_mod

#: Engine role names (DESIGN.md §Disaggregated serving). The prefill role
#: runs admissions and prompt chunks; the decode role runs the batched
#: decode/verify forwards. A combined engine is both at once. Canonical
#: definitions live in the scheduler (routing is a scheduling decision).
PREFILL_ROLE = sched_mod.PREFILL_ROLE
DECODE_ROLE = sched_mod.DECODE_ROLE


# ------------------------------------------------------------- tier codecs


@dataclasses.dataclass(frozen=True)
class TierCodec:
    """How one pool tier stores KV bytes (DESIGN.md §Tiered KV compression).

    ``scaled`` codecs (int8) carry one f32 scale per page per leaf in a
    sibling ``<leaf>_scale`` array; unscaled codecs are a plain dtype cast
    (fp8-e4m3) or the identity (fp16 — bf16 storage, bit-exact by
    construction, the reference every quantized tier is gated against).
    """

    name: str
    dtype: Any
    bytes_per_value: int
    scaled: bool


CODECS: Dict[str, TierCodec] = {
    "fp16": TierCodec("fp16", jnp.bfloat16, 2, False),
    "fp8": TierCodec("fp8", jnp.float8_e4m3fn, 1, False),
    "int8": TierCodec("int8", jnp.int8, 1, True),
}


def quant_policy(kv_quant: Optional[str]) -> Tuple[str, str]:
    """Map a ``--kv-quant`` knob to ``(layer0_codec, layer1_codec)``.

    The spill tier quantizes at least as hard as layer 0 — layer-1
    bandwidth is cheap (pages move once per preemption), capacity is not —
    so ``fp8`` spills as int8 while ``int8`` is already at the floor.
    """
    if kv_quant in (None, "none", "fp16"):
        return ("fp16", "fp16")
    if kv_quant == "fp8":
        return ("fp8", "int8")
    if kv_quant == "int8":
        return ("int8", "int8")
    raise ValueError(f"unknown kv quant codec {kv_quant!r} "
                     f"(choices: {', '.join(sorted(CODECS))})")


def _has_recurrent_state(cfg) -> bool:
    return any(kind.attn == "mamba"
               for group in cfg.layer_groups() for kind in group.pattern)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PoolState:
    """Device-side state of the KV slot pool (batch axis = slot index).

    ``block_tables`` is ``None`` for the dense slot-slab pool; in paged
    mode it is the ``(S, P)`` int32 map from each slot's logical page index
    to a physical page of the flat layer-0 page pool (null page 0 for
    unmapped entries). The host rebuilds and uploads it at every drain
    boundary from the scheduler's page mappings.
    """

    state: Dict[str, Any]       # model caches (+aux), slot- or page-major
    tok: jax.Array              # (S,) int32 — last emitted token per slot
    cache_len: jax.Array        # (S,) int32 — filled KV prefix per slot
    done: jax.Array             # (S,) bool — drained/empty slot mask
    n_gen: jax.Array            # (S,) int32 — tokens emitted per occupant
    budget: jax.Array           # (S,) int32 — occupant's max_new_tokens
    block_tables: Optional[jax.Array] = None    # (S, P) int32, paged only


class PoolManager:
    """Owns PoolState construction, tier copies, and slot ownership.

    Exactly ONE PoolManager backs an engine, shared by its prefill and
    decode roles — the pool is a single address space (the paper's shared
    L1), the roles are just who computes against it. ``place`` is the
    engine core's mesh-placement function so pools land on the same
    shardings as every jitted fn's output.
    """

    def __init__(self, model: Any, ecfg: Any,
                 place: Callable[[Any], Any]):
        self.model = model
        self.ecfg = ecfg
        self._place = place
        self._tier_copy: Dict[Tuple[str, str], Any] = {}   # jitted tier copies
        self._geom = None           # PageGeometry after init_paged_pool
        # ---- disaggregated slot ownership (role name per occupied slot).
        # Empty in combined mode: a single engine owns everything and the
        # bookkeeping would only add per-boundary host work.
        self.owner: Dict[int, str] = {}
        self.handovers = 0
        self.handover_pages = 0

    # ------------------------------------------------------- construction
    def init_pool(self, n_slots: int) -> PoolState:
        """Empty slot pool: all slots done (free), caches zeroed."""
        cfg = self.model.cfg
        if cfg.family == "encdec":
            raise NotImplementedError(
                "pooled serving targets decoder-only families; encdec "
                "requests go through one-shot generate()")
        if cfg.frontend_len:
            raise NotImplementedError(
                "pooled serving takes token prompts; frontend-embed "
                "requests go through one-shot generate()")
        from repro.models import transformer
        state = {"caches": transformer.init_caches(cfg, n_slots,
                                                   self.ecfg.max_len)}
        zeros = jnp.zeros((n_slots,), jnp.int32)
        return self._place(PoolState(
            state=state,
            tok=jnp.full((n_slots,), self.ecfg.pad_token, jnp.int32),
            cache_len=zeros,
            done=jnp.ones((n_slots,), bool),
            n_gen=zeros, budget=zeros))

    def init_paged_pool(self, sch: sched_mod.Scheduler
                        ) -> Tuple[PoolState, Dict[str, Any]]:
        """Empty paged pool + the layer-1 spill tier's device arrays.

        Layer 0 is a flat page pool shared by all slots (block tables map
        slots to pages); layer 1 mirrors it at the spill budget, plus one
        resident "seat" per spill page for recurrent SSM state (a spilled
        sequence holds at least one page, so seats cannot run out first).
        """
        geom = sch.pages
        assert geom is not None, "init_paged_pool needs a paged scheduler"
        cfg = self.model.cfg
        if cfg.family == "encdec" or cfg.frontend_len:
            raise NotImplementedError(
                "paged serving targets decoder-only token-prompt models; "
                "others go through one-shot generate()")
        from repro.models import transformer
        l0 = CODECS[getattr(geom, "layer0_codec", "fp16")]
        l1 = CODECS[getattr(geom, "layer1_codec", "fp16")]
        if (l0.name != "fp16" or l1.name != "fp16") \
                and _has_recurrent_state(cfg):
            raise ValueError(
                "quantized KV pages require attention-only models: "
                "recurrent SSM state integrates every step and has no "
                "bounded per-page error story (docs/SERVING.md)")
        self._geom = geom
        n_slots = sch.n_slots
        state = {"caches": transformer.init_paged_caches(
            cfg, n_slots, geom.n_pages, geom.page_tokens,
            dtype=l0.dtype, quant_scales=l0.scaled)}
        spill = transformer.init_paged_caches(
            cfg, geom.n_spill_pages, geom.n_spill_pages, geom.page_tokens,
            dtype=l1.dtype, quant_scales=l1.scaled)
        zeros = jnp.zeros((n_slots,), jnp.int32)
        pool = PoolState(
            state=state,
            tok=jnp.full((n_slots,), self.ecfg.pad_token, jnp.int32),
            cache_len=zeros, done=jnp.ones((n_slots,), bool),
            n_gen=zeros, budget=zeros,
            block_tables=jnp.zeros((n_slots, geom.max_pages_per_slot),
                                   jnp.int32))
        return self._place(pool), self._place(spill)

    # -------------------------------------------------------- tier copies
    def tier_copy_fn(self, src_codec: str = "fp16", dst_codec: str = "fp16"):
        """ONE jitted layer-0 <-> layer-1 copy per codec pair, shared by
        spill and restore (jit's shape-keyed cache traces each direction
        independently).

        Page pools move whole pages (gather by source ids, scatter at
        destination ids — padded entries route through the null pages);
        recurrent per-slot state moves one row between the slot axis and
        the spill seat axis. Everything stays on device.

        Same-codec tiers copy VERBATIM — int8 codes and their page scales
        move untouched, so a quantized spill -> restore round-trip is
        bit-exact (no double quantization). Cross-codec tiers (the fp8
        policy's fp8 layer 0 <-> int8 layer 1) dequantize each moved page
        to f32 and re-encode at the destination codec, writing fresh
        per-page scales when the destination is scaled.
        """
        key = (src_codec, dst_codec)
        if key in self._tier_copy:
            return self._tier_copy[key]
        from repro.models import transformer
        from repro.kernels import paged_attention as pq
        cfg = self.model.cfg
        src_c, dst_c = CODECS[src_codec], CODECS[dst_codec]
        same = src_codec == dst_codec

        def convert_pages(src_leaves, dst_leaves, pages_src, pages_dst):
            out = dict(dst_leaves)
            for name in [n for n in src_leaves if not n.endswith("_scale")]:
                sel = src_leaves[name][:, pages_src]    # (r, Psel, *page)
                if src_c.scaled:
                    scl = src_leaves[name + "_scale"][:, pages_src]
                    sel = (sel.astype(jnp.float32)
                           * scl.reshape(scl.shape + (1,) * (sel.ndim - 2)))
                else:
                    sel = sel.astype(jnp.float32)
                dst = dst_leaves[name]
                if dst_c.scaled:
                    codes, scales = pq.quantize_page_int8(
                        sel, tuple(range(2, sel.ndim)))
                    out[name] = dst.at[:, pages_dst].set(codes)
                    out[name + "_scale"] = dst_leaves[
                        name + "_scale"].at[:, pages_dst].set(scales)
                else:
                    out[name] = dst.at[:, pages_dst].set(
                        sel.astype(dst.dtype))
            return out

        def copy(src_caches, dst_caches, row_src, row_dst, pages_src,
                 pages_dst):
            def page_copy(s, d):
                return d.at[:, pages_dst].set(s[:, pages_src].astype(d.dtype))

            def row_copy(s, d):
                row = jax.lax.dynamic_slice_in_dim(s, row_src, 1, axis=1)
                return jax.lax.dynamic_update_slice_in_dim(
                    d, row.astype(d.dtype), row_dst, axis=1)

            out: Dict[str, Any] = {}
            for gname, gkey, is_paged in transformer.paged_cache_kinds(cfg):
                src_g, dst_g = src_caches[gname][gkey], dst_caches[gname][gkey]
                if not is_paged:
                    leaf = jax.tree.map(row_copy, src_g, dst_g)
                elif same:
                    leaf = jax.tree.map(page_copy, src_g, dst_g)
                else:
                    leaf = convert_pages(src_g, dst_g, pages_src, pages_dst)
                out.setdefault(gname, {})[gkey] = leaf
            return out

        self._tier_copy[key] = jax.jit(copy)
        return self._tier_copy[key]

    def _tier_codecs(self) -> Tuple[str, str]:
        geom = self._geom
        if geom is None:
            return ("fp16", "fp16")
        return (getattr(geom, "layer0_codec", "fp16"),
                getattr(geom, "layer1_codec", "fp16"))

    @staticmethod
    def pad_pages(pages, p_max: int) -> jax.Array:
        row = np.zeros((p_max,), np.int32)
        row[:len(pages)] = pages
        return jnp.asarray(row)

    def exec_spill(self, pool: PoolState, spill: Dict[str, Any],
                   act: sched_mod.SpillAction, p_max: int) -> Dict[str, Any]:
        self.owner.pop(act.slot, None)      # preempted: the slot frees
        l0, l1 = self._tier_codecs()
        return self.tier_copy_fn(l0, l1)(
            pool.state["caches"], spill,
            jnp.asarray(act.slot, jnp.int32),
            jnp.asarray(act.seat, jnp.int32),
            self.pad_pages(act.src_pages, p_max),
            self.pad_pages(act.dst_pages, p_max))

    def exec_restore(self, pool: PoolState, spill: Dict[str, Any],
                     act: sched_mod.RestoreAction, p_max: int) -> PoolState:
        """Copy a preempted sequence back into layer 0 and re-arm its slot.

        The per-slot vectors are rebuilt from the host mirror: the KV
        frontier is one behind the emitted count (the last token's K/V is
        written by its own upcoming decode step), so decode resumes
        bit-exactly where preemption cut it."""
        req = act.req
        l0, l1 = self._tier_codecs()
        caches = self.tier_copy_fn(l1, l0)(
            spill, pool.state["caches"],
            jnp.asarray(act.seat, jnp.int32),
            jnp.asarray(act.slot, jnp.int32),
            self.pad_pages(act.src_pages, p_max),
            self.pad_pages(req.pages[:len(act.src_pages)], p_max))
        slot = act.slot
        if req.status == sched_mod.PREFILLING:
            # restored mid-chunked-prefill: no output token exists yet, so
            # only the KV frontier is re-armed; done is FORCED True (the
            # slot may have been freed by a mid-decode preemption, leaving
            # done=False on device) so the slot stays masked until its
            # final chunk lands, and the cursor resumes at the NEXT
            # boundary's prefill phase (plan order contract)
            return dataclasses.replace(
                pool, state={**pool.state, "caches": caches},
                cache_len=pool.cache_len.at[slot].set(req.cache_len),
                done=pool.done.at[slot].set(True))
        return dataclasses.replace(
            pool, state={**pool.state, "caches": caches},
            tok=pool.tok.at[slot].set(int(req.tokens[-1])),
            cache_len=pool.cache_len.at[slot].set(req.cache_len),
            done=pool.done.at[slot].set(False),
            n_gen=pool.n_gen.at[slot].set(len(req.tokens)),
            budget=pool.budget.at[slot].set(req.max_new_tokens))

    # ---------------------------------------------------------- ownership
    def claim(self, slot: int, role: str) -> None:
        """Record which role a slot's work is issued by (disaggregated
        serving only; combined engines never populate the map)."""
        self.owner[slot] = role

    def release(self, slot: int) -> None:
        self.owner.pop(slot, None)

    def transfer_ownership(self, slot: int, pages: List[int], *,
                           src: str = PREFILL_ROLE,
                           dst: str = DECODE_ROLE) -> None:
        """Hand a slot (and its mapped pages) from ``src`` to ``dst``.

        This is pure bookkeeping — the zero-copy invariant of the shared
        pool: the slot's pages already live in the layer-0 arrays both
        roles compute against, so handover flips one table entry and the
        decode role's next block-table upload carries the row. Raises if
        ``src`` does not own the slot (a handover for a slot the prefill
        role lost to preemption would silently corrupt routing).
        """
        cur = self.owner.get(slot)
        if cur != src:
            raise RuntimeError(
                f"handover of slot {slot}: owned by {cur!r}, expected "
                f"{src!r} — page handover must follow the final prefill "
                f"chunk of the owning role")
        self.owner[slot] = dst
        self.handovers += 1
        self.handover_pages += len(pages)
