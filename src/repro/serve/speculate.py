"""Self-drafting speculative decoding: proposer + acceptance folding.

Decode is memory-bound — each emitted token streams a slot's ENTIRE
resident KV through layer 0 (the paged pool reads every mapped page per
step). Speculative decoding amortizes that sweep: per drain boundary the
host proposes up to k draft tokens per live slot from the slot's own
emitted+prompt history (n-gram / prompt lookup — no second model), the
engine scores all k in ONE batched verify forward
(:meth:`repro.models.api.Model.verify_step`), and the fold below converts
per-slot greedy agreement into the engine's existing done-masked pool
updates. Greedy outputs are bit-exact with the single-token path by
construction: logits column ``j`` of the verify forward equals what the
``j``-th sequential decode step would have produced, so every emitted
token is the argmax given its true prefix (DESIGN.md §Speculative
decoding).

Host/device split: :func:`propose_ngram` is pure numpy and runs at drain
boundaries (where the host already owns a sync); :func:`fold_acceptance`
is pure jnp and runs inside the jitted verify chunk — the
one-host-sync-per-chunk discipline is untouched.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def propose_ngram(context: np.ndarray, k: int, *, max_ngram: int = 3,
                  min_ngram: int = 1) -> np.ndarray:
    """Prompt-lookup draft proposal: continue the most recent repeat.

    Finds the latest earlier occurrence of the context's trailing n-gram
    (longest ``max_ngram``..``min_ngram`` first) and proposes the up-to-k
    tokens that followed it. Repetitive/self-similar streams — templated
    agent turns, code, looping greedy continuations — make this proposer
    nearly oracle; on non-repeating text it simply finds no match and the
    boundary degrades to an ordinary single-token step. Proposals are
    GUESSES only: acceptance is decided by the verify forward, so a bad
    draft can never corrupt output, only waste the speculated positions.
    """
    ctx = np.asarray(context, np.int32)
    n = int(ctx.shape[0])
    if k <= 0 or n < min_ngram + 1:
        return np.zeros((0,), np.int32)
    for g in range(min(max_ngram, n - 1), min_ngram - 1, -1):
        tail = ctx[n - g:]
        windows = np.lib.stride_tricks.sliding_window_view(ctx, g)
        # candidate starts strictly before the trailing occurrence, so a
        # continuation of at least one token exists
        hits = np.nonzero((windows[:n - g] == tail).all(axis=1))[0]
        if hits.size:
            # most recent hit whose continuation supplies all k tokens —
            # on a short-period cycle the very latest hits sit so close to
            # the end that their continuation is truncated by it, which
            # would cap every proposal at the cycle period
            full = hits[hits + g + k <= n]
            start = int(full[-1] if full.size else hits[-1]) + g
            return ctx[start:start + k].copy()
    return np.zeros((0,), np.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FoldResult:
    """On-device result of folding verify targets against drafts."""

    valid: jax.Array       # (S, k+1) bool — token j of this slot is emitted
    emitted: jax.Array     # (S,) int32 — tokens emitted = accepted + 1
    tok: jax.Array         # (S,) int32 — new last-emitted token per slot
    done: jax.Array        # (S,) bool — done mask after the fold
    n_gen: jax.Array       # (S,) int32
    cache_len: jax.Array   # (S,) int32 — rolled-back frontier


def fold_acceptance(targets: jax.Array, drafts: jax.Array,
                    draft_len: jax.Array, *, done: jax.Array,
                    n_gen: jax.Array, budget: jax.Array,
                    cache_len: jax.Array, max_len: int,
                    eos_token: int) -> FoldResult:
    """Fold greedy verify targets into the pool's done-masked updates.

    ``targets[:, j]`` is the argmax after feeding token ``j`` of the verify
    chunk (slot's last token, then its drafts); ``drafts`` is ``(S, k)``
    with ``draft_len`` proposed entries per slot. The accepted prefix is
    the LONGEST exact match of drafts against targets; the slot then emits
    those accepted drafts plus one correction/bonus token — ``targets`` at
    the first mismatch — replicating exactly what ``emitted`` sequential
    single-token steps would have produced, including every stop rule:

      * nothing is emitted past the first rejection,
      * nothing is emitted past an emitted EOS / exhausted ``budget`` /
        full ``max_len`` slot (``stop`` below mirrors the single-token
        loop's done update, applied mid-chunk),
      * rollback: ``cache_len`` advances by exactly ``emitted`` — i.e. to
        pre-verify length + accepted + 1 — so the rejected suffix's K/V
        sits at-or-past the frontier where every attention mask already
        hides it, and ordinary decode overwrites it as it advances.

    Pure jnp; runs inside the jitted verify chunk (no host sync).
    """
    k = drafts.shape[1]
    idx = jnp.arange(k + 1, dtype=jnp.int32)[None, :]       # (1, k+1)
    match = ((targets[:, :k] == drafts)
             & (jnp.arange(k, dtype=jnp.int32)[None, :] < draft_len[:, None]))
    accepted = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    # stop[:, j]: emitting token j makes the slot done (same predicate the
    # single-token loop applies after its j-th step)
    stop = ((targets == eos_token)
            | (n_gen[:, None] + idx + 1 >= budget[:, None])
            | (cache_len[:, None] + idx + 1 >= max_len))
    stops_before = (jnp.cumsum(stop.astype(jnp.int32), axis=1)
                    - stop.astype(jnp.int32))
    valid = ((~done[:, None]) & (idx <= accepted[:, None])
             & (stops_before == 0))
    emitted = valid.sum(axis=1).astype(jnp.int32)           # (S,)
    last = jnp.maximum(emitted - 1, 0)
    last_tok = jnp.take_along_axis(targets, last[:, None], axis=1)[:, 0]
    tok = jnp.where(emitted > 0, last_tok, eos_token).astype(jnp.int32)
    stop_last = jnp.take_along_axis(stop, last[:, None], axis=1)[:, 0]
    return FoldResult(
        valid=valid,
        emitted=emitted,
        tok=tok,
        done=done | ((emitted > 0) & stop_last),
        n_gen=n_gen + emitted,
        cache_len=cache_len + emitted,
    )
