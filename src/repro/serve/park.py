"""Layer-2 host tier: serialize an idle session off-device, resume later.

DESIGN.md §Tiered KV compression & host parking. A *park* captures
everything a session needs to continue decoding after its device
resources are gone:

  * the contents of every page its block-table row maps — KV codes AND,
    for a scaled (int8) tier, the sibling per-page scales, copied
    verbatim so the round trip is lossless at ANY codec (fp16 parks are
    byte-identical; int8 parks restore the exact codes that were
    resident, never a re-quantization);
  * its per-slot rows — recurrent-state seats never park (the scheduler
    rejects parking for recurrent families upstream), but the row slice
    keeps the walk uniform;
  * the scheduler residue: prompt, emitted tokens, decode budget, and
    the KV frontier, enough for :meth:`Scheduler.submit_parked` to
    rebuild the host mirror and re-enter admission as a *resume* rather
    than a re-prefill.

The byte format rides :mod:`repro.train.checkpoint`'s codec path — the
same zstd(-or-zlib) per-leaf compression checkpoints use — wrapped with a
small msgpack header for the residue. Raw-bytes round trip through
``np.frombuffer`` keeps fp16 parks bit-exact end to end.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import msgpack
import numpy as np

from repro.train.checkpoint import _deserialize_leaves, _serialize_tree

#: Bump when the blob layout changes — parked sessions may outlive
#: processes, so a loud version check beats a shape error mid-resume.
PARK_FORMAT = 1


def pack_parked(meta: Dict[str, Any], arrays: Dict[str, Any]) -> bytes:
    """Serialize one parked session: ``meta`` (json-safe scheduler
    residue) + ``arrays`` (a pytree of page/row contents, host or device;
    leaves are fetched and compressed per-leaf)."""
    return msgpack.packb(
        {"format": PARK_FORMAT, "meta": meta,
         "arrays": _serialize_tree(arrays)},
        use_bin_type=True)


def unpack_parked(blob: bytes) -> Tuple[Dict[str, Any],
                                        Dict[str, np.ndarray]]:
    """Inverse of :func:`pack_parked`: ``(meta, flat arrays)`` with array
    keys ``"/"``-joined along the original tree paths."""
    top = msgpack.unpackb(blob, raw=False)
    fmt = top.get("format")
    if fmt != PARK_FORMAT:
        raise ValueError(f"parked-session blob format {fmt!r}; this build "
                         f"reads format {PARK_FORMAT}")
    return top["meta"], _deserialize_leaves(top["arrays"])
