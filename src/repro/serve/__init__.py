"""Serving: continuous-batching engine + scheduler over the pooled KV cache."""

from repro.serve.engine import Engine, EngineConfig, PoolState, ServeReport
from repro.serve.scheduler import (Request, Scheduler, SlotTable,
                                   derive_n_slots, kv_bytes_per_token,
                                   pool_partition, resident_bytes_per_slot)

__all__ = [
    "Engine", "EngineConfig", "PoolState", "ServeReport",
    "Request", "Scheduler", "SlotTable",
    "derive_n_slots", "kv_bytes_per_token", "pool_partition",
    "resident_bytes_per_slot",
]
