"""Serving."""
