"""Data pipeline."""
