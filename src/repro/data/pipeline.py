"""Deterministic, resumable, host-sharded synthetic token pipeline.

Production posture without a dataset dependency: batches are a pure function
of (seed, step, host_shard), so (a) restart-resume is exact (no iterator
state to checkpoint beyond the step counter), (b) every host generates only
its shard, (c) elastic re-slicing just changes the shard map.

The token stream is a seeded first-order Markov chain (fixed per-seed bigram
table), so models *can* learn structure — the train-loss-decreases
integration test relies on that.

Prefetch: a background thread keeps ``depth`` batches ready — the paper's
memory-phase/compute-phase overlap, at the input-pipeline level.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4      # candidate successors per token (structure)
    frontend_len: int = 0   # vlm/audio prefix length
    d_model: int = 0        # for frontend embeds
    encdec: bool = False


class SyntheticPipeline:
    """Stateless batch generation + stateful prefetcher."""

    def __init__(self, cfg: DataConfig, host_index: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        rng = np.random.default_rng(cfg.seed)
        # fixed bigram table: token t -> branching candidates
        self._bigram = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size, cfg.branching),
            dtype=np.int32)

    # ------------------------------------------------------------ pure gen
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        seed = (hash((cfg.seed, step, self.host_index)) & 0x7FFFFFFF)
        rng = np.random.default_rng(seed)
        b, s = self.local_batch, cfg.seq_len
        s_text = s - cfg.frontend_len
        toks = np.empty((b, s_text + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        choices = rng.integers(0, cfg.branching, size=(b, s_text))
        for t in range(s_text):
            toks[:, t + 1] = self._bigram[toks[:, t], choices[:, t]]
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }
        if cfg.encdec:
            batch["src_embeds"] = rng.standard_normal(
                (b, s, cfg.d_model)).astype(np.float32) * 0.02
            batch["tokens"] = np.pad(toks[:, :-1], ((0, 0), (0, cfg.frontend_len)))[:, :s]
            batch["labels"] = np.pad(toks[:, 1:], ((0, 0), (0, cfg.frontend_len)),
                                     constant_values=-1)[:, :s]
        elif cfg.frontend_len:
            batch["frontend_embeds"] = rng.standard_normal(
                (b, cfg.frontend_len, cfg.d_model)).astype(np.float32) * 0.02
        return batch

    # ----------------------------------------------------------- prefetch
    def iterator(self, start_step: int = 0, depth: int = 2
                 ) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                batch = self.batch_at(step)
                while not stop.is_set():
                    try:
                        q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        th = threading.Thread(target=worker, daemon=True)
        th.start()
        try:
            while True:
                _, batch = q.get()
                yield batch
        finally:
            stop.set()
