"""Production mesh definitions.

Hierarchy mapping (DESIGN.md): `model` = intra-pod ICI (MemPool's group
interconnect), `data` = FSDP/DP within a pod, `pod` = the cluster level
(lowest bandwidth, gradient-reduce only). A function, not a module constant:
importing this module never touches jax device state.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dry-run must set --xla_force_host_platform_device_count)")
    return make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = data * model
    devices = jax.devices()[:n]
    return make_mesh((data, model), ("data", "model"), devices=devices)
