"""Production mesh definitions.

Hierarchy mapping (DESIGN.md): `model` = intra-pod ICI (MemPool's group
interconnect), `data` = FSDP/DP within a pod, `pod` = the cluster level
(lowest bandwidth, gradient-reduce only). A function, not a module constant:
importing this module never touches jax device state.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dry-run must set --xla_force_host_platform_device_count)")
    return make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = data * model
    devices = jax.devices()[:n]
    return make_mesh((data, model), ("data", "model"), devices=devices)


def parse_mesh(spec: str, axes: str = "data,model"):
    """Parse the serving CLIs' ``--mesh`` / ``--mesh-axes`` flags.

    ``spec`` is either one int — model-parallel shorthand, ``"2"`` means
    ``1x2`` — or ``"DxM[xP...]"`` sizes matching ``axes`` (comma-separated
    axis names, default ``"data,model"``). Returns ``(sizes, names)``.
    """
    names = tuple(a.strip() for a in axes.split(",") if a.strip())
    if not names:
        raise ValueError(f"--mesh-axes names no axes: {axes!r}")
    if "x" in spec:
        sizes = tuple(int(x) for x in spec.split("x"))
    else:
        sizes = (1,) * (len(names) - 1) + (int(spec),)
    if len(sizes) != len(names):
        raise ValueError(
            f"--mesh {spec!r} has {len(sizes)} sizes but --mesh-axes "
            f"names {len(names)} axes ({', '.join(names)})")
    if any(s < 1 for s in sizes):
        raise ValueError(f"--mesh sizes must be >= 1, got {spec!r}")
    return sizes, names


def make_cli_mesh(spec: str, axes: str = "data,model") -> jax.sharding.Mesh:
    """Mesh for the serving CLIs, with guidance when devices are missing."""
    sizes, names = parse_mesh(spec, axes)
    n = 1
    for s in sizes:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"--mesh {spec} needs {n} devices, have {len(devices)}; on a "
            f"CPU host, export XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={n} before starting python")
    return make_mesh(sizes, names, devices=devices[:n])
