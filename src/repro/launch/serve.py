"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Prefill + batched greedy decode through the Engine (pooled KV cache).
Reports prefill latency and per-step decode latency/throughput.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve.engine import Engine, EngineConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    model = build_model(cfg)
    d_mesh, m_mesh = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d_mesh, m_mesh)

    with shd.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        max_len = args.prompt_len + args.gen_len + cfg.frontend_len
        engine = Engine(model, params, EngineConfig(max_len=max_len))
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 2, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["src_embeds"] = (jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02
            ).astype(jnp.bfloat16)
        elif cfg.frontend_len:
            batch["frontend_embeds"] = (jax.random.normal(
                key, (args.batch, cfg.frontend_len, cfg.d_model)) * 0.02
            ).astype(jnp.bfloat16)

        t0 = time.monotonic()
        tokens, _ = engine.generate(batch, n_steps=args.gen_len)
        dt = time.monotonic() - t0
        n_generated = int(tokens.shape[0] * tokens.shape[1])
        print(f"arch={cfg.name} batch={args.batch} "
              f"prompt={args.prompt_len} gen={tokens.shape[1]}")
        print(f"tokens (first row): {tokens[0].tolist()}")
        print(f"total {dt*1e3:.0f} ms, {n_generated/dt:.1f} tok/s "
              f"(prefill amortized)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
