"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Two modes through the same Engine (pooled KV cache):

  * default — one-shot batched greedy decode (prefill + fixed batch),
    reporting total latency and throughput.
  * ``--stream N`` — continuous batching: N synthetic requests with mixed
    prompt/output lengths flow through the scheduler's slot table; reports
    per-request queueing/decode latency percentiles and aggregate tokens/s.

Hardware target selection: ``--target <name>`` (or ``REPRO_TARGET``) — the
slot budget is derived from that target's CapacityPartition.
"""

from __future__ import annotations

import argparse
import contextlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core.target import available_targets, use_target
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve.engine import Engine, EngineConfig
from repro.serve.scheduler import (DRAINED, Scheduler, derive_n_slots,
                                   synthetic_stream)


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def run_stream(engine: Engine, scheduler: Scheduler, n_requests: int,
               prompt_len: int, gen_len: int, vocab: int, seed: int = 0
               ) -> dict:
    """Drive a synthetic mixed-length request stream; return counters."""
    for spec in synthetic_stream(n_requests, prompt_len, gen_len, vocab,
                                 seed):
        scheduler.submit(spec["prompt"], spec["max_new_tokens"])
    t0 = time.monotonic()
    report = engine.serve(scheduler=scheduler)
    dt = time.monotonic() - t0
    n_tokens = sum(len(r.tokens) for r in report.requests)
    served = [r for r in report.requests if r.status == DRAINED]
    queue_steps = [r.admit_step - r.submit_step for r in served]
    decode_steps = [r.finish_step - r.admit_step for r in served
                    if r.finish_step >= 0]
    return {
        "n_requests": n_requests,
        "completed": report.stats["drained"],
        "n_tokens": n_tokens,
        "wall_s": dt,
        "tok_per_s": n_tokens / dt if dt else 0.0,
        "host_syncs": report.stats["host_syncs"],
        "decode_steps_total": report.stats["decode_steps"],
        "n_slots": report.stats["n_slots"],
        "max_slot_reuse": report.stats["max_slot_reuse"],
        "queue_steps_p50": _percentile(queue_steps, 50),
        "queue_steps_p95": _percentile(queue_steps, 95),
        "decode_steps_p50": _percentile(decode_steps, 50),
        "decode_steps_p95": _percentile(decode_steps, 95),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--target", default=None, metavar="NAME",
                    help=f"hardware target ({', '.join(available_targets())})")
    ap.add_argument("--stream", type=int, default=0, metavar="N",
                    help="continuous batching over N synthetic requests")
    ap.add_argument("--slots", type=int, default=None,
                    help="override the CapacityPartition-derived slot count")
    ap.add_argument("--sync-interval", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if args.stream and (cfg.family == "encdec" or cfg.frontend_len):
        ap.error(f"--stream serves decoder-only token-prompt models; "
                 f"{cfg.name} ({cfg.family}) goes through one-shot mode")
    d_mesh, m_mesh = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d_mesh, m_mesh)

    tgt_ctx = use_target(args.target) if args.target else contextlib.nullcontext()
    with tgt_ctx, shd.use_mesh(mesh):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = args.prompt_len + args.gen_len + cfg.frontend_len
        engine = Engine(model, params,
                        EngineConfig(max_len=max_len,
                                     sync_interval=args.sync_interval))

        if args.stream:
            n_slots = args.slots or derive_n_slots(
                cfg, max_len, max_slots=max(2, args.batch))
            sched = Scheduler(n_slots=n_slots)
            rec = run_stream(engine, sched, args.stream, args.prompt_len,
                             args.gen_len, cfg.vocab_size)
            print(f"arch={cfg.name} stream={args.stream} "
                  f"slots={rec['n_slots']} (max reuse {rec['max_slot_reuse']})")
            print(f"completed {rec['completed']}/{rec['n_requests']} "
                  f"({rec['n_tokens']} tokens) in {rec['wall_s']*1e3:.0f} ms "
                  f"-> {rec['tok_per_s']:.1f} tok/s")
            print(f"host syncs {rec['host_syncs']} over "
                  f"{rec['decode_steps_total']} decode steps")
            print(f"latency (decode steps): queue p50/p95 "
                  f"{rec['queue_steps_p50']:.0f}/{rec['queue_steps_p95']:.0f}, "
                  f"decode p50/p95 {rec['decode_steps_p50']:.0f}/"
                  f"{rec['decode_steps_p95']:.0f}", flush=True)
            return 0

        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 2, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["src_embeds"] = (jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02
            ).astype(jnp.bfloat16)
        elif cfg.frontend_len:
            batch["frontend_embeds"] = (jax.random.normal(
                key, (args.batch, cfg.frontend_len, cfg.d_model)) * 0.02
            ).astype(jnp.bfloat16)

        t0 = time.monotonic()
        tokens, _ = engine.generate(batch, n_steps=args.gen_len)
        dt = time.monotonic() - t0
        n_generated = int(tokens.shape[0] * tokens.shape[1])
        print(f"arch={cfg.name} batch={args.batch} "
              f"prompt={args.prompt_len} gen={tokens.shape[1]}")
        print(f"tokens (first row): {tokens[0].tolist()}")
        print(f"total {dt*1e3:.0f} ms, {n_generated/dt:.1f} tok/s "
              f"(prefill amortized; {engine.last_stats['host_syncs']} host "
              f"syncs / {engine.last_stats['decode_steps']} steps)",
              flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
