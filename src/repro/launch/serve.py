"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Two modes through the same Engine (pooled KV cache):

  * default — one-shot batched greedy decode (prefill + fixed batch),
    reporting total latency and throughput.
  * ``--stream N`` — continuous batching: N synthetic requests with mixed
    prompt/output lengths flow through the scheduler's slot table; reports
    per-request TTFT / end-to-end latency percentiles (from the
    scheduler's per-request clocks) and aggregate tokens/s.
  * ``--stream N --paged`` — the same stream over the paged two-tier pool:
    admission by pages, preempt-and-spill to the layer-1 tier when layer 0
    runs out. ``--page-tokens`` / ``--layer0-bytes`` / ``--layer1-bytes``
    shape the pool; preemption/spill counters join the report.
  * ``--stream N --paged --prefix-share`` — the stream becomes the
    shared-system-prompt workload (every prompt = one common
    ``--system-len`` prefix + a unique tail) and admissions serve the
    shared prefix from ref-counted resident pages, prefilling only the
    tail; prefix hit/miss, shared-token, COW, and mapped-vs-physical page
    counters join the report (DESIGN.md §Prefix sharing & copy-on-write).
  * ``--chunk-prefill-tokens N`` (any stream mode) — chunked prefill:
    admission prefill is capped at N tokens per drain boundary and
    interleaved with decode, so a long prompt no longer stalls in-flight
    requests; ``0`` derives the budget from the target
    (``derive_prefill_chunk``). Chunk counters (chunks, max boundary
    prefill tokens) join the report (DESIGN.md §Chunked prefill).
  * ``--stream N --paged --disaggregate`` — disaggregated prefill/decode
    engine roles over the same paged pool (DESIGN.md §Disaggregated
    serving): admissions and prompt chunks run on the prefill role, the
    batched decode on the decode role, and at each request's final prefill
    chunk its pages hand over by a zero-copy block-table-row move.
    Handover and per-role host-sync counters join the report; outputs are
    bit-identical to the combined engine.
  * ``--speculate-tokens K`` (any stream mode) — self-drafting speculative
    decoding: each drain boundary proposes up to K draft tokens per live
    slot by prompt lookup and scores them all in ONE batched verify
    forward, emitting the accepted prefix + 1 (greedy bit-exact); ``0``
    derives K from the target (``derive_speculate_tokens``). Without
    ``--prefix-share`` the stream becomes the repetitive (motif-tiled)
    workload the proposer is built for; proposed/accepted/rejected and
    acceptance-rate counters join the report (DESIGN.md §Speculative
    decoding).

Hardware target selection: ``--target <name>`` (or ``REPRO_TARGET``) — the
slot/page budgets are derived from that target's CapacityPartition
(two-tier via its stacked TieredPartition in paged mode).
"""

from __future__ import annotations

import argparse
import contextlib
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core.target import available_targets, use_target
from repro.distributed import sharding as shd
from repro.launch.mesh import make_cli_mesh
from repro.models import build_model
from repro.serve.engine import Engine, EngineConfig
from repro.serve.scheduler import (DECODING, DRAINED, PREFILLING, Scheduler,
                                   derive_n_slots, derive_page_geometry,
                                   derive_prefill_chunk,
                                   derive_speculate_tokens, kv_shards,
                                   percentile, repetitive_stream,
                                   shared_prefix_stream, synthetic_stream)


def run_stream(engine: Engine, scheduler: Scheduler, stream: list, *,
               park_idle: int = 0) -> dict:
    """Drive a prepared request stream; return counters.

    With ``park_idle`` the stream runs in two phases: serve ``park_idle``
    decode steps, park every decoding resident to the layer-2 host tier
    (mid-prefill residents requeue from scratch — they have nothing to
    resume), resume the parked blobs into the SAME scheduler, and serve to
    completion. Outputs are bit-identical to the uninterrupted run at the
    fp16 codec; the park counters land in the report."""
    n_requests = len(stream)
    for spec in stream:
        scheduler.submit(spec["prompt"], spec["max_new_tokens"])
    t0 = time.monotonic()
    pre_stats = None
    if park_idle:
        engine.serve(scheduler=scheduler, max_steps=park_idle)
        pre_stats = dict(engine.last_stats)
        blobs = []
        for slot in sorted(list(scheduler.active)):
            req = scheduler.active[slot]
            if req.status == DECODING:
                blobs.append(engine.park_request(scheduler, req.rid))
            elif req.status == PREFILLING:
                scheduler.requeue(slot)
        for blob in blobs:
            engine.resume_parked(scheduler, blob)
    report = engine.serve(scheduler=scheduler)
    dt = time.monotonic() - t0
    stats = report.stats
    if pre_stats:
        for k in ("host_syncs", "decode_steps", "chunks"):
            stats[k] = stats.get(k, 0) + pre_stats.get(k, 0)
    n_tokens = sum(len(r.tokens) for r in report.requests)
    served = [r for r in report.requests if r.status == DRAINED]
    decode_steps = [r.finish_step - r.admit_step for r in served
                    if r.finish_step >= 0]
    rec = {
        "n_requests": n_requests,
        "completed": stats["drained"],
        "n_tokens": n_tokens,
        "wall_s": dt,
        "tok_per_s": n_tokens / dt if dt else 0.0,
        "host_syncs": stats["host_syncs"],
        "decode_steps_total": stats["decode_steps"],
        "n_slots": stats["n_slots"],
        "max_slot_reuse": stats["max_slot_reuse"],
        # per-request latency percentiles from the scheduler's clocks —
        # TTFT (submit -> admission) and end-to-end (submit -> drain)
        "ttft_steps_p50": percentile(stats["ttft_steps"], 50),
        "ttft_steps_p95": percentile(stats["ttft_steps"], 95),
        "e2e_steps_p50": percentile(stats["e2e_steps"], 50),
        "e2e_steps_p95": percentile(stats["e2e_steps"], 95),
        "decode_steps_p50": percentile(decode_steps, 50),
        "decode_steps_p95": percentile(decode_steps, 95),
        "preemptions": stats["preemptions"],
        "spilled_pages": stats["spilled_pages"],
        "restores": stats["restores"],
        # chunked prefill: TTFT to the first OUTPUT token (under chunking
        # the final chunk's boundary, later than slot admission) and how
        # much prompt work any single boundary booked
        "ttft_emit_steps_p50": percentile(stats["ttft_emit_steps"], 50),
        "ttft_emit_steps_p95": percentile(stats["ttft_emit_steps"], 95),
        "chunk_prefill_tokens": stats["chunk_prefill_tokens"],
        "prefill_chunks": stats["prefill_chunks"],
        "max_boundary_prefill_tokens": stats["max_boundary_prefill_tokens"],
    }
    if stats.get("speculate_tokens"):
        rec.update({k: stats[k] for k in (
            "speculate_tokens", "spec_proposed", "spec_accepted",
            "spec_rejected", "spec_acceptance_rate")})
    if stats.get("paged"):
        rec.update({k: stats[k] for k in (
            "page_tokens", "n_pages", "n_spill_pages", "pages_high_water",
            "spill_high_water", "pool_bytes", "spill_bytes",
            "layer0_codec", "layer1_codec", "parks", "park_resumes",
            "resident_high_water")})
    if stats.get("prefix_sharing"):
        rec.update({k: stats[k] for k in (
            "prefix_hits", "prefix_misses", "shared_prefix_tokens",
            "cow_copies", "mapped_high_water")})
    if stats.get("disaggregate"):
        rec.update({
            "disaggregate": True,
            "handovers": stats["handovers"],
            "handover_pages": stats["handover_pages"],
            "host_syncs_by_role": dict(stats["host_syncs_by_role"]),
            "decode_tokens": stats["decode_tokens"],
        })
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mesh", default="1",
                    help="device mesh: one int (model-parallel shorthand, "
                         "2 = 1x2) or DxM sizes matching --mesh-axes; "
                         "default 1 = today's single-device path")
    ap.add_argument("--mesh-axes", default="data,model",
                    help="comma-separated axis names the --mesh sizes "
                         "bind to (default data,model)")
    ap.add_argument("--target", default=None, metavar="NAME",
                    help=f"hardware target ({', '.join(available_targets())})")
    ap.add_argument("--stream", type=int, default=0, metavar="N",
                    help="continuous batching over N synthetic requests")
    ap.add_argument("--slots", type=int, default=None,
                    help="override the CapacityPartition-derived slot count")
    ap.add_argument("--sync-interval", type=int, default=8)
    ap.add_argument("--paged", action="store_true",
                    help="serve --stream over the paged two-tier KV pool")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--layer0-bytes", type=int, default=None,
                    help="override the layer-0 (hot tier) page-pool budget")
    ap.add_argument("--layer1-bytes", type=int, default=None,
                    help="override the layer-1 (spill tier) budget")
    ap.add_argument("--kv-quant", choices=["fp16", "fp8", "int8"],
                    default=None,
                    help="per-tier KV page codec (paged mode): fp16 is the "
                         "bit-exact identity; fp8/int8 store more pages in "
                         "the same layer-0 bytes at a bounded logit error, "
                         "and the spill tier quantizes at least as hard "
                         "(fp8 spills as int8)")
    ap.add_argument("--park-idle", type=int, default=None, metavar="N",
                    help="after N decode steps, park every decoding "
                         "resident to the layer-2 host tier (zstd-coded "
                         "page bytes + scheduler residue), then resume "
                         "and serve to completion — bit-identical outputs "
                         "at fp16 (paged mode)")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split serving into prefill-role and decode-role "
                         "engines over the shared paged pool; pages hand "
                         "over at the final prefill chunk (requires "
                         "--paged; bit-identical outputs)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="share cached prompt prefixes across requests "
                         "(paged mode; drives a shared-system-prompt stream)")
    ap.add_argument("--system-len", type=int, default=None,
                    help="shared system-prompt length for --prefix-share "
                         "(default: half of --prompt-len)")
    ap.add_argument("--chunk-prefill-tokens", type=int, default=None,
                    metavar="N",
                    help="chunked prefill: cap prompt prefill at N tokens "
                         "per drain boundary, interleaved with decode "
                         "(0: derive N from the target's CapacityPartition; "
                         "default: whole-prompt admission)")
    ap.add_argument("--speculate-tokens", type=int, default=None,
                    metavar="K",
                    help="self-drafting speculative decoding: propose up to "
                         "K draft tokens per slot per drain boundary and "
                         "verify them in one batched forward "
                         "(0: derive K from the target's CapacityPartition; "
                         "default: off)")
    args = ap.parse_args(argv)
    if args.speculate_tokens is not None and not args.stream:
        ap.error("--speculate-tokens applies to --stream serving")
    if args.paged and not args.stream:
        ap.error("--paged applies to --stream serving")
    if args.prefix_share and not args.paged:
        ap.error("--prefix-share requires --paged (shared pages live in "
                 "the paged pool)")
    if args.disaggregate and not args.paged:
        ap.error("--disaggregate requires --paged (page handover moves "
                 "block-table rows, which the dense pool does not have)")
    if args.kv_quant and not args.paged:
        ap.error("--kv-quant requires --paged (tier codecs apply to the "
                 "paged pool's page bytes)")
    if args.park_idle is not None and not args.paged:
        ap.error("--park-idle requires --paged (the layer-2 host tier "
                 "serializes pages, which the dense pool does not have)")

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if args.stream and (cfg.family == "encdec" or cfg.frontend_len):
        ap.error(f"--stream serves decoder-only token-prompt models; "
                 f"{cfg.name} ({cfg.family}) goes through one-shot mode")
    mesh = make_cli_mesh(args.mesh, args.mesh_axes)
    data_shards = shd.axis_size(mesh, shd.DATA_AXIS)
    model_shards = shd.axis_size(mesh, shd.MODEL_AXIS)

    tgt_ctx = use_target(args.target) if args.target else contextlib.nullcontext()
    with tgt_ctx, shd.use_mesh(mesh):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = args.prompt_len + args.gen_len + cfg.frontend_len
        spec_k = args.speculate_tokens
        if spec_k == 0:
            spec_k = derive_speculate_tokens(cfg)
        engine = Engine(model, params,
                        EngineConfig(max_len=max_len,
                                     sync_interval=args.sync_interval,
                                     speculate_tokens=spec_k or 0,
                                     disaggregate=args.disaggregate,
                                     mesh=mesh))

        if args.stream:
            pages = None
            if args.paged:
                pages = derive_page_geometry(
                    cfg, max_len, page_tokens=args.page_tokens,
                    max_slots=max(2, args.batch),
                    layer0_bytes=args.layer0_bytes,
                    layer1_bytes=args.layer1_bytes,
                    model_shards=model_shards,
                    kv_quant=args.kv_quant)
            n_slots = args.slots or derive_n_slots(
                cfg, max_len, max_slots=max(2, args.batch), pages=pages,
                model_shards=model_shards, data_shards=data_shards)
            chunk = args.chunk_prefill_tokens
            if chunk == 0:
                chunk = derive_prefill_chunk(cfg)
            sched = Scheduler(n_slots=n_slots, pages=pages,
                              prefix_share=args.prefix_share,
                              chunk_prefill_tokens=chunk,
                              disaggregate=args.disaggregate)
            if args.prefix_share:
                system_len = args.system_len or max(1, args.prompt_len // 2)
                if system_len >= args.prompt_len:
                    ap.error("--system-len must leave room for a unique "
                             "tail (< --prompt-len)")
                stream = shared_prefix_stream(
                    args.stream, system_len, args.prompt_len - system_len,
                    args.gen_len, cfg.vocab_size)
            elif spec_k:
                # the motif-tiled workload the prompt-lookup proposer is
                # built for — what serve_bench --speculate measures
                stream = repetitive_stream(args.stream, args.prompt_len,
                                           args.gen_len, cfg.vocab_size)
            else:
                stream = synthetic_stream(args.stream, args.prompt_len,
                                          args.gen_len, cfg.vocab_size)
            rec = run_stream(engine, sched, stream,
                             park_idle=args.park_idle or 0)
            mode = ("paged+share" if args.prefix_share
                    else "paged" if args.paged else "dense")
            if args.disaggregate:
                mode += "+disagg"
            if args.kv_quant:
                mode += f"+{args.kv_quant}"
            print(f"arch={cfg.name} stream={args.stream} mode={mode} "
                  f"slots={rec['n_slots']} (max reuse {rec['max_slot_reuse']})")
            if data_shards * model_shards > 1:
                shards = kv_shards(cfg, model_shards)
                line = (f"mesh: {data_shards}x{model_shards} (data x model), "
                        f"kv pool sharded {shards}x")
                if args.paged:
                    line += f"; per-shard pool {rec['pool_bytes'] // shards} B"
                print(line)
            print(f"completed {rec['completed']}/{rec['n_requests']} "
                  f"({rec['n_tokens']} tokens) in {rec['wall_s']*1e3:.0f} ms "
                  f"-> {rec['tok_per_s']:.1f} tok/s")
            print(f"host syncs {rec['host_syncs']} over "
                  f"{rec['decode_steps_total']} decode steps")
            print(f"latency (decode steps): ttft p50/p95 "
                  f"{rec['ttft_steps_p50']:.0f}/{rec['ttft_steps_p95']:.0f}, "
                  f"e2e p50/p95 {rec['e2e_steps_p50']:.0f}/"
                  f"{rec['e2e_steps_p95']:.0f}, decode p50/p95 "
                  f"{rec['decode_steps_p50']:.0f}/"
                  f"{rec['decode_steps_p95']:.0f}")
            if rec["chunk_prefill_tokens"]:
                print(f"chunked prefill: {rec['chunk_prefill_tokens']} "
                      f"tokens/boundary budget, {rec['prefill_chunks']} "
                      f"chunks, max boundary prefill "
                      f"{rec['max_boundary_prefill_tokens']} tokens, "
                      f"ttft-to-first-token p50/p95 "
                      f"{rec['ttft_emit_steps_p50']:.0f}/"
                      f"{rec['ttft_emit_steps_p95']:.0f}")
            if spec_k:
                print(f"speculative decoding: k={rec['speculate_tokens']}, "
                      f"{rec['spec_proposed']} proposed -> "
                      f"{rec['spec_accepted']} accepted / "
                      f"{rec['spec_rejected']} rejected "
                      f"(acceptance {rec['spec_acceptance_rate']:.2f}); "
                      f"{rec['n_tokens']} tokens over "
                      f"{rec['decode_steps_total']} verify forwards")
            if args.disaggregate:
                roles = rec["host_syncs_by_role"]
                print(f"disaggregated roles: {rec['handovers']} handovers "
                      f"({rec['handover_pages']} pages moved, 0 bytes "
                      f"copied); host syncs prefill "
                      f"{roles.get('prefill', 0)} / decode "
                      f"{roles.get('decode', 0)}; "
                      f"{rec['decode_tokens']} decode-role tokens")
            if args.paged:
                print(f"pages: {rec['pages_high_water']}/{rec['n_pages']} "
                      f"layer-0 high water ({rec['pool_bytes']} B), "
                      f"{rec['preemptions']} preemptions -> "
                      f"{rec['spilled_pages']} pages spilled, "
                      f"{rec['restores']} restores "
                      f"(layer-1 high water {rec['spill_high_water']}/"
                      f"{rec['n_spill_pages']})", flush=True)
                if args.kv_quant:
                    print(f"tier codecs: layer0={rec['layer0_codec']} "
                          f"layer1={rec['layer1_codec']}; "
                          f"{rec['resident_high_water']} residents high "
                          f"water in {rec['pool_bytes']} B", flush=True)
                if args.park_idle is not None:
                    print(f"host parking: {rec['parks']} parked at step "
                          f"{args.park_idle}, {rec['park_resumes']} "
                          f"resumed (re-admitted as resumes, not "
                          f"re-prefills)", flush=True)
                if args.prefix_share:
                    hw = max(rec["pages_high_water"], 1)
                    print(f"prefix sharing: {rec['prefix_hits']} hits / "
                          f"{rec['prefix_misses']} misses, "
                          f"{rec['shared_prefix_tokens']} prompt tokens "
                          f"served from cache, {rec['cow_copies']} COW "
                          f"copies; residency {rec['mapped_high_water']} "
                          f"mapped vs {rec['pages_high_water']} physical "
                          f"pages ({rec['mapped_high_water'] / hw:.2f}x)",
                          flush=True)
            else:
                print(f"preemptions {rec['preemptions']} (dense pool)",
                      flush=True)
            return 0

        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 2, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["src_embeds"] = (jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02
            ).astype(jnp.bfloat16)
        elif cfg.frontend_len:
            batch["frontend_embeds"] = (jax.random.normal(
                key, (args.batch, cfg.frontend_len, cfg.d_model)) * 0.02
            ).astype(jnp.bfloat16)

        t0 = time.monotonic()
        tokens, _ = engine.generate(batch, n_steps=args.gen_len)
        dt = time.monotonic() - t0
        n_generated = int(tokens.shape[0] * tokens.shape[1])
        print(f"arch={cfg.name} batch={args.batch} "
              f"prompt={args.prompt_len} gen={tokens.shape[1]}")
        print(f"tokens (first row): {tokens[0].tolist()}")
        print(f"total {dt*1e3:.0f} ms, {n_generated/dt:.1f} tok/s "
              f"(prefill amortized; {engine.last_stats['host_syncs']} host "
              f"syncs / {engine.last_stats['decode_steps']} steps)",
              flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
