"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

End-to-end production loop wiring every substrate layer together:
data pipeline (prefetch) -> sharded train step (mesh + rules) -> telemetry
(straggler detector) -> atomic async checkpoints -> crash/restart recovery
(failure injection for drills). On CPU it runs reduced configs; on a real
slice the same driver runs the full configs (mesh size via --mesh).
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import FailureInjector, StragglerDetector
from repro.train.loop import TrainConfig, make_train_step


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real slice); default reduced")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--quantized-moments", action="store_true")
    ap.add_argument("--mesh", default="1x1",
                    help="data x model, e.g. 16x16 (device count must match)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="failure-injection drill: crash at this step")
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    model = build_model(cfg)
    d_mesh, m_mesh = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d_mesh, m_mesh)

    tcfg = TrainConfig(
        opt=opt_mod.OptConfig(peak_lr=args.lr, warmup_steps=10,
                              decay_steps=max(args.steps, 100),
                              quantized_moments=args.quantized_moments),
        n_microbatches=args.microbatches)
    data = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=0,
        frontend_len=cfg.frontend_len, d_model=cfg.d_model,
        encdec=cfg.family == "encdec"))

    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    injector = FailureInjector(fail_at_steps=(args.fail_at,)
                               if args.fail_at is not None else ())
    detector = StragglerDetector()

    with shd.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, shd.named_shardings(params, mesh))
        state = opt_mod.init_opt_state(params, tcfg.opt)
        start_step = 0
        if mgr and args.resume and mgr.latest_step() is not None:
            tmpl = jax.eval_shape(lambda: {"params": params, "opt": state})
            shardings = {"params": shd.named_shardings(params, mesh),
                         "opt": jax.tree.map(
                             lambda _: None, jax.eval_shape(lambda: state))}
            start_step, restored = mgr.restore(tmpl)
            params, state = restored["params"], restored["opt"]
            print(f"resumed from step {start_step}", flush=True)

        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
        it = data.iterator(start_step=start_step, depth=2)
        t_tokens = args.global_batch * args.seq_len
        for step in range(start_step, args.steps):
            if injector.check(step):
                print(f"[drill] injected crash at step {step}", flush=True)
                if mgr:
                    mgr.wait()
                return 17    # distinct exit code: restart me with --resume
            batch = jax.tree.map(jnp.asarray, next(it))
            t0 = time.monotonic()
            params, state, metrics = step_fn(params, state, batch)
            loss = float(metrics["total_loss"])   # sync point
            dt = time.monotonic() - t0
            detector.record(jax.process_index(), dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  "
                      f"{t_tokens/dt:.0f} tok/s", flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": state},
                         extra={"loss": loss})
        if mgr:
            mgr.save(args.steps, {"params": params, "opt": state},
                     blocking=True)
        stragglers = detector.stragglers()
        if stragglers:
            print(f"[telemetry] straggler hosts: {stragglers}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
