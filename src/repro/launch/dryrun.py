import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real train_step / serve_step with
ShapeDtypeStruct inputs (no allocation), compiles it for the production mesh
(16x16 single-pod, 2x16x16 multi-pod) on 512 forced host devices, and records:

  * compiled.memory_analysis()  -> bytes/device (proves it fits a v5e chip)
  * compiled.cost_analysis()    -> per-device HLO FLOPs / bytes accessed
  * collective traffic          -> parsed from the partitioned HLO
                                   (all-gather/all-reduce/reduce-scatter/
                                   all-to-all/collective-permute), split into
                                   intra-pod vs pod-crossing by replica-group
                                   span

Artifacts land in benchmarks/artifacts/dryrun/<mesh>/<arch>/<shape>.json —
benchmarks/roofline.py turns them into EXPERIMENTS.md §Roofline.

NOTE: the XLA_FLAGS line above MUST precede any jax-importing import.
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core import traffic
from repro.core.planner import RooflineReport, attention_plan
from repro.core.target import get_target, set_target
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.api import SHAPES
from repro.train import optimizer as opt_mod
from repro.train.loop import TrainConfig, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")

#: per-arch knobs: memory fitting (microbatches, int8 moments) + the
#: planner/§Perf choices (layout, MoE capacity factor). "dp" layout = batch
#: spans the model axis, weights FSDP-gathered at use — measured wins on the
#: small/medium dense archs (EXPERIMENTS.md §Perf); MoE archs need the model
#: axis for EP and keep "tp".
TRAIN_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "deepseek-v2-236b": dict(n_microbatches=16, quantized=True,
                             capacity_factor=1.25),
    "jamba-1.5-large-398b": dict(n_microbatches=8, quantized=True,
                                 capacity_factor=1.25),
    "gemma3-27b": dict(n_microbatches=8, quantized=False),
    "qwen3-moe-30b-a3b": dict(n_microbatches=8, quantized=False,
                              capacity_factor=1.25),
    "mistral-nemo-12b": dict(n_microbatches=8, quantized=False, layout="dp"),
    "yi-6b": dict(n_microbatches=8, quantized=False, layout="dp"),
    "qwen2.5-3b": dict(n_microbatches=8, quantized=False, layout="dp"),
    "qwen2-vl-2b": dict(n_microbatches=8, quantized=False, layout="dp"),
    "falcon-mamba-7b": dict(n_microbatches=8, quantized=False),
    "seamless-m4t-medium": dict(n_microbatches=4, quantized=False),
}


# -------------------------------------------------- flash traffic correction

def _visible_kv_elems(sq: int, skv: int, bq: int, bkv: int,
                      causal: bool, window: Optional[int]) -> int:
    """KV elements each Q block must stream, summed over Q blocks."""
    total = 0
    for i in range(-(-sq // bq)):
        hi = min(skv, (i + 1) * bq) if causal else skv
        lo = 0
        if window is not None:
            lo = max(0, i * bq - window)
        # round to block granularity (whole blocks are streamed)
        lo_b = (lo // bkv) * bkv
        hi_b = min(skv, -(-hi // bkv) * bkv)
        total += max(0, hi_b - lo_b)
    return total


def attn_traffic_correction(cfg, shape, cost_block: int) -> float:
    """Bytes to ADD to the measured cost-mode HBM traffic: the real Pallas
    plan uses smaller KV blocks (scores must fit VMEM), so KV re-reads exceed
    what the capped-trip cost lowering streamed. Exact block-count delta."""
    if shape.kind != "prefill" or cfg.n_heads == 0:
        return 0.0  # train_4k/decode lower the exact direct path
    sq = skv = shape.seq_len
    d = cfg.head_dim if not cfg.use_mla else (
        cfg.qk_nope_head_dim + cfg.qk_rope_head_dim + cfg.v_head_dim) // 2
    plan = attention_plan(sq, skv, d)
    delta = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.kind_for_layer(i)
        if kind.attn == "mamba":
            continue
        r_p = _visible_kv_elems(sq, skv, plan.block_q, plan.block_kv,
                                True, kind.window)
        r_c = _visible_kv_elems(sq, skv, cost_block, cost_block,
                                True, kind.window)
        hkv = max(cfg.n_kv_heads, 1)
        delta += shape.global_batch * hkv * 2 * d * 2 * (r_p - r_c)
    return max(delta, 0.0)


# ------------------------------------------------------------ input specs

def batch_shard_specs(batch: Any, mesh) -> Any:
    """Sharding for train/prefill batches: batch dim over (pod, data) —
    plus `model` under the DP-dominant layout."""
    axes = ("pod", "data", "model") if shd.layout() == "dp" \
        else ("pod", "data")
    dp = tuple(a for a in axes if a in mesh.axis_names)

    def spec(leaf):
        s = (dp,) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, shd.fix_spec_for(mesh, P(*s), leaf.shape))
    return jax.tree.map(spec, batch)


def decode_shard_specs(inputs: Any, mesh, *, batch: int) -> Any:
    """Decode-cell shardings: pooled KV (seq over `model`; batch over
    (pod,data) when it divides, else seq over everything)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    seq_axes = ("model",) if batch > 1 else (dp + ("model",))

    def spec_for(path_names, leaf):
        name = path_names[-1] if path_names else ""
        r = len(leaf.shape)
        if name in ("k", "v"):            # (rep, B, H, S, D)
            s = (None, dp, None, seq_axes, None)[-r:]
        elif name in ("ckv", "krope"):    # (rep, B, S, lora)
            s = (None, dp, seq_axes, None)[-r:]
        elif name == "conv":              # (rep, B, K-1, Di)
            s = (None, dp, None, "model")[-r:]
        elif name == "ssm":               # (rep, B, Di, Ds)
            s = (None, dp, "model", None)[-r:]
        elif name == "enc_out":           # (B, S, d)
            s = (dp, None, None)
        elif name == "tokens":
            s = (dp, None)
        else:
            s = (None,) * r
        return NamedSharding(mesh, shd.fix_spec_for(mesh, P(*s), leaf.shape))

    flat, tdef = jax.tree_util.tree_flatten_with_path(inputs)
    out = []
    for path, leaf in flat:
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        out.append(spec_for(names, leaf))
    return jax.tree_util.tree_unflatten(tdef, out)


# ------------------------------------------------------- HLO collective scan

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?,?\s*)+)"
    r"\s*(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s16": 2, "u16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "c64": 8, "u4": 1, "s4": 1}

#: bytes-on-wire multiplier per collective kind (ring algorithms)
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shapes_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _crosses_pod(line: str, pod_stride: int = 256) -> bool:
    m = _GROUPS_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")[:64]]
        return len(ids) > 1 and (max(ids) - min(ids)) >= pod_stride
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota groups [n_groups, group_size]<=[dims](T(perm)): the group walks
        # the minor dims of the (possibly transposed) device iota; it crosses
        # the pod iff the group's span covers the leading (pod) dim.
        n_groups, g_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        # stride of one step within a group in linear device id space
        permuted = [dims[i] for i in perm]
        # group dimension(s) are the trailing axes of the permuted iota
        span = 1
        trailing = 1
        for ax in reversed(range(len(permuted))):
            if trailing >= g_size:
                break
            trailing *= permuted[ax]
            # linear stride of this permuted axis in original id space
            orig_ax = perm[ax]
            stride = 1
            for j in range(orig_ax + 1, len(dims)):
                stride *= dims[j]
            span = max(span, stride * (min(trailing, g_size) - 1)
                       if permuted[ax] > 1 else span)
        return span >= pod_stride
    return False


def collect_collectives(hlo_text: str, multi_pod: bool,
                        top_k: int = 8) -> Dict[str, Any]:
    """Sum wire bytes of every collective in the partitioned HLO.

    bf16-promotion correction: the CPU backend cannot execute bf16 dots, so
    XLA:CPU re-promotes bf16 operands to f32 *after* our bf16 cast — the
    gathered weight shows as f32 with a ``convert_convert_fusion`` operand
    (master f32 -> bf16 cast -> CPU f32 promotion). On the TPU target the
    gather stays bf16, so such ops are counted at half their f32 bytes.
    Both raw and corrected sums are recorded.
    """
    intra = 0.0
    cross = 0.0
    raw = 0.0
    counts: Dict[str, int] = {}
    biggest = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        nbytes = _shape_bytes(m.group(2)) * _WIRE_FACTOR[kind]
        raw += nbytes
        # CPU f32-promotion signature: operand is a double convert
        tail = line[m.end():]
        if "f32[" in m.group(2) and "convert_convert" in tail.split(")")[0]:
            nbytes *= 0.5
        counts[kind] = counts.get(kind, 0) + 1
        biggest.append((nbytes, kind, m.group(2).strip()[:80]))
        if multi_pod and _crosses_pod(line):
            cross += nbytes
        else:
            intra += nbytes
    biggest.sort(reverse=True)
    return {"intra_bytes": intra, "cross_pod_bytes": cross,
            "raw_bytes_uncorrected": raw, "counts": counts,
            "top": [dict(bytes=b, kind=k, shape=s)
                    for b, k, s in biggest[:top_k]]}


# ---------------------------------------------------------------- dry run

def _serving_param_specs(params_s):
    """Serving stores weights in bf16 (the deploy format): cast the >=2-D
    f32 param specs, keeping the numerics-sensitive ones f32 (same exclusion
    list as the training-side compute cast)."""
    from repro.train.loop import _F32_PARAM_NAMES
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_s)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path).lower()
        if (len(leaf.shape) >= 2 and leaf.dtype == jnp.float32
                and not any(n in name for n in _F32_PARAM_NAMES)):
            leaf = jax.ShapeDtypeStruct(leaf.shape, jnp.bfloat16)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(tdef, out)


def _lower_cell(model, shape, mesh, ov, *, n_micro_override=None):
    """Build + lower the cell's step function under the ambient mesh."""
    cfg = model.cfg
    if shape.kind == "train":
        tcfg = TrainConfig(
            opt=opt_mod.OptConfig(quantized_moments=ov.get("quantized", False)),
            n_microbatches=(n_micro_override if n_micro_override is not None
                            else ov.get("n_microbatches", 1)))
        step_fn = make_train_step(model, tcfg)
        params_s = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        opt_s = jax.eval_shape(lambda: opt_mod.init_opt_state(params_s, tcfg.opt))
        batch_s = model.input_specs(shape)
        jitted = jax.jit(step_fn,
                         in_shardings=(shd.named_shardings(params_s, mesh),
                                       shd.named_shardings(opt_s, mesh),
                                       batch_shard_specs(batch_s, mesh)),
                         donate_argnums=(0, 1))
        return jitted.lower(params_s, opt_s, batch_s)
    if shape.kind == "prefill":
        params_s = _serving_param_specs(
            jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))))
        batch_s = model.input_specs(shape)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        jitted = jax.jit(prefill_fn,
                         in_shardings=(shd.named_shardings(params_s, mesh),
                                       batch_shard_specs(batch_s, mesh)))
        return jitted.lower(params_s, batch_s)
    inputs = model.input_specs(shape)
    params_s = _serving_param_specs(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))))
    i_shard = decode_shard_specs(inputs, mesh, batch=shape.global_batch)

    def serve_step(params, tokens, state, cache_len):
        return model.decode_step(params, tokens, state, cache_len)

    jitted = jax.jit(serve_step,
                     in_shardings=(shd.named_shardings(params_s, mesh),
                                   i_shard["tokens"], i_shard["state"],
                                   i_shard["cache_len"]),
                     donate_argnums=(2,))
    return jitted.lower(params_s, inputs["tokens"], inputs["state"],
                        inputs["cache_len"])


def _scaled_cfg(cfg, k: int):
    """Config with the scanned body at k repetitions (head/tail intact).
    Returns (cfg_k, full_reps). Quantities linear in body reps extrapolate
    exactly: Q(n) = Q(1) + (Q(2) - Q(1)) * (n - 1)."""
    import dataclasses as dc
    groups = cfg.layer_groups()
    body = next(g for g in groups if g.name == "blocks")
    period = len(body.pattern)
    extra = cfg.n_layers - body.n_layers
    repl = dict(n_layers=extra + k * period)
    if cfg.n_encoder_layers:
        repl["n_encoder_layers"] = k
    return dc.replace(cfg, **repl), body.n_repeat


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                verbose: bool = True) -> Dict[str, Any]:
    try:
        return _dryrun_cell(arch, shape_name, multi_pod=multi_pod,
                            verbose=verbose)
    finally:
        os.environ.pop("REPRO_LAYOUT", None)


def _dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                 verbose: bool = True) -> Dict[str, Any]:
    """Per cell:

    A. *memory* lowering — full depth, scans rolled (while-body buffers
       counted right), production microbatching: memory_analysis is the
       fits-on-chip proof; this is THE required lower().compile() pass.
    B. *cost* lowerings — REPRO_COST_MODE=1 (scans unrolled so
       HloCostAnalysis sees every body), at body-depth k=1 and k=2, then
       exact linear extrapolation to full depth (scan groups are homogeneous,
       so FLOPs and collective bytes are affine in body repetitions).
    The roofline memory term comes from the analytic TPU traffic model
    (core/traffic.py) — CPU-backend 'bytes accessed' is recorded but not
    used (CPU fusion overstates TPU HBM traffic by ~75x, see DESIGN.md).
    """
    cfg = get_config(arch)
    ov_pre = TRAIN_OVERRIDES.get(arch, {})
    if ov_pre.get("capacity_factor"):
        import dataclasses as _dc
        cfg = _dc.replace(cfg, capacity_factor=ov_pre["capacity_factor"])
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    if shape_name not in model.runnable_shapes():
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch: no sub-quadratic 500k path"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    ov = dict(TRAIN_OVERRIDES.get(arch, {}))
    n_chips = 512 if multi_pod else 256
    mesh_dims = traffic.MeshDims(pod=2 if multi_pod else 1, data=16, model=16)
    # planner-chosen activation layout: train cells of small dense models run
    # DP-dominant (model axis joins DP; weights gathered at use). Only viable
    # when the global batch covers every device — otherwise the model columns
    # compute redundantly (e.g. batch 256 on the 512-chip multi-pod mesh)
    # and the cell stays TP.
    if (shape.kind == "train" and ov.get("layout") == "dp"
            and shape.global_batch % n_chips == 0):
        os.environ["REPRO_LAYOUT"] = "dp"
    # decode cells: weights resident (data-replicated dense, 2D experts with
    # token-gathering partial-K MoE) — gather-at-use would dwarf the tokens
    if shape.kind == "decode":
        os.environ["REPRO_LAYOUT"] = "infer"
    # microbatch rows must cover the whole DP extent, else the per-microbatch
    # batch dim cannot shard across it (2x16x16: n_micro <= 8; dp layout: 1)
    dp = mesh_dims.dp
    if os.environ.get("REPRO_LAYOUT") == "dp":
        dp *= mesh_dims.model
    if shape.kind == "train":
        max_micro = max(shape.global_batch // dp, 1)
        ov["n_microbatches"] = min(ov.get("n_microbatches", 1), max_micro)

    # --- A: memory lowering (full depth) ------------------------------------
    t0 = time.time()
    with shd.use_mesh(mesh):
        compiled_mem = _lower_cell(model, shape, mesh, ov).compile()
    t_mem = time.time() - t0
    mem = compiled_mem.memory_analysis()
    mem_rec = {k: getattr(mem, k, None) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")}

    # --- B: cost lowerings at k=1,2 + exact extrapolation --------------------
    t0 = time.time()
    os.environ["REPRO_COST_MODE"] = "1"
    q = {}
    try:
        for k in (1, 2):
            cfg_k, full_reps = _scaled_cfg(cfg, k)
            model_k = build_model(cfg_k)
            with shd.use_mesh(mesh):
                compiled_k = _lower_cell(model_k, shape, mesh, ov,
                                         n_micro_override=1).compile()
            cost_k = compiled_k.cost_analysis()
            coll_k = collect_collectives(compiled_k.as_text(), multi_pod)
            q[k] = dict(flops=float(cost_k.get("flops", 0.0)),
                        bytes=float(cost_k.get("bytes accessed", 0.0)),
                        intra=coll_k["intra_bytes"],
                        cross=coll_k["cross_pod_bytes"],
                        counts=coll_k["counts"])
    finally:
        os.environ.pop("REPRO_COST_MODE", None)
    t_cost = time.time() - t0

    def extrap(key):
        return q[1][key] + (q[2][key] - q[1][key]) * (full_reps - 1)

    flops = extrap("flops")
    bytes_acc = extrap("bytes")
    intra = extrap("intra")
    cross = extrap("cross")

    # analytic corrections / terms
    n_micro = ov.get("n_microbatches", 1) if shape.kind == "train" else 1
    total_params, active_params = cfg.param_count()
    regather = ((n_micro - 1) * 2.0 * total_params / mesh_dims.model
                if n_micro > 1 else 0.0)
    hbm = traffic.step_traffic(cfg, kind=shape.kind, seq_len=shape.seq_len,
                               global_batch=shape.global_batch,
                               mesh=mesh_dims, n_micro=n_micro)
    resid = traffic.hbm_residency(cfg, kind=shape.kind, seq_len=shape.seq_len,
                                  global_batch=shape.global_batch,
                                  mesh=mesh_dims,
                                  quantized_moments=ov.get("quantized", False))

    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    if shape.kind == "train":
        model_flops = cfg.model_flops(tokens)
    else:
        model_flops = 2.0 * active_params * tokens

    target = get_target()
    assert target.kind == "tpu", f"dry-run needs a TPU target, got {target.name}"
    report = RooflineReport(
        name=f"{arch}/{shape_name}", n_chips=n_chips,
        hlo_flops=flops * n_chips,          # cost_analysis is per-device
        hlo_bytes=hbm["total"] * n_chips,   # analytic TPU traffic model
        collective_bytes=(intra + regather) * n_chips,
        pod_collective_bytes=cross * n_chips,
        model_flops=model_flops, profile=target.profile)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "target": target.name,
        "status": "ok",
        "compile_mem_s": round(t_mem, 1), "compile_cost_s": round(t_cost, 1),
        "n_microbatches": n_micro,
        "memory": mem_rec,
        "residency_model": resid,
        "cost": {"flops_per_device": flops,
                 "xla_cpu_bytes_per_device": bytes_acc,
                 "traffic_model_bytes_per_device": hbm,
                 "micro_regather_per_device": regather},
        "collectives": {"intra_bytes": intra, "cross_pod_bytes": cross,
                        "counts": q[2]["counts"]},
        "roofline": report.to_dict(),
    }
    if verbose:
        tmp = mem_rec.get("temp_size_in_bytes") or 0
        arg = mem_rec.get("argument_size_in_bytes") or 0
        print(f"[{rec['mesh']}] {arch}/{shape_name}: "
              f"args {arg/2**30:.2f} + temp {tmp/2**30:.2f} GiB/dev, "
              f"{flops/1e9:.1f} GF/dev, useful={report.useful_flops_ratio:.2f}, "
              f"bound={report.bound}, roofline={report.roofline_fraction:.2f}, "
              f"compile {t_mem:.0f}+{t_cost:.0f}s", flush=True)
    return rec


def artifact_path(mesh_tag: str, arch: str, shape: str) -> str:
    d = os.path.abspath(os.path.join(ARTIFACT_DIR, mesh_tag, arch))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{shape}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--target", default=None,
                    help="hardware target name from the registry "
                         "(default: current target, e.g. tpu-v5e)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.target:
        set_target(args.target)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for multi in meshes:
        tag = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape in shapes:
                path = artifact_path(tag, arch, shape)
                if os.path.exists(path) and not args.force:
                    continue
                try:
                    rec = dryrun_cell(arch, shape, multi_pod=multi)
                except Exception as e:  # record failures as artifacts too
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": tag,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
