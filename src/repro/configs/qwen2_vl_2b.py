"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. The vision frontend
is a stub (256 precomputed patch embeddings prefix the text tokens).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
    mrope=True, mrope_sections=(16, 24, 24), frontend_len=256,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen2-vl-2b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, qkv_bias=True, rope_theta=1e6,
    mrope=True, mrope_sections=(2, 3, 3), frontend_len=8,
    tie_embeddings=True,
)
