"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L d_model=5120 128H d_ff=1536(expert) vocab=102400. MLA: q_lora=1536,
kv_lora=512, qk_nope=128, qk_rope=64, v=128. First layer dense (d_ff=12288,
per the HF config). ~236B total / ~21B active (validated in tests).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=12288, vocab_size=102400,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    first_dense=1,
)

REDUCED = ModelConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=256, vocab_size=512,
    use_mla=True, q_lora_rank=48, kv_lora_rank=32,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=64,
    first_dense=1,
)
