"""gemma3-27b [dense] — 5:1 local:global, 128k [hf:google/gemma-3-1b-pt].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144. Sliding window 1024
on local layers; every 6th layer global. Tied embeddings. Runs long_500k
(local attention is sub-quadratic; globals are 1-in-6).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    window=1024, local_global_ratio=5, rope_theta=1e6,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma3-27b-smoke", family="dense",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    window=16, local_global_ratio=5, rope_theta=1e6,
    tie_embeddings=True,
)
