"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) d_ff=768(expert) vocab=151936.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, rope_theta=1e6,
    n_experts=128, n_shared_experts=0, top_k=8, moe_d_ff=768,
)

REDUCED = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512, rope_theta=1e6,
    n_experts=8, n_shared_experts=0, top_k=2, moe_d_ff=64,
)
