"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206. Realized as 12
encoder + 12 decoder layers (DESIGN.md §Shape-cell skip rules); the speech frontend is a stub
providing precomputed frame embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_encoder_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
)

REDUCED = ModelConfig(
    name="seamless-m4t-medium-smoke", family="encdec",
    n_layers=2, n_encoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
)
