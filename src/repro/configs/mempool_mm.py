"""The paper's own workload: the tiled matrix-multiplication study (§VI).

Not a transformer — this config parameterizes the MemPool matmul experiment
(M, capacities, bandwidths) exactly as published, and is what
``examples/mempool_matmul.py`` and the Fig. 6-9 benchmarks consume.
"""

import dataclasses
from typing import Tuple

from repro.core.hw_profiles import MiB
from repro.core.perf_model import PAPER_BANDWIDTHS, PAPER_M


@dataclasses.dataclass(frozen=True)
class MempoolMatmulConfig:
    m: int = PAPER_M
    capacities_mib: Tuple[int, ...] = (1, 2, 4, 8)
    bandwidths: Tuple[float, ...] = PAPER_BANDWIDTHS
    word_bytes: int = 4
    flows: Tuple[str, ...] = ("2D", "3D")


CONFIG = MempoolMatmulConfig()
