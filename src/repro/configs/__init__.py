"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

#: arch id -> module name
_MODULES: Dict[str, str] = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "yi-6b": "yi_6b",
    "gemma3-27b": "gemma3_27b",
    "qwen2.5-3b": "qwen2_5_3b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _mod(arch).REDUCED
