"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Pattern period 8: attention at offset 4, mamba elsewhere; MoE every 2nd
layer. 398B total / ~94B active (validated in tests against param_count()).
Runs long_500k (SSM state is O(1)).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    n_experts=16, top_k=2, moe_d_ff=24576, moe_period=2,
    attn_period=8, attn_offset=4,
    ssm_d_state=16, ssm_expand=2, ssm_conv=4,
)

REDUCED = ModelConfig(
    name="jamba-1.5-large-398b-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    n_experts=4, top_k=2, moe_d_ff=128, moe_period=2,
    attn_period=8, attn_offset=4,
    ssm_d_state=8, ssm_expand=2, ssm_conv=4,
)
