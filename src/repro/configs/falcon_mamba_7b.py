"""falcon-mamba-7b [ssm] — mamba1 arch [arXiv:2410.05355].

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16. Attention-free:
the planner's attention tiling is inapplicable; the same capacity rule sizes
the scan chunk instead (DESIGN.md §Shape-cell skip rules). Runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024,
    ssm_d_state=16, ssm_expand=2, ssm_conv=4,
)

REDUCED = ModelConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=512,
    ssm_d_state=8, ssm_expand=2, ssm_conv=4,
)
