"""mistral-nemo-12b [dense] — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072. head_dim=128
(explicit; 5120/32 != 128 in this architecture).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, rope_theta=1e6,
)

REDUCED = ModelConfig(
    name="mistral-nemo-12b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=24,
    d_ff=128, vocab_size=512, rope_theta=1e6,
)
