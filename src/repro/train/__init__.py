"""Training substrate."""
