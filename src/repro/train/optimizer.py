"""AdamW with optional 8-bit (blockwise-quantized) moments.

Built from scratch (no optax in this environment). The int8 moments are the
memory-side "distributed-optimization trick": at 236B-scale the Adam moments
dominate per-chip HBM; blockwise absmax int8 storage cuts them 4x — the same
"more capacity in the same footprint" play as the paper's memory die.
Quantization error per step is bounded by the block absmax / 127 and is
empirically loss-neutral (tests/test_optimizer.py compares convergence).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantized_moments: bool = False


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


# ------------------------------------------------------- int8 moment codec
# The int8 payload keeps the PARAMETER'S OWN SHAPE (blocking is over the last
# dim only), so the FSDP/TP PartitionSpecs of the parameter apply verbatim to
# its quantized moments — no resharding in the optimizer step.

def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Last-dim blockwise absmax int8. Returns (q int8, scale f32).

    q has x's shape; scale has shape x.shape[:-1] + (ceil(last/QBLOCK),).
    """
    last = x.shape[-1] if x.ndim else 1
    xr = x.reshape(x.shape or (1,))
    nb = -(-last // QBLOCK)
    pad = nb * QBLOCK - last
    xp = jnp.pad(xr, [(0, 0)] * (xr.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*xr.shape[:-1], nb, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0           # (..., nb)
    rep = jnp.repeat(scale, QBLOCK, axis=-1)[..., :last]
    q = jnp.round(xr / jnp.maximum(rep, 1e-20)).astype(jnp.int8)
    return q.reshape(x.shape), scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    last = shape[-1] if len(shape) else 1
    qr = q.reshape(q.shape or (1,))
    rep = jnp.repeat(scale, QBLOCK, axis=-1)[..., :last]
    return (qr.astype(jnp.float32) * rep).reshape(shape)


class QTensor(NamedTuple):
    q: jax.Array       # int8, same shape as the parameter
    scale: jax.Array   # f32, (..., ceil(last/QBLOCK))


def _enc(x: jax.Array, quantized: bool):
    if not quantized:
        return x
    q, s = _quantize(x)
    return QTensor(q, s)


def _dec(t, shape, quantized: bool) -> jax.Array:
    if not quantized:
        return t
    return _dequantize(t.q, t.scale, shape)


# ----------------------------------------------------------------- adamw

def init_opt_state(params: Any, cfg: OptConfig) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: _enc(jnp.zeros(p.shape, jnp.float32),
                                        cfg.quantized_moments), params)
    zeros2 = jax.tree.map(lambda p: _enc(jnp.zeros(p.shape, jnp.float32),
                                         cfg.quantized_moments), params)
    return {"m": zeros, "v": zeros2, "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: OptConfig) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    q = cfg.quantized_moments

    def upd(p, g, m_t, v_t):
        g = g.astype(jnp.float32) * scale
        m = _dec(m_t, p.shape, q)
        v = _dec(v_t, p.shape, q)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        decay = cfg.weight_decay * p if p.ndim >= 2 else 0.0  # no wd on norms
        newp = p - lr * (upd + decay)
        return newp.astype(p.dtype), _enc(m, q), _enc(v, q)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])   # QTensor subtrees stay intact
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
