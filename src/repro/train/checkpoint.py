"""Sharded, atomic, async, mesh-agnostic checkpoints (msgpack + zstd/zlib).

Fault-tolerance contract:
  * **atomic**: a step directory appears only via os.rename of a finished tmp
    dir — a crash mid-save can never corrupt the latest checkpoint;
  * **resumable**: manifest carries the step; the data pipeline is stateless
    in step, so restart-resume is bit-exact;
  * **elastic**: arrays are stored *logically* (full shape, no mesh layout);
    restore() applies whatever NamedShardings the *new* mesh prescribes, so a
    job can come back on a different pod count / mesh shape;
  * **async**: save() hands the device_get'ed arrays to a writer thread; the
    train loop keeps stepping (checkpoint I/O overlaps compute — the paper's
    phase overlap, applied to state persistence);
  * **keep-k**: old steps pruned after a successful save.
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np

try:  # preferred codec; stdlib zlib keeps checkpoints working without it
    import zstandard
except ImportError:
    zstandard = None

_EXEC = cf.ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")


def _make_compressor():
    """(codec name, compress fn) — one compressor reused across all leaves."""
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3)
        return "zstd", comp.compress
    return "zlib", lambda data: zlib.compress(data, 3)


def _decompress(codec: str, blob: bytes) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but the zstandard package "
                "is not installed (pip install zstandard)")
        return zstandard.ZstdDecompressor().decompress(blob)
    if codec == "zlib":
        return zlib.decompress(blob)
    if codec == "raw":
        return blob
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _serialize_tree(tree: Any) -> bytes:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    codec, compress = _make_compressor()
    payload = {}
    for path, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        data = compress(arr.tobytes())
        payload[_path_str(path)] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "codec": codec,
            "data": data,
        }
    return msgpack.packb(payload, use_bin_type=True)


def _deserialize_leaves(blob: bytes) -> Dict[str, np.ndarray]:
    import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)
    payload = msgpack.unpackb(blob, raw=False)
    out = {}
    for path, rec in payload.items():
        dtype = np.dtype(rec["dtype"])
        # records from before the codec field were always zstd
        buf = _decompress(rec.get("codec", "zstd"), rec["data"])
        out[path] = np.frombuffer(buf, dtype=dtype).reshape(rec["shape"])
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[cf.Future] = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, state: Dict[str, Any], *,
             blocking: bool = False, extra: Optional[Dict] = None) -> None:
        self.wait()  # at most one in-flight save
        # device_get on the main thread (arrays may be donated/mutated next step)
        blob = _serialize_tree(state)
        manifest = json.dumps({"step": step, **(extra or {})})

        def write():
            tmp = os.path.join(self.dir, f".tmp-{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "state.msgpack"), "wb") as f:
                f.write(blob)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                f.write(manifest)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._prune()

        self._pending = _EXEC.submit(write)
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of NamedShardings (same structure) —
        this is the elastic path: the stored logical arrays are placed onto
        the *current* mesh regardless of the mesh they were saved from.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "state.msgpack"), "rb") as f:
            leaves = _deserialize_leaves(f.read())
        flat, tdef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(flat))
        out = []
        for (path, tmpl), shd in zip(flat, shard_flat):
            arr = leaves[_path_str(path)]
            assert tuple(arr.shape) == tuple(tmpl.shape), (path, arr.shape, tmpl.shape)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return step, jax.tree_util.tree_unflatten(tdef, out)
