"""Train step factory: mixed precision, grad-accumulation scan, remat,
optional compressed cross-pod gradient sync.

Memory layout per device (the capacity budget the planner reasons about):
f32 master params + moments (FSDP-sharded), bf16 compute copies (transient),
one superblock of activations (remat) x microbatch. Microbatch count is the
knob that trades activation stash against per-step launch overhead — the
direct analogue of the paper's tile-size/static-overhead tradeoff.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import collectives
from repro.distributed import sharding as shd
from repro.models.api import Model
from repro.train import optimizer as opt_mod


#: params kept in f32 for compute even under mixed precision (numerics):
#: norm scales (1-D anyway), SSM decay logs, dt bias, router logits.
_F32_PARAM_NAMES = ("a_log", "scale", "dt_bias", "router")


def _cast_params_for_compute(params):
    """bf16 compute copies, CONSTRAINED to the parameter shardings.

    Pinning the cast output to the param's own (FSDP x TP) spec makes GSPMD
    place the FSDP all-gather AFTER the convert — weights travel the wire in
    bf16, halving gather bytes vs gathering f32 then casting (§Perf,
    qwen2.5/h3). Numerics are unchanged: layers already cast weights to
    bf16 at use; this moves the cast before the gather."""
    mesh = shd.ambient_mesh()
    have_mesh = mesh is not None and bool(mesh.axis_names)
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, p in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path).lower()
        keep_f32 = (p.ndim < 2 or p.dtype != jnp.float32
                    or any(n in name for n in _F32_PARAM_NAMES))
        if keep_f32:
            out.append(p)
            continue
        pb = p.astype(jnp.bfloat16)
        if have_mesh:
            pb = jax.lax.with_sharding_constraint(
                pb, shd.spec_for_param(name, p.shape, mesh))
        out.append(pb)
    return jax.tree_util.tree_unflatten(tdef, out)


def _constrain_grads_like_params(grads, params):
    """Pin gradient shardings to the parameter shardings at the point of
    production, so GSPMD lowers the DP gradient reduction as a
    reduce-scatter onto the FSDP shards (half the wire bytes of the
    all-reduce it otherwise coalesces). §Perf hypothesis log, qwen2.5/h2."""
    mesh = shd.ambient_mesh()
    if mesh is None or not mesh.axis_names:
        return grads
    specs = shd.param_specs(params, mesh)
    return jax.tree.map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, specs)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_mod.OptConfig = opt_mod.OptConfig()
    n_microbatches: int = 1
    remat: bool = True
    compress_pod_grads: bool = False  # int8+EF gradient sync across pods


def _split_micro(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    """(B, ...) -> (n, B/n, ...) for the accumulation scan."""
    def f(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(f, batch)


def make_train_step(model: Model, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` arrays are (global_batch, ...); sharding comes from the caller's
    in_shardings / ambient mesh.
    """

    def loss_fn(params, micro_batch):
        params_c = _cast_params_for_compute(params)
        loss, metrics = model.loss(params_c, micro_batch, remat=tcfg.remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.n_microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, _constrain_grads_like_params(grads, params)
        micro = _split_micro(batch, tcfg.n_microbatches)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zero = _constrain_grads_like_params(zero, params)

        def acc_step(carry, mb):
            g_acc, l_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            grads = _constrain_grads_like_params(grads, params)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 g_acc, grads)
            return (g_acc, l_acc + loss), metrics

        (g_acc, l_sum), metrics = jax.lax.scan(
            acc_step, (zero, jnp.zeros(())), micro)
        n = tcfg.n_microbatches
        grads = jax.tree.map(lambda g: g / n, g_acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return l_sum / n, metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        new_params, new_opt, opt_metrics = opt_mod.adamw_update(
            params, grads, opt_state, tcfg.opt)
        metrics = dict(metrics, **opt_metrics, total_loss=loss)
        return new_params, new_opt, metrics

    return train_step


def make_compressed_train_step(model: Model, tcfg: TrainConfig, mesh):
    """Variant for multi-pod meshes: per-pod gradients are computed under
    GSPMD (data/model stay auto-sharded), then synced across the `pod` axis
    with int8 + error feedback inside a shard_map restricted to `pod`.

    State gains an ``err`` tree (error-feedback residuals, pod-local).
    """
    from jax.sharding import PartitionSpec as P
    n_pods = mesh.shape["pod"]

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=tcfg.remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, err, batch):
        def per_pod(params, err, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            grads, new_err = collectives.compressed_grad_sync(
                grads, err, "pod", n_pods)
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.lax.pmean(metrics, "pod")
            return loss, metrics, grads, new_err

        pspec = jax.tree.map(lambda _: P(), params)
        loss, metrics, grads, new_err = shd.shard_map(
            per_pod, mesh=mesh,
            in_specs=(pspec, pspec, P("pod")),
            out_specs=(P(), P(), pspec, pspec),
            axis_names={"pod"},
        )(params, err, batch)
        new_params, new_opt, opt_metrics = opt_mod.adamw_update(
            params, grads, opt_state, tcfg.opt)
        metrics = dict(metrics, **opt_metrics, total_loss=loss)
        return new_params, new_opt, new_err, metrics

    return train_step
