"""Fault tolerance: heartbeats, straggler detection, restart policy,
failure injection.

At 1000+ nodes the failure model is: (a) hard node loss -> restart from the
latest atomic checkpoint on a (possibly re-sliced) mesh; (b) stragglers ->
detect from step-time telemetry and either exclude the host at the next
re-slice or lower its data shard. This module is the host-side control plane
for both; it is deliberately runtime-agnostic (pure data structures +
policies) so it is fully unit-testable without hardware, and the launcher
wires it to the real loop.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-host liveness from heartbeat timestamps."""

    timeout_s: float = 60.0
    _last: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_hosts(now)


@dataclasses.dataclass
class StragglerDetector:
    """Flags hosts whose step time is a robust outlier.

    Uses the median + k*MAD rule over a sliding window — stable against the
    non-Gaussian tail of real step-time distributions.
    """

    window: int = 32
    k_mad: float = 5.0
    min_samples: int = 8
    _hist: Dict[int, List[float]] = dataclasses.field(default_factory=dict)

    def record(self, host: int, step_time_s: float) -> None:
        h = self._hist.setdefault(host, [])
        h.append(step_time_s)
        if len(h) > self.window:
            del h[0]

    def _median(self, xs: Sequence[float]) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def stragglers(self) -> List[int]:
        per_host = {h: self._median(v) for h, v in self._hist.items()
                    if len(v) >= self.min_samples}
        if len(per_host) < 2:
            return []
        meds = list(per_host.values())
        med = self._median(meds)
        mad = self._median([abs(x - med) for x in meds]) or 1e-9
        return sorted(h for h, m in per_host.items()
                      if m > med + self.k_mad * mad)


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Decides the recovery action after a failure event."""

    max_restarts: int = 100
    backoff_base_s: float = 5.0
    backoff_cap_s: float = 300.0

    def next_action(self, n_restarts: int, dead_hosts: Sequence[int],
                    n_hosts: int) -> Tuple[str, float]:
        """Returns (action, backoff_s); action in
        {"restart", "reslice", "abort"}."""
        if n_restarts >= self.max_restarts:
            return "abort", 0.0
        backoff = min(self.backoff_cap_s,
                      self.backoff_base_s * (2.0 ** min(n_restarts, 10)))
        # losing hosts permanently -> restart on a smaller (elastic) mesh
        if dead_hosts and len(dead_hosts) >= max(1, n_hosts // 16):
            return "reslice", backoff
        return "restart", backoff


def elastic_mesh_shape(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid fitting the surviving device count.

    Keeps `model` fixed (TP degree is architectural) and shrinks `data` —
    checkpoints are mesh-agnostic so the optimizer state resharding is free.
    """
    data = n_devices // model_parallel
    if data < 1:
        raise ValueError(f"{n_devices} devices cannot host TP={model_parallel}")
    return data, model_parallel


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for integration tests / drills."""

    fail_at_steps: Tuple[int, ...] = ()
    kind: str = "crash"          # "crash" | "hang" | "slow"

    def check(self, step: int) -> Optional[str]:
        return self.kind if step in self.fail_at_steps else None
