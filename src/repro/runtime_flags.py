"""Process-level lowering flags (read dynamically, set by the dry-run).

REPRO_COST_MODE=1 switches the model to a *cost-accurate* lowering: every
`lax.scan` is fully unrolled (XLA's HloCostAnalysis counts while bodies once,
not x trip-count) and blockwise attention uses capped trip counts. Used for
the roofline's FLOPs/bytes/collective measurements; the default (rolled)
lowering is used for memory analysis, where while-body buffers are counted
correctly and HLO size stays flat in depth.
"""

from __future__ import annotations

import os


def cost_mode() -> bool:
    return os.environ.get("REPRO_COST_MODE") == "1"


def scan_unroll(length: int) -> int:
    return length if cost_mode() else 1


def cost_attn_block() -> int:
    return int(os.environ.get("REPRO_COST_ATTN_BLOCK", "8192"))


def target_name() -> str | None:
    """Hardware-target override for repro.core.target.get_target()."""
    return os.environ.get("REPRO_TARGET") or None
