"""Sharding rules: FSDP x TP x EP over the hierarchical mesh.

MemPool's locality principle, at pod scale: the `model` axis (intra-pod ICI,
the "group" level) carries the high-traffic tensor-parallel and
expert-parallel collectives; the `data` axis carries FSDP parameter gathers
and gradient reduce-scatters; the `pod` axis (the "cluster" level,
lowest-bandwidth point-to-point links) carries only data-parallel gradient
reductions, optionally int8-compressed.

Rules are divisibility-aware: a dim that does not divide its mesh axis falls
back to replication (e.g. 4 KV heads on a 16-way model axis).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


# ---------------------------------------------------------------------------
# jax version compatibility. jax.sharding.AxisType, jax.set_mesh and
# jax.sharding.get_abstract_mesh only exist on newer jax; these shims keep a
# single code path across versions (the seed's 42-failure AttributeError
# storm on jax 0.4.x came from calling them unconditionally).
# ---------------------------------------------------------------------------


def axis_types_kwargs(n_axes: int, explicit: bool = False) -> Dict[str, Any]:
    """``axis_types=`` kwargs for jax.make_mesh, or {} where unsupported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    kind = axis_type.Explicit if explicit else axis_type.Auto
    return {"axis_types": (kind,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Optional[Sequence] = None,
              explicit: bool = False) -> jax.sharding.Mesh:
    """jax.make_mesh with axis_types only where the running jax supports it."""
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices,
                         **axis_types_kwargs(len(axes), explicit))


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager activating ``mesh`` as the ambient mesh."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh   # older jax: Mesh itself is the context manager


try:
    shard_map = jax.shard_map
except AttributeError:  # older jax: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(f, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        axis_names = kwargs.pop("axis_names", None)
        if axis_names is not None:  # old spelling: auto = the complement
            mesh_axes = frozenset(kwargs["mesh"].axis_names)
            kwargs["auto"] = mesh_axes - frozenset(axis_names)
        return _experimental_shard_map(f, **kwargs)


def ambient_mesh():
    """The ambient (context/thread-local) mesh, or None outside any."""
    get_abs = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abs is not None:
        return get_abs()
    try:  # older jax: the pjit-era thread-local physical mesh
        from jax._src import mesh as _mesh_lib
        env_mesh = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return None
    return None if env_mesh.empty else env_mesh

#: batch-dimension sharding: span the pod axis too (multi-pod data
#: parallelism). shard()/_fix_spec drop axes absent from the ambient mesh,
#: so single-pod meshes see plain ("data",).
BATCH = (POD_AXIS, DATA_AXIS)

#: DP-dominant layout: the batch dim additionally spans `model`, hidden dims
#: replicate. Chosen by the planner for models whose TP activation
#: all-reduces would dominate the step (small dense models — the paper's
#: "co-explore capacity and interconnect placement" applied to parallelism).
BATCH_ALL = (POD_AXIS, DATA_AXIS, MODEL_AXIS)


def layout() -> str:
    """Activation layout: "tp" (model axis partitions hidden dims) or "dp"
    (model axis joins data parallelism; weights FSDP-gathered at use).
    Process-level, read at trace time — set by the launcher/dry-run."""
    import os
    return os.environ.get("REPRO_LAYOUT", "tp")


def _apply_layout(spec: Tuple) -> Tuple:
    if layout() != "dp":
        return spec
    out = []
    for names in spec:
        if names == BATCH:
            out.append(BATCH_ALL)
        elif names == MODEL_AXIS:
            out.append(None)                  # hidden dims replicate
        elif isinstance(names, tuple):
            kept = tuple(n for n in names if n != MODEL_AXIS)
            out.append(kept or None)
        else:
            out.append(names)
    return tuple(out)


def axis_size(mesh, name: str) -> int:
    try:
        return mesh.shape[name]
    except (KeyError, TypeError):
        return 1


def shard(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that no-ops without an ambient mesh.

    Axis names absent from the mesh are dropped from the spec; dims that do
    not divide the axis size are replicated.
    """
    mesh = ambient_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    fixed = _fix_spec(_apply_layout(tuple(spec)), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def _fix_spec(spec: Tuple, shape: Tuple[int, ...], mesh) -> Tuple:
    fixed = []
    for i, names in enumerate(spec):
        if names is None:
            fixed.append(None)
            continue
        names_t = names if isinstance(names, tuple) else (names,)
        names_t = tuple(n for n in names_t if n in mesh.axis_names)
        # greedy prefix: keep the longest leading run of axes whose product
        # divides the dim (e.g. batch=256 over (pod,data,model)=512 shards
        # over (pod,data)=32 instead of replicating entirely)
        kept = []
        prod = 1
        for n in names_t:
            size = axis_size(mesh, n)
            if shape[i] % (prod * size) == 0:
                kept.append(n)
                prod *= size
            else:
                break
        if not kept:
            fixed.append(None)
        else:
            fixed.append(tuple(kept) if len(kept) > 1 else kept[0])
    # pad/trim to rank
    fixed += [None] * (len(shape) - len(fixed))
    return tuple(fixed[:len(shape)])


def fix_spec_for(mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Public divisibility fixer for out-of-trace use (e.g. input shardings)."""
    return P(*_fix_spec(tuple(spec), shape, mesh))


def model_axis_size(mesh=None) -> int:
    """Size of the `model` axis on ``mesh`` (ambient if None); 1 without one."""
    if mesh is None:
        mesh = ambient_mesh()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return 1
    return axis_size(mesh, MODEL_AXIS)


def heads_divide(n_heads: int, mesh=None) -> bool:
    """True iff the (ambient) model axis is > 1 and divides ``n_heads``.

    The gate for head-axis KV placement: the head dim of attention is
    batch-like (softmax and PV reduce over the *sequence* dim, which stays
    local), so a head-sharded cache computes exactly what a replicated one
    does, shard by shard — each mesh shard holds the pages its own heads
    read and never sees the others'. When heads do not divide, callers fall
    back to the seq-sharded (flash-decoding) layout."""
    m = model_axis_size(mesh)
    return m > 1 and n_heads % m == 0


# ---------------------------------------------------------------------------
# Parameter partitioning rules (by pytree path name patterns).
# ---------------------------------------------------------------------------

#: (substring pattern, spec builder). First match wins. Specs may be longer
#: than the param rank: stacked (scan) params get the leading axes skipped.
_RULES: Tuple[Tuple[str, P], ...] = (
    # embeddings: vocab on model (TP vocab-parallel logits), d on data (FSDP)
    ("embed", P(MODEL_AXIS, DATA_AXIS)),
    ("unembed", P(MODEL_AXIS, DATA_AXIS)),
    # attention
    ("wq_a", P(DATA_AXIS, MODEL_AXIS)),
    ("wq_b", P(DATA_AXIS, MODEL_AXIS)),
    ("wkv_a", P(DATA_AXIS, None)),
    ("wkv_b", P(DATA_AXIS, MODEL_AXIS)),
    ("wq", P(DATA_AXIS, MODEL_AXIS)),
    ("wk", P(DATA_AXIS, MODEL_AXIS)),
    ("wv", P(DATA_AXIS, MODEL_AXIS)),
    ("wo", P(MODEL_AXIS, DATA_AXIS)),
    ("bq", P(MODEL_AXIS)),
    ("bk", P(MODEL_AXIS)),
    ("bv", P(MODEL_AXIS)),
    # dense mlp
    ("w_gate", P(DATA_AXIS, MODEL_AXIS)),
    ("w_up", P(DATA_AXIS, MODEL_AXIS)),
    ("w_down", P(MODEL_AXIS, DATA_AXIS)),
    # moe: experts on model (EP), shared experts like dense mlp
    ("router", P(None, None)),
    ("we_gate", P(MODEL_AXIS, DATA_AXIS, None)),
    ("we_up", P(MODEL_AXIS, DATA_AXIS, None)),
    ("we_down", P(MODEL_AXIS, None, DATA_AXIS)),
    # mamba
    ("in_proj", P(DATA_AXIS, MODEL_AXIS)),
    ("conv_w", P(None, MODEL_AXIS)),
    ("conv_b", P(MODEL_AXIS)),
    ("x_proj", P(MODEL_AXIS, None)),
    ("dt_proj", P(None, MODEL_AXIS)),
    ("dt_bias", P(MODEL_AXIS)),
    ("a_log", P(MODEL_AXIS, None)),
    ("ssm_d", P(MODEL_AXIS)),
    ("out_proj", P(MODEL_AXIS, DATA_AXIS)),
)


#: KV-cache leaves carrying a head axis at rank-3 *from the right* — true in
#: BOTH layouts the serving engine uses: the dense slab ``(B, hkv, max_len,
#: hd)`` / stacked ``(r, B, hkv, max_len, hd)`` AND the paged pool
#: ``(n_pages, hkv, page_tokens, hd)`` / stacked ``(r, n_pages, hkv, pt,
#: hd)``. The page-indexed leading axis replicates (block tables address any
#: page from any shard's table row); only the head axis shards.
_CACHE_HEAD_LEAVES = frozenset({"k", "v"})

#: Cache leaves with no head axis: the MLA latent (shared across heads) and
#: recurrent SSM state (per-sequence). These replicate over `model` — which
#: is why MLA pool capacity does NOT scale with model shards (see
#: repro.serve.scheduler.kv_shards).
_CACHE_STATE_LEAVES = frozenset({"ckv", "krope", "conv", "ssm"})


def spec_for_cache(path: str, shape: Tuple[int, ...], mesh) -> Optional[P]:
    """PartitionSpec for a KV-cache / paged-pool leaf, or None if ``path``
    does not name one. Matches by final path component (exact leaf names,
    not substrings — ``wkv_a`` must not match ``k``)."""
    leaf = path.rsplit("/", 1)[-1]
    if leaf in _CACHE_HEAD_LEAVES and len(shape) >= 3:
        base = (None,) * (len(shape) - 3) + (MODEL_AXIS, None, None)
        return P(*_fix_spec(base, shape, mesh))
    if leaf in _CACHE_STATE_LEAVES:
        return P(*(None,) * len(shape))
    return None


def spec_for_param(path: str, shape: Tuple[int, ...], mesh) -> P:
    """PartitionSpec for one parameter, by name pattern + divisibility.

    Under the "infer" layout (decode serving), non-expert weights drop their
    `data`-axis (FSDP) factor and live TP-sharded but data-replicated: a
    decode step touches every dense weight for a handful of tokens, so
    gather-at-use traffic would dwarf the activations. Expert weights stay
    2D-sharded — too big to replicate — and the MoE layer gathers the
    *tokens* to the weights instead (repro.models.moe partial-K path)."""
    cache_spec = spec_for_cache(path, shape, mesh)
    if cache_spec is not None:
        return cache_spec
    for pat, spec in _RULES:
        if pat in path:
            base = tuple(spec)
            if layout() == "infer" and not pat.startswith("we_"):
                base = tuple(None if n == DATA_AXIS else n for n in base)
            # stacked scan params: leading (n_repeat,) axes -> replicate them
            extra = len(shape) - len(base)
            if extra > 0:
                base = (None,) * extra + base
            elif extra < 0:
                base = base[-len(shape):] if shape else ()
            return P(*_fix_spec(base, shape, mesh))
    return P(*_fix_spec((None,) * len(shape), shape, mesh))


def param_specs(params: Any, mesh) -> Any:
    """Spec pytree matching ``params`` (works on arrays or ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        specs.append(spec_for_param(name.lower(), leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_shardings(params: Any, mesh) -> Any:
    specs = param_specs(params, mesh)
    return jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
