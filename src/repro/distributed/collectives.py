"""Hierarchical collectives: compressed cross-pod gradient reduction.

The `pod` axis is MemPool's "cluster" level — point-to-point, lowest
bandwidth — so the framework never moves activations across it, and offers
int8 + error-feedback compression for the one thing that must cross it: the
data-parallel gradient all-reduce.

Scheme (per tensor): a shared scale = psum-max of per-pod absmax; each pod
quantizes (grad + error_feedback) to int8 at that scale; the int8 payload is
all-reduced (as int32 accumulator); the dequantized mean comes back and the
residual stays in the local error-feedback buffer. Wire bytes: 1/4 of f32.
Error feedback makes the compression unbiased *over time* (the residual is
replayed next step) — convergence checked in tests/test_compression.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compressed_psum_mean(x: jax.Array, err: jax.Array, axis_name: str,
                         n_shards: int) -> Tuple[jax.Array, jax.Array]:
    """int8 + error-feedback psum-mean over ``axis_name`` (shard_map body)."""
    xf = x.astype(jnp.float32) + err
    absmax = jnp.max(jnp.abs(xf))
    shared = jax.lax.pmax(absmax, axis_name)
    scale = jnp.maximum(shared, 1e-20) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    deq_local = q.astype(jnp.float32) * scale
    new_err = xf - deq_local
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = summed.astype(jnp.float32) * scale / n_shards
    return mean.astype(x.dtype), new_err


def compressed_grad_sync(grads: Any, err_state: Any, axis_name: str,
                         n_shards: int) -> Tuple[Any, Any]:
    """Tree-mapped compressed psum-mean (use inside shard_map over `pod`)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    outs = [compressed_psum_mean(g, e, axis_name, n_shards)
            for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_error_feedback(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
