"""Distribution: sharding rules, hierarchical collectives, compression."""
