"""Chunked selective-scan (Mamba-1) kernel.

The paper's capacity-aware tiling applied to a state-space model: the chunk of
inputs/gates plus the running (d_inner x d_state) state must fit VMEM
(:func:`repro.core.tiling.plan_scan_chunk`); the state is carried in VMEM
scratch across sequential chunk grid steps — exactly MemPool's pattern of a
resident output tile (the state) updated across memory/compute phases (the
chunks). Longer chunks amortize the per-phase static overhead, the paper's
second reuse mechanism.

Layout: d_inner is blocked on the 128-lane axis; d_state (16) rides the
sublane axis of the state scratch. The time loop is a `fori_loop` over the
chunk (VPU-bound; the matmul-form intra-chunk scan is a recorded follow-up
optimization in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import ScanChunkPlan


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref, *,
                 chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)          # (bd, ds)
    dvec = d_ref[0].astype(jnp.float32)         # (bd,)

    def body(t, h):
        xt = x_ref[0, pl.ds(t, 1), :][0].astype(jnp.float32)    # (bd,)
        dtt = dt_ref[0, pl.ds(t, 1), :][0].astype(jnp.float32)  # (bd,)
        bt = b_ref[0, pl.ds(t, 1), :][0].astype(jnp.float32)    # (ds,)
        ct = c_ref[0, pl.ds(t, 1), :][0].astype(jnp.float32)    # (ds,)
        decay = jnp.exp(dtt[:, None] * a)                       # (bd, ds)
        h = decay * h + (dtt * xt)[:, None] * bt[None, :]
        y = (h * ct[None, :]).sum(axis=-1) + dvec * xt
        y_ref[0, pl.ds(t, 1), :] = y[None].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("plan", "block_d", "interpret"))
def mamba_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
               c: jax.Array, d: jax.Array, *, plan: ScanChunkPlan,
               block_d: int = 128, interpret: bool = False) -> jax.Array:
    """x, dt: (B, L, Di); a: (Di, Ds); b, c: (B, L, Ds); d: (Di,) -> (B, L, Di)."""
    bsz, length, di = x.shape
    ds = a.shape[1]
    bd = min(block_d, di)
    chunk = min(plan.chunk, length)
    assert di % bd == 0 and length % chunk == 0, (di, bd, length, chunk)
    grid = (bsz, di // bd, length // chunk)
    d2 = d.reshape(1, di)

    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, bd), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, ds), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((bd, ds), lambda ib, id_, ic: (id_, 0)),
            pl.BlockSpec((1, bd), lambda ib, id_, ic: (0, id_)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda ib, id_, ic: (ib, ic, id_)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, d2)
