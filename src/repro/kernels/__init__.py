"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""

from repro.kernels.ops import attention, matmul, selective_scan
