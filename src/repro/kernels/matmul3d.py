"""The paper's kernel: capacity-aware, double-buffered tiled matmul.

MemPool-3D §VI keeps three tiles (A, B, C) resident in the shared-L1 SPM and
alternates DMA *memory phases* with *compute phases*; the tile edge is the
largest one that fills the SPM (:func:`repro.core.tiling.mempool_tile_size`).

On TPU the same structure is expressed with a Pallas grid: the (bm, bk, bn)
blocks are the resident tiles (f32 accumulator lives in VMEM scratch across
the K loop), the HBM->VMEM pipeline that `pallas_call` generates from the
BlockSpecs *is* the memory phase (Pallas multi-buffers it automatically, the
analogue of the paper's 0.25-tile double-buffer margin), and block sizes come
from :func:`repro.core.tiling.plan_matmul` so the working set fills the VMEM
budget — the paper's t-rule verbatim.

Alignment: MXU wants every matmul dim a multiple of 128; the wrapper in
``ops.py`` pads. Grid iteration (i, j, k) with k minor is sequential on TPU,
so the accumulator carries across k steps ("arbitrary" dimension semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import MatmulPlan


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("plan", "out_dtype", "interpret"))
def matmul3d(a: jax.Array, b: jax.Array, *, plan: MatmulPlan,
             out_dtype: jnp.dtype | None = None,
             interpret: bool = False) -> jax.Array:
    """(M, K) @ (K, N) with planner-chosen VMEM tiling. Dims must divide."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(plan.bm, m), min(plan.bk, k), min(plan.bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"pad first: {(m, k, n)} vs blocks {(bm, bk, bn)}")
    out_dtype = out_dtype or a.dtype
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
