"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (sweeps in
``tests/test_kernels.py``) and the implementations the model stack uses on
CPU, where Pallas only runs in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array,
               out_dtype: jnp.dtype | None = None) -> jax.Array:
    """A @ B with f32 accumulation (MXU semantics)."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: int | None = None,
                  scale: float | None = None,
                  q_offset: int = 0) -> jax.Array:
    """Multi-head attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    ``window`` masks keys further than ``window`` positions behind the query
    (sliding-window / local attention). ``q_offset`` is the absolute position
    of q[0] (for decode: q_offset = cache_len).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    group = hq // hkv
    # MHA/MLA (group==1): never jnp.repeat — it lowers to a head-merging
    # reshape that breaks GSPMD head-sharding and all-gathers the full K/V.
    # GQA (group>1) with q HEAD-sharded: the repeat is what KEEPS hq
    # mesh-divisible (hkv alone may not divide the model axis), so keep it.
    # (Decode uses the grouped einsum with replicated q — _decode_attention.)
    kk = k if group == 1 else jnp.repeat(k, group, axis=1)
    vv = v if group == 1 else jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (can happen with window=0 edge cases) -> zeros
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_ref_blockwise(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True,
                            window: int | None = None,
                            scale: float | None = None,
                            q_offset: int = 0,
                            block_q: int = 1024,
                            block_kv: int = 1024,
                            unroll: bool = False) -> jax.Array:
    """Online-softmax blockwise attention in pure jnp (lax.scan over blocks).

    Numerically identical to :func:`attention_ref` but with O(block^2)
    transient memory — this is the XLA path used for long sequences, and the
    direct jnp mirror of the Pallas flash kernel (same phase structure).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                 # may differ from d (MLA: 192 qk / 128 v)
    scale = scale if scale is not None else d ** -0.5
    group = hq // hkv
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    while sq % bq:
        bq //= 2
    while skv % bkv:
        bkv //= 2
    nq, nkv = sq // bq, skv // bkv

    # group==1: no repeat (sharding-preserving); group>1: repeat keeps the
    # hq dim mesh-divisible — see attention_ref for the rationale.
    qf = q.astype(jnp.float32).reshape(b, hq, nq, bq, d)
    kf = k.astype(jnp.float32).reshape(b, hkv, nkv, bkv, d)
    vf = v.astype(jnp.float32).reshape(b, hkv, nkv, bkv, dv)
    neg = jnp.float32(-jnp.inf)

    def q_step(_, iq):
        qb = qf[:, :, iq]                                   # (B,Hq,bq,D)

        def kv_step(carry, ik):
            m_p, l_p, acc = carry
            kb = kf[:, :, ik] if group == 1 else \
                jnp.repeat(kf[:, :, ik], group, axis=1)     # (B,Hq,bkv,D)
            vb = vf[:, :, ik] if group == 1 else \
                jnp.repeat(vf[:, :, ik], group, axis=1)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale
            qpos = iq * bq + jnp.arange(bq)[:, None] + q_offset
            kpos = ik * bkv + jnp.arange(bkv)[None, :]
            mask = jnp.ones((bq, bkv), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None], s, neg)
            m_n = jnp.maximum(m_p, s.max(-1, keepdims=True))
            alpha = jnp.where(m_p > neg, jnp.exp(m_p - m_n), 0.0)
            p = jnp.where(s > neg, jnp.exp(s - m_n), 0.0)
            l_n = alpha * l_p + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
            return (m_n, l_n, acc), None

        init = (jnp.full((b, hq, bq, 1), neg),
                jnp.zeros((b, hq, bq, 1)),
                jnp.zeros((b, hq, bq, dv)))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nkv),
                                      unroll=nkv if unroll else 1)
        return None, acc / jnp.maximum(l, 1e-30)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq),
                             unroll=nq if unroll else 1)   # (nq,B,Hq,bq,Dv)
    out = blocks.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, dv)
    return out.astype(q.dtype)


def selective_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
                       b: jax.Array, c: jax.Array, d: jax.Array,
                       h0: jax.Array | None = None,
                       return_state: bool = False):
    """Mamba-1 selective scan oracle (discretized zero-order hold).

    x, dt: (B, L, Di);  a: (Di, Ds);  b, c: (B, L, Ds);  d: (Di,)
    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t ;  y_t = h_t . C_t + D*x_t
    """
    bsz, length, di = x.shape
    ds = a.shape[1]
    xf, dtf, bf, cf = (t.astype(jnp.float32) for t in (x, dt, b, c))
    af = a.astype(jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs
        # (B, Di, Ds) decay and input injection
        decay = jnp.exp(dtt[..., None] * af[None])
        h = decay * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bis,bs->bi", h, ct)
        return h, y

    h_init = jnp.zeros((bsz, di, ds), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    hT, ys = jax.lax.scan(step, h_init,
                          (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                           bf.swapaxes(0, 1), cf.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + xf * d.astype(jnp.float32)[None, None, :]
    y = y.astype(x.dtype)
    if return_state:
        return y, hT
    return y
