"""Public, planner-driven entry points for the kernel package.

Models call these; they dispatch to the Pallas kernel (interpret mode on CPU,
compiled on TPU) or to the pure-jnp oracle. The dispatch default — oracle on
CPU, Pallas on TPU — keeps tests fast while exercising identical math; kernel
sweeps in tests/test_kernels.py pin ``impl="pallas"`` to validate the kernels
themselves.

Block plans come from the LRU plan cache in :mod:`repro.core.planner`, keyed
on (current hardware target, shapes, dtypes): repeated calls with the same
problem reuse the same plan object instead of re-planning, and callers that
hold a :class:`~repro.core.planner.KernelPlans` (models/serving thread them
from build time) pass it via ``plan=``; it is clamped to the concrete shapes
by the planner's shared pad/clamp helpers.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import planner, tiling
from repro.kernels import ref
from repro import runtime_flags
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.matmul3d import matmul3d as _matmul
from repro.kernels.mamba_scan import mamba_scan as _scan

Impl = Literal["auto", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas(impl: Impl) -> bool:
    if impl == "auto":
        return _on_tpu()
    return impl == "pallas"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def matmul(a: jax.Array, b: jax.Array, *,
           plan: tiling.MatmulPlan | None = None,
           out_dtype: jnp.dtype | None = None,
           impl: Impl = "auto") -> jax.Array:
    """Capacity-aware tiled matmul; pads to block multiples then crops."""
    if not _use_pallas(impl):
        return ref.matmul_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    if plan is None:
        eff = planner.matmul_kernel_plan(m, k, n, in_bytes=a.dtype.itemsize)
    else:
        eff = planner.clamp_matmul_plan(plan, m, k, n)
    ap = _pad_to(_pad_to(a, 0, eff.bm), 1, eff.bk)
    bp = _pad_to(_pad_to(b, 0, eff.bk), 1, eff.bn)
    out = _matmul(ap, bp, plan=eff, out_dtype=out_dtype or a.dtype,
                  interpret=not _on_tpu())
    return out[:m, :n]


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              window: int | None = None,
              scale: float | None = None,
              q_offset: int | jax.Array = 0,
              plan: tiling.AttentionPlan | None = None,
              impl: Impl = "auto") -> jax.Array:
    """Blockwise attention; q (B,Hq,Sq,D), k/v (B,Hkv,Skv,D).

    ``q_offset`` may be a traced int32 scalar (chunked prefill resumes at
    a runtime cursor): the offset only enters the mask arithmetic of the
    jnp reference paths, so a traced offset computes the exact same HLO as
    a static one. The Pallas kernel needs a static grid offset, so traced
    offsets always take the reference path.
    """
    if not _use_pallas(impl) or isinstance(q_offset, jax.Array):
        # long sequences take the blockwise XLA path (bounded transients);
        # short ones take the direct softmax (cheaper compile, exact grads).
        # Cost-mode lowering (dry-run) unrolls the block scans with capped
        # trip counts so HloCostAnalysis sees every block body.
        if runtime_flags.cost_mode():
            blk = runtime_flags.cost_attn_block()
            if q.shape[2] * k.shape[2] > blk * blk:
                return ref.attention_ref_blockwise(
                    q, k, v, causal=causal, window=window, scale=scale,
                    q_offset=q_offset, block_q=blk, block_kv=blk, unroll=True)
            return ref.attention_ref(q, k, v, causal=causal, window=window,
                                     scale=scale, q_offset=q_offset)
        if q.shape[2] * k.shape[2] > 4096 * 4096:
            return ref.attention_ref_blockwise(
                q, k, v, causal=causal, window=window, scale=scale,
                q_offset=q_offset)
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    _, _, sq, d = q.shape
    skv = k.shape[2]
    if plan is None:
        eff = planner.attention_kernel_plan(sq, skv, d,
                                            in_bytes=q.dtype.itemsize)
    else:
        eff = planner.clamp_attention_plan(plan, sq, skv)
    return _flash(q, k, v, plan=eff, causal=causal, window=window,
                  scale=scale, q_offset=q_offset, interpret=not _on_tpu())


def selective_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                   c: jax.Array, d: jax.Array, *,
                   plan: tiling.ScanChunkPlan | None = None,
                   h0: jax.Array | None = None,
                   return_state: bool = False,
                   impl: Impl = "auto"):
    """Mamba-1 selective scan (see ref.selective_scan_ref for shapes)."""
    if not _use_pallas(impl) or return_state or h0 is not None:
        # decode path (carried state) stays on the jnp oracle
        return ref.selective_scan_ref(x, dt, a, b, c, d, h0=h0,
                                      return_state=return_state)
    bsz, length, di = x.shape
    ds = a.shape[1]
    if plan is None:
        eff = planner.scan_kernel_plan(length, di, ds)
    else:
        eff = planner.clamp_scan_plan(plan, length)
    bd = 128
    while di % bd:
        bd //= 2
    return _scan(x, dt, a, b, c, d, plan=eff, block_d=max(bd, 1),
                 interpret=not _on_tpu())
