"""Blockwise (flash) attention kernel with causal / sliding-window masking.

Capacity-aware the MemPool way: the (block_q, block_kv) working set — Q block,
double-buffered K/V blocks, f32 accumulator and running softmax stats — is
sized by :func:`repro.core.tiling.plan_attention` to fill the VMEM budget.
GQA is handled in the index map (Hq query heads read Hq/Hkv-strided KV heads),
so KV blocks are fetched once per query-head group member without materializing
`repeat`ed KV in HBM.

Blocks that are fully masked (beyond the causal diagonal, or behind the
sliding window) are skipped with ``pl.when`` on a program-id predicate — the
TPU analogue of MemPool skipping empty memory phases.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import AttentionPlan

_NEG_INF = float("-inf")
_STATS_LANES = 128  # stats scratch is (bq, 128) for TPU lane alignment


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_q: int, block_kv: int, n_kv: int,
                 causal: bool, window: int | None, q_offset: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Static-per-step visibility: skip fully masked K/V blocks.
    q_lo = iq * block_q + q_offset
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_kv
    k_hi = k_lo + block_kv - 1
    visible = jnp.bool_(True)
    if causal:
        visible &= k_lo <= q_hi
    if window is not None:
        visible &= k_hi > q_lo - window

    @pl.when(visible)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                          # (bq, 1)
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # Guards: fully masked rows keep m == -inf; exp must not produce NaN.
        alpha = jnp.where(m_prev > _NEG_INF, jnp.exp(m_prev - m_new), 0.0)
        p = jnp.where(s > _NEG_INF, jnp.exp(s - m_new), 0.0)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_kv - 1)
    def _flush():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "plan", "causal", "window", "scale", "q_offset", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    plan: AttentionPlan,
                    causal: bool = True,
                    window: int | None = None,
                    scale: float | None = None,
                    q_offset: int = 0,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k: (B, Hkv, Skv, D); v: (B, Hkv, Skv, Dv).
    Dv may differ from D (MLA decompressed heads). Sq % bq == Skv % bkv == 0.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq, bkv = min(plan.block_q, sq), min(plan.block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    n_kv = skv // bkv
    grid = (b, hq, sq // bq, n_kv)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=bq, block_kv=bkv, n_kv=n_kv,
        causal=causal, window=window, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bkv, dv),
                         lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _STATS_LANES), jnp.float32),
            pltpu.VMEM((bq, _STATS_LANES), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
