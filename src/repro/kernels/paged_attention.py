"""Paged decode attention: walk a block table over a flat page pool.

The paged two-tier KV pool (DESIGN.md §Paged two-tier pool) stores KV as a
flat array of fixed-size pages — ``(n_pages, ..., page_tokens, ...)`` — and
each sequence slot maps logical page indices to physical pages through an
int32 block table. This module owns the decode-attention math over that
layout:

  * :func:`decode_attention_masked` — the dense masked decode-attention
    oracle (GQA without materializing repeated K/V, traced valid-prefix
    masking). This is THE reference: the dense slot-slab serving path calls
    it directly, and the paged path reduces to it after a gather, so
    paged == dense is bit-exact by construction.
  * :func:`gather_kv_pages` — block-table gather: physical pages back into
    a per-slot contiguous view.
  * :func:`paged_decode_attention` — the public entry. On CPU (and under
    ``impl="ref"``) it gathers and calls the oracle; on TPU it runs the
    Pallas page-walk kernel: grid over (slot, kv-head, page), the block
    table scalar-prefetched so the index map DMAs exactly the pages the
    slot owns — the two-tier pool's analogue of MemPool fetching only the
    banks a tile maps to. Fully-masked pages (beyond the slot's frontier)
    are skipped with ``pl.when``.

Page ALIASING is invisible to everything here: attention only ever reads
through a slot's block table, so two slots mapping the same physical page
(ref-counted prefix sharing — DESIGN.md §Prefix sharing & copy-on-write)
each see it as ordinary positions of their own contiguous view, and the
gather/page-walk math is unchanged. The aliasing contract lives entirely
at the WRITE edge, upstream of this module: shared pages are full prompt
pages strictly behind every reader's ``cache_len`` frontier, and the one
page a cache-hit admission both matches and writes (the partial frontier
page of a page-aligned full match) is copied into a private page at
admission — so the per-token append in ``attention._paged_cache_write``
can never land in a page another slot reads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.distributed.sharding import BATCH, MODEL_AXIS, heads_divide, shard

_NEG_INF = float("-inf")
_STATS_LANES = 128   # stats scratch is (group, 128) for TPU lane alignment

INT8_QMAX = 127.0    # symmetric int8: codes in [-127, 127], -128 unused


# ------------------------------------------------------------ quantization
#
# Per-page symmetric int8 (DESIGN.md §Tiered KV compression): each page
# carries ONE f32 scale per leaf (amax / 127 over everything in the page),
# stored in a sibling `<leaf>_scale` array of shape (n_pages,). fp8-e4m3
# needs no scales — KV values live inside e4m3's dynamic range and the
# cast/uncast is a plain astype. fp16 (bf16 storage) is the identity.


def quantize_page_int8(x: jax.Array, axes) -> tuple:
    """Quantize ``x`` to symmetric int8 with one scale per un-reduced index.

    ``axes`` are the reduced (per-page) axes: the scale is
    ``amax(|x|, axes) / 127`` and the codes ``round(x / scale)`` clipped to
    [-127, 127]. An all-zero page gets scale 0 and all-zero codes — the
    dequant ``codes * 0`` round-trips it exactly. Returns ``(codes int8,
    scales f32)`` with ``scales.shape == x.shape`` minus ``axes``.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axes)
    scales = amax / INT8_QMAX
    safe = jnp.where(scales > 0, scales, 1.0)
    expand = list(axes) if isinstance(axes, (tuple, list)) else [axes]
    safe_b = jnp.expand_dims(safe, expand)
    codes = jnp.clip(jnp.round(xf / safe_b), -INT8_QMAX, INT8_QMAX)
    return codes.astype(jnp.int8), scales


def dequantize_page_int8(codes: jax.Array, scales: jax.Array,
                         axes) -> jax.Array:
    """Inverse of :func:`quantize_page_int8` (f32 out); ``axes`` are the
    page axes the scales were reduced over."""
    expand = list(axes) if isinstance(axes, (tuple, list)) else [axes]
    return codes.astype(jnp.float32) * jnp.expand_dims(scales, expand)


# ------------------------------------------------------------------ oracle


def decode_attention_masked(q, k, v, cache_len, *, window=None, causal=True):
    """Masked attention with a traced valid-prefix length (decode path).

    GQA WITHOUT materializing repeated K/V: q is viewed as
    (B, Hkv, group, S, D) and contracted against the (B, Hkv, T, D) cache —
    a jnp.repeat here lowers to broadcast+reshape that merges the head dims,
    which breaks GSPMD's seq-sharding propagation and all-gathers the whole
    pooled cache per layer (§Perf, decode/h3).

    ``cache_len`` is a scalar or a per-row ``(B,)`` vector (slot pool: rows
    at different fill depths decode in one batched step). Positions at or
    beyond a row's frontier — including stale K/V left over from a padded
    prefill or a previous occupant of the slot — are masked out, so a slot
    row never attends across its own reuse boundary."""
    b, hq, s, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = d ** -0.5
    qg = q.reshape(b, hkv, group, s, d)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if isinstance(cache_len, jax.Array) and cache_len.ndim == 1:
        # (B,1,1,1,1): broadcasts against logits' (B,Hkv,group,S,T)
        cache_len = cache_len.reshape(b, 1, 1, 1, 1)
    qpos = cache_len + jnp.arange(s)[:, None]
    tpos = jnp.arange(skv)[None, :]
    mask = tpos < cache_len + s            # written region only
    if causal:
        mask = mask & (tpos <= qpos)
    if window is not None:
        mask = mask & (tpos > qpos - window)
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd",
                     probs.astype(jnp.float32), v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)


# ------------------------------------------------------------------ gather


def gather_kv_pages(pages: jax.Array, block_tables: jax.Array, *,
                    seq_axis: int) -> jax.Array:
    """Walk the block table: physical pages -> per-slot contiguous KV.

    ``pages`` is ``(n_pages, *page_shape)`` with ``page_shape[seq_axis] ==
    page_tokens``; ``block_tables`` is ``(B, P)`` int32. Returns
    ``(B, *page_shape)`` with the seq axis widened to ``P * page_tokens``.
    Unmapped entries (null page 0) gather junk that the caller's frontier
    mask must hide — exactly like stale rows in the dense slab.
    """
    gathered = pages[block_tables]                 # (B, P, *page_shape)
    gathered = jnp.moveaxis(gathered, 1, seq_axis + 1)
    shape = list(gathered.shape)
    merged = shape[:seq_axis + 1] + [shape[seq_axis + 1] * shape[seq_axis + 2]]
    return gathered.reshape(merged + shape[seq_axis + 3:])


# ------------------------------------------------------------ Pallas kernel


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *,
                         page_tokens: int, n_pages_per_slot: int,
                         scale: float, window: int | None):
    """One (slot, kv-head, logical page) cell of the page walk."""
    ib, ip = pl.program_id(0), pl.program_id(2)
    frontier = len_ref[ib]                    # this slot's filled prefix

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip pages wholly beyond the frontier (unmapped tail -> null page).
    lo = ip * page_tokens
    visible = lo <= frontier
    if window is not None:
        visible &= (lo + page_tokens - 1) > frontier - window

    @pl.when(visible)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)             # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)             # (pt, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        pos = lo + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], page_tokens), 1)
        mask = pos <= frontier                          # causal + written
        if window is not None:
            mask &= pos > frontier - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.where(m_prev > _NEG_INF, jnp.exp(m_prev - m_new), 0.0)
        p = jnp.where(s > _NEG_INF, jnp.exp(s - m_new), 0.0)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ip == n_pages_per_slot - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       block_tables: jax.Array, cache_len: jax.Array, *,
                       window: int | None = None,
                       interpret: bool = False) -> jax.Array:
    """Pallas page-walk decode attention.

    q: (B, Hq, 1, D); k_pages/v_pages: (n_pages, Hkv, page_tokens, D);
    block_tables: (B, P) int32; cache_len: (B,) int32. The block table and
    frontier vector are scalar-prefetched so each grid step's index map
    resolves the PHYSICAL page to DMA — the kernel never touches pages the
    slot does not own (page 0 junk is masked like any stale row).
    """
    b, hq, s, d = q.shape
    assert s == 1, "paged decode attention is single-token"
    n_pages, hkv, page_tokens, dv = (k_pages.shape[0], k_pages.shape[1],
                                     k_pages.shape[2], v_pages.shape[-1])
    group = hq // hkv
    p_max = block_tables.shape[1]
    qg = q.reshape(b, hkv, group, d)

    kernel = functools.partial(
        _paged_decode_kernel, page_tokens=page_tokens,
        n_pages_per_slot=p_max, scale=d ** -0.5, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, p_max),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda ib, ih, ip, bt, ln: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, page_tokens, d),
                         lambda ib, ih, ip, bt, ln: (bt[ib, ip], ih, 0, 0)),
            pl.BlockSpec((1, 1, page_tokens, dv),
                         lambda ib, ih, ip, bt, ln: (bt[ib, ip], ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dv),
                               lambda ib, ih, ip, bt, ln: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, _STATS_LANES), jnp.float32),
            pltpu.VMEM((group, _STATS_LANES), jnp.float32),
            pltpu.VMEM((group, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, dv), q.dtype),
        interpret=interpret,
    )(block_tables, cache_len.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(b, hq, 1, dv)


# ------------------------------------------------------------------ entry


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           cache_len: jax.Array, *,
                           window: int | None = None,
                           causal: bool = True,
                           impl: str = "auto",
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None) -> jax.Array:
    """Decode attention over the paged pool; dense math is the oracle.

    ``impl="auto"`` walks pages with the Pallas kernel on TPU and takes the
    gather + :func:`decode_attention_masked` path elsewhere — the latter is
    bit-identical to the dense slot-slab path, which serving relies on for
    paged == dense equivalence (tolerances only enter with the Pallas
    kernel's online softmax, validated in tests/test_kernels.py).

    ``q`` may carry more than one query position per slot: the speculative
    verify chunk (DESIGN.md §Speculative decoding) scores k+1 candidate
    tokens at positions ``cache_len + j`` in one forward. The Pallas page
    walk is single-token, so ``impl="auto"`` routes multi-position queries
    through the gather + oracle path (whose masks already handle
    ``qpos = cache_len + arange(s)``); an explicit ``impl="pallas"`` still
    asserts.

    Quantized pools (DESIGN.md §Tiered KV compression): int8 pages carry
    per-page ``k_scale``/``v_scale`` vectors ``(n_pages,)`` and fp8-e4m3
    pages are detected by dtype; both dequantize AFTER the block-table
    gather (dequant-on-gather) and run the oracle — the page walk moves
    half the bytes, the math is unchanged. The Pallas kernel stays
    fp16-only for now, so quantized pools always take the gather path.
    """
    on_tpu = jax.default_backend() == "tpu"
    single = q.shape[2] == 1
    quantized = (k_scale is not None
                 or k_pages.dtype not in (jnp.bfloat16, jnp.float16,
                                          jnp.float32))
    if quantized and impl == "pallas":
        raise NotImplementedError(
            "the Pallas page walk reads fp16 pages; quantized pools "
            "dequantize on gather (impl='auto')")
    use_pallas = (impl == "pallas") or (impl == "auto" and on_tpu and single
                                        and not quantized)
    if use_pallas and causal:
        return paged_flash_decode(q, k_pages, v_pages, block_tables,
                                  cache_len, window=window,
                                  interpret=not on_tpu)
    k = gather_kv_pages(k_pages, block_tables, seq_axis=1)
    v = gather_kv_pages(v_pages, block_tables, seq_axis=1)
    if k_scale is not None:
        # per-page scalar scales -> per-token columns of the gathered view
        pt = k_pages.shape[2]
        ks = jnp.repeat(k_scale[block_tables], pt, axis=1)    # (B, P*pt)
        vs = jnp.repeat(v_scale[block_tables], pt, axis=1)
        k = k.astype(jnp.float32) * ks[:, None, :, None]
        v = v.astype(jnp.float32) * vs[:, None, :, None]
    elif quantized:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
    if heads_divide(k_pages.shape[1]):
        # pin the gathered per-slot view to the head shards that own the
        # pages: the block-table gather indexes the (replicated-looking)
        # page axis, and without the constraint GSPMD may resolve it by
        # all-gathering the head-sharded pool first.
        q = shard(q, BATCH, MODEL_AXIS, None, None)
        k = shard(k, BATCH, MODEL_AXIS, None, None)
        v = shard(v, BATCH, MODEL_AXIS, None, None)
    return decode_attention_masked(q, k, v, cache_len,
                                   window=window, causal=causal)
