"""Model substrate: layers, attention variants, MoE, SSM, assemblies."""

from repro.models.api import Model, ShapeCfg, SHAPES, build_model
from repro.models.config import LayerKind, ModelConfig
