"""Model configuration and layer-pattern machinery.

Heterogeneous stacks (gemma3's 5:1 local:global, jamba's 1:7 attn:mamba with
every-other-layer MoE) are described as a repeating *pattern* of
:class:`LayerKind`s. The stack is a list of :class:`LayerGroup`s — each group
is `n_repeat` copies of a pattern, whose params are stacked on a leading axis
and driven with `jax.lax.scan` (keeps HLO size flat in depth, which matters
for the 512-device dry-run compiles).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

VOCAB_ALIGN = 256  # pad vocab to a multiple (MXU lanes x mesh divisibility)


@dataclasses.dataclass(frozen=True)
class LayerKind:
    """What one layer is made of."""

    attn: str = "gqa"          # "gqa" | "mla" | "mamba" | "none"
    mlp: str = "mlp"           # "mlp" | "moe" | "none"
    window: Optional[int] = None   # sliding window (None = full attention)

    @property
    def tag(self) -> str:
        w = f"w{self.window}" if self.window else "full"
        return f"{self.attn}-{self.mlp}-{w}"


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    name: str
    pattern: Tuple[LayerKind, ...]
    n_repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.n_repeat


def _groups_size(groups: List["LayerGroup"]) -> int:
    return sum(len(g.pattern) for g in groups)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention ---
    rope_theta: float = 1e4
    qkv_bias: bool = False
    window: Optional[int] = None           # sliding-window width for local layers
    local_global_ratio: int = 0            # gemma3: N local layers per 1 global
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_period: int = 1                    # MoE every k-th layer (jamba: 2)
    first_dense: int = 0                   # leading dense layers (deepseek: 1)
    capacity_factor: float = 2.0
    router_aux_weight: float = 0.01
    # --- SSM (mamba) ---
    ssm_d_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0                   # 0 -> ceil(d_model/16)
    attn_period: int = 0                   # hybrid: attention every k-th layer
    attn_offset: int = 0                   # position of attn layer inside period
    # --- encoder-decoder ---
    n_encoder_layers: int = 0
    # --- frontends (vlm/audio stubs) ---
    frontend_len: int = 0                  # prefix of precomputed embeddings
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    f32_attn_logits: bool = True

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return math.ceil(self.vocab_size / VOCAB_ALIGN) * VOCAB_ALIGN

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or math.ceil(self.d_model / 16)

    def kind_for_layer(self, i: int) -> LayerKind:
        """The LayerKind of absolute layer index ``i`` (decoder stack)."""
        if self.family in ("ssm",):
            return LayerKind(attn="mamba", mlp="none")
        if self.family == "hybrid":
            attn = (self.attn_period and i % self.attn_period == self.attn_offset)
            moe = (self.n_experts and i % self.moe_period == self.moe_period - 1)
            return LayerKind(attn="gqa" if attn else "mamba",
                             mlp="moe" if moe else "mlp")
        if self.local_global_ratio:
            r = self.local_global_ratio
            is_global = (i % (r + 1)) == r
            return LayerKind(attn="gqa", mlp="mlp",
                             window=None if is_global else self.window)
        attn = "mla" if self.use_mla else "gqa"
        if self.n_experts:
            moe = i >= self.first_dense and (i % self.moe_period
                                             == self.moe_period - 1)
            return LayerKind(attn=attn, mlp="moe" if moe else "mlp",
                             window=self.window)
        return LayerKind(attn=attn, mlp="mlp", window=self.window)

    def layer_groups(self) -> List[LayerGroup]:
        """Greedy factorization of the layer stack into head + repeated
        pattern + tail (head: e.g. deepseek's leading dense layer)."""
        kinds = [self.kind_for_layer(i) for i in range(self.n_layers)]
        best: Optional[List[LayerGroup]] = None
        for head in range(0, min(4, self.n_layers)):
            body = kinds[head:]
            for period in range(1, len(body) + 1):
                pattern = tuple(body[:period])
                n_rep = len(body) // period
                if list(pattern) * n_rep != body[:period * n_rep]:
                    continue
                rem = body[period * n_rep:]
                groups = []
                if head:
                    groups.append(LayerGroup("head", tuple(kinds[:head]), 1))
                groups.append(LayerGroup("blocks", pattern, n_rep))
                if rem:
                    groups.append(LayerGroup("tail", tuple(rem), 1))
                # prefer the factorization with the smallest unrolled size
                size = head + period + len(rem)
                if best is None or size < _groups_size(best):
                    best = groups
                break  # smallest period for this head
        assert best is not None
        return best

    # --- parameter / FLOP accounting (for the roofline's MODEL_FLOPS) ----
    def attn_params(self, kind: LayerKind) -> int:
        d = self.d_model
        if kind.attn == "mamba":
            di, ds, dr = self.ssm_d_inner, self.ssm_d_state, self.dt_rank
            return (d * 2 * di + di * self.ssm_conv + di * (dr + 2 * ds)
                    + dr * di + di * ds + di + di * d)
        if kind.attn == "mla":
            qd = self.qk_nope_head_dim + self.qk_rope_head_dim
            q_in = self.q_lora_rank or d
            p = (d * self.q_lora_rank if self.q_lora_rank else 0)
            p += q_in * self.n_heads * qd
            p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            p += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim
                                                     + self.v_head_dim)
            p += self.n_heads * self.v_head_dim * d
            return p
        if kind.attn == "gqa":
            hq, hkv, hd = self.n_heads, self.n_kv_heads, self.head_dim
            return d * hd * (hq + 2 * hkv) + hq * hd * d
        return 0

    def mlp_params(self, kind: LayerKind) -> Tuple[int, int]:
        """(total, active) params of the layer's MLP."""
        d = self.d_model
        if kind.mlp == "mlp":
            p = 3 * d * self.d_ff
            return p, p
        if kind.mlp == "moe":
            e = 3 * d * self.moe_d_ff
            total = self.n_experts * e + self.n_shared_experts * e \
                + d * self.n_experts
            active = (self.top_k + self.n_shared_experts) * e \
                + d * self.n_experts
            return total, active
        return 0, 0

    def param_count(self) -> Tuple[int, int]:
        """(total, active) decoder params incl. embeddings."""
        total = active = 0
        for i in range(self.n_layers):
            kind = self.kind_for_layer(i)
            a = self.attn_params(kind)
            mt, ma = self.mlp_params(kind)
            norms = 2 * self.d_model
            total += a + mt + norms
            active += a + ma + norms
        emb = self.padded_vocab * self.d_model
        emb_total = emb if self.tie_embeddings else 2 * emb
        # encoder stack (GQA + dense MLP per layer)
        if self.n_encoder_layers:
            enc_kind = LayerKind(attn="gqa", mlp="mlp")
            enc = self.n_encoder_layers * (self.attn_params(enc_kind)
                                           + 3 * self.d_model * self.d_ff
                                           + 2 * self.d_model)
            # cross-attention in every decoder layer
            cross = self.n_layers * (self.attn_params(enc_kind) + self.d_model)
            total += enc + cross
            active += enc + cross
        return total + emb_total, active + emb_total

    def model_flops(self, tokens: int) -> float:
        """6 * N_active * D — the roofline's MODEL_FLOPS for a train step."""
        _, active = self.param_count()
        return 6.0 * active * tokens
