"""Encoder-decoder model (seamless-m4t backbone).

Encoder: bidirectional GQA self-attention stack over precomputed modality
frame embeddings (the audio frontend is a stub per the assignment). Decoder:
causal self-attention + cross-attention + MLP. Cross K/V are computed once
from the encoder output and reused across decode steps (the standard
cross-cache).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.planner import KernelPlans
from repro.distributed.sharding import BATCH, shard
from repro.models import attention as attn_mod
from repro import runtime_flags
from repro.models import layers
from repro.models.config import LayerKind, ModelConfig
from repro.models.transformer import _stack_init

Params = Dict[str, Any]
_KIND = LayerKind(attn="gqa", mlp="mlp")


def _init_enc_layer(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model),
        "attn": attn_mod.init_gqa(cfg, k1),
        "ln2": layers.init_rmsnorm(cfg.d_model),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model),
        "attn": attn_mod.init_gqa(cfg, k1),
        "lnx": layers.init_rmsnorm(cfg.d_model),
        "xattn": attn_mod.init_gqa(cfg, k2),
        "ln2": layers.init_rmsnorm(cfg.d_model),
        "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_encdec(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "tok": layers.init_embed(ks[0], cfg.padded_vocab, cfg.d_model,
                                 tie=cfg.tie_embeddings),
        "encoder": _stack_init(functools.partial(_init_enc_layer, cfg),
                               cfg.n_encoder_layers, ks[1]),
        "decoder": _stack_init(functools.partial(_init_dec_layer, cfg),
                               cfg.n_layers, ks[2]),
        "enc_norm": layers.init_rmsnorm(cfg.d_model),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
    }


def encode(cfg: ModelConfig, params: Params, src_embeds: jax.Array,
           *, remat: bool = True,
           plans: Optional[KernelPlans] = None) -> jax.Array:
    """src_embeds: (B, Ss, d) frame embeddings from the (stub) frontend."""
    b, s, _ = src_embeds.shape
    attn_plan = plans.attention if plans else None
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard(src_embeds.astype(layers.COMPUTE_DTYPE), BATCH, None, None)

    def body(x, p):
        h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, _ = attn_mod.gqa_attention(p["attn"], h, cfg=cfg, kind=_KIND,
                                      positions=positions, causal=False,
                                      plan=attn_plan)
        x = x + y
        x = x + layers.mlp(p["mlp"], layers.rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["encoder"],
                        unroll=runtime_flags.scan_unroll(cfg.n_encoder_layers))
    return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(cfg: ModelConfig, p: Params, enc_out: jax.Array):
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = layers.linear(enc_out, p["wk"], p.get("bk")).reshape(
        b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = layers.linear(enc_out, p["wv"], p.get("bv")).reshape(
        b, s, hkv, hd).transpose(0, 2, 1, 3)
    return k, v


def decode(cfg: ModelConfig, params: Params, tokens: jax.Array,
           enc_out: jax.Array, *, caches=None, cache_len=None,
           remat: bool = True, plans: Optional[KernelPlans] = None):
    """Decoder stack. Returns (x, new_caches)."""
    x = layers.embed(params["tok"], tokens)
    b, s, _ = x.shape
    attn_plan = plans.attention if plans else None
    start = cache_len if cache_len is not None else 0
    positions = start + jnp.arange(s, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))

    def body(carry, xs):
        x = carry
        p, cache = xs
        h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, nc = attn_mod.gqa_attention(p["attn"], h, cfg=cfg, kind=_KIND,
                                       positions=positions, cache=cache,
                                       cache_len=cache_len, plan=attn_plan)
        x = x + y
        h = layers.rmsnorm(p["lnx"], x, cfg.norm_eps)
        kv = _cross_kv(cfg, p["xattn"], enc_out)
        y, _ = attn_mod.gqa_attention(p["xattn"], h, cfg=cfg, kind=_KIND,
                                      positions=positions, cross_kv=kv,
                                      causal=False, plan=attn_plan)
        x = x + y
        x = x + layers.mlp(p["mlp"], layers.rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x, nc

    if remat and caches is None:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_caches = jax.lax.scan(body, x, (params["decoder"], caches),
                                 unroll=runtime_flags.scan_unroll(cfg.n_layers))
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_caches


def encdec_loss(cfg: ModelConfig, params: Params, src_embeds: jax.Array,
                tokens: jax.Array, labels: jax.Array, *, remat: bool = True,
                loss_chunk: int = 2048, plans: Optional[KernelPlans] = None):
    enc_out = encode(cfg, params, src_embeds, remat=remat, plans=plans)
    x, _ = decode(cfg, params, tokens, enc_out, remat=remat, plans=plans)
    from repro.models.transformer import lm_loss as _  # noqa: F401 (layout)
    # chunked xent (same as decoder-only path)
    b, s, d = x.shape
    chunk = min(loss_chunk, s)
    while s % chunk:
        chunk //= 2
    xc = x.reshape(b, s // chunk, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, s // chunk, chunk).swapaxes(0, 1)

    def chunk_loss(carry, inp):
        xi, li = inp
        logits = layers.unembed_logits(params["tok"], xi).astype(jnp.float32)
        neg = jnp.finfo(jnp.float32).min
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col[None, None, :] < cfg.vocab_size, logits, neg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        tot, cnt = carry
        return (tot + ((lse - gold) * valid).sum(), cnt + valid.sum()), None

    body = jax.checkpoint(chunk_loss) if remat else chunk_loss
    (tot, cnt), _ys = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"loss": loss, "aux": jnp.zeros(()), "tokens": cnt}


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16):
    one = attn_mod.init_gqa_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
