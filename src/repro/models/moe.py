"""Mixture-of-Experts: top-k routing, shared experts, expert parallelism.

Two interchangeable implementations (cross-checked in tests):

* ``dense``: GShard-style einsum over *all* experts — exact, differentiable,
  used for tiny CPU configs only (compute is E/k-fold redundant).
* ``ep``: production path. `shard_map` over the mesh: tokens stay on their
  (pod, data) shard, experts live on the `model` axis (E/16 per shard).
  Each expert shard sorts its local token->expert hits, runs the expert FFNs
  as grouped GEMMs (`jax.lax.ragged_dot`), scatters back, and the partial
  outputs are psum'd over `model`. Expert weights are additionally
  FSDP-sharded on `data` and all-gathered at use. Capacity: each shard
  processes at most ceil(cf * T_loc * k / n_shards) hits (global-capacity
  dropping; dropped hits contribute zero, like GShard).

The MemPool mapping: experts are "remote banks" — tokens access expert
weights resident on other chips' memory die, at group-level (ICI) latency.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ambient_mesh, shard, shard_map
from repro.models.config import ModelConfig
from repro.models.layers import cast, init_mlp, linear, mlp


def init_moe(cfg: ModelConfig, key) -> Dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "we_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in,
        "we_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in,
        "we_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out,
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _route(router_w: jax.Array, xt: jax.Array, top_k: int):
    """Returns (gates (T,k) f32, idx (T,k) i32, probs (T,E) f32)."""
    logits = jnp.dot(xt.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _aux_loss(probs: jax.Array, idx: jax.Array, n_experts: int,
              batch_axes: Tuple[str, ...] = ()) -> jax.Array:
    """Load-balancing loss (Switch/GShard): E * sum_e f_e * P_e.

    Inside shard_map, ``batch_axes`` carries the mesh axes the token batch is
    split over; f_e/P_e are pmean'ed across them *before* the product, which
    makes the sharded aux numerically identical to the dense global one
    (means of equal-size shard means == global mean).
    """
    hits = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32).sum(1)  # (T,E)
    f_e = hits.mean(0)
    p_e = probs.mean(0)
    for ax in batch_axes:
        f_e = jax.lax.pmean(f_e, ax)
        p_e = jax.lax.pmean(p_e, ax)
    return n_experts * jnp.sum(f_e * p_e)


# ------------------------------------------------------------------ dense

def _moe_dense(p: Dict, xt: jax.Array, cfg: ModelConfig):
    gates, idx, probs = _route(p["router"], xt, cfg.top_k)
    # every expert runs every token, in f32 (tiny CPU test configs only;
    # the CPU backend lacks bf16xbf16->f32 for batched dots)
    xf = xt.astype(jnp.float32)
    h = jnp.einsum("td,edf->tef", xf, p["we_gate"])
    u = jnp.einsum("td,edf->tef", xf, p["we_up"])
    y_e = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["we_down"])
    w_te = (jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)
            * gates[..., None]).sum(1)                      # (T,E)
    y = jnp.einsum("ted,te->td", y_e, w_te)
    return y.astype(xt.dtype), _aux_loss(probs, idx, cfg.n_experts)


# --------------------------------------------------------------------- ep

def _moe_ep_inner(xt, router_w, wg, wu, wd, *, cfg: ModelConfig,
                  n_shards: int, fsdp_axis: Optional[str],
                  batch_axes: Tuple[str, ...] = (),
                  partial_k: bool = False):
    """Per-shard body. xt: (T_loc, d); wg/wu/wd: (E_loc, d[/fsdp], f).

    Two data-movement modes (the paper's locality rule — move whichever is
    smaller):
      * weight-gather (train): tokens >> weights, so the d-sharded expert
        weights are all-gathered over the FSDP axis and tokens stay put;
      * partial-K token-gather (decode): a handful of tokens vs GBs of
        expert weights — the *tokens* are all-gathered to the stationary
        2D-sharded experts, partial-K GEMMs run on each d-slice, and
        activations psum over the FSDP axis. Weights never move.
    """
    if partial_k and fsdp_axis is not None:
        return _moe_ep_partial_k(xt, router_w, wg, wu, wd, cfg=cfg,
                                 n_shards=n_shards, fsdp_axis=fsdp_axis,
                                 batch_axes=batch_axes)
    if fsdp_axis is not None:
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
    t, d = xt.shape
    k, e = cfg.top_k, cfg.n_experts
    e_loc = e // n_shards
    rank = jax.lax.axis_index("model")

    gates, idx, probs = _route(router_w, xt, k)
    flat_e = idx.reshape(-1)
    flat_gate = gates.reshape(-1).astype(jnp.float32)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    lo = rank * e_loc
    is_local = (flat_e >= lo) & (flat_e < lo + e_loc)
    loc_e = jnp.where(is_local, flat_e - lo, e_loc)         # E_loc = overflow
    order = jnp.argsort(loc_e)                              # locals first
    cap = min(t * k, int(math.ceil(cfg.capacity_factor * t * k / n_shards)))
    sel = order[:cap]
    sel_e = loc_e[sel]
    sel_tok = flat_tok[sel]
    sel_gate = jnp.where(sel_e < e_loc, flat_gate[sel], 0.0)

    xs = jnp.take(xt, sel_tok, axis=0)                      # (cap, d)
    counts = jnp.bincount(sel_e, length=e_loc + 1)
    gs = jnp.concatenate([counts[:e_loc],
                          jnp.array([cap], jnp.int32) - counts[:e_loc].sum()[None]])
    # +1 zero expert absorbs overflow rows
    zg = jnp.zeros((1,) + wg.shape[1:], wg.dtype)
    zd = jnp.zeros((1,) + wd.shape[1:], wd.dtype)
    h = jax.lax.ragged_dot(xs, jnp.concatenate([cast(wg), cast(zg)]), gs,
                           preferred_element_type=jnp.float32)
    u = jax.lax.ragged_dot(xs, jnp.concatenate([cast(wu), cast(zg)]), gs,
                           preferred_element_type=jnp.float32)
    act = (jax.nn.silu(h) * u).astype(xt.dtype)
    out = jax.lax.ragged_dot(act, jnp.concatenate([cast(wd), cast(zd)]), gs,
                             preferred_element_type=jnp.float32)
    y = jnp.zeros((t, d), jnp.float32).at[sel_tok].add(out * sel_gate[:, None])
    # local scatter-add in f32 (exact); wire in bf16 — the expert-combine
    # psum is one of the two largest activation collectives (§Perf jamba/h2)
    y = jax.lax.psum(y.astype(xt.dtype), "model")

    aux = _aux_loss(probs, idx, e, batch_axes)
    return y, aux


def _moe_ep_partial_k(xt, router_w, wg, wu, wd, *, cfg: ModelConfig,
                      n_shards: int, fsdp_axis: str,
                      batch_axes: Tuple[str, ...]):
    """Token-gathering partial-K MoE (decode). See _moe_ep_inner docstring.

    xt: (T_loc, d) batch-sharded over ``fsdp_axis``; wg/wu: (E_loc, d/nf, f);
    wd: (E_loc, f, d/nf). Tokens are gathered (tiny), every device routes the
    full token set, runs its d-slice of the expert GEMMs, and partial sums
    travel instead of weights."""
    t_loc, d = xt.shape
    k, e = cfg.top_k, cfg.n_experts
    e_loc = e // n_shards
    dsh = wg.shape[1]                              # local d-slice width
    nf = d // dsh                                  # fsdp axis size
    rank_e = jax.lax.axis_index("model")
    rank_d = jax.lax.axis_index(fsdp_axis)

    xt_all = jax.lax.all_gather(xt, fsdp_axis, axis=0, tiled=True)  # (T, d)
    t = xt_all.shape[0]
    gates, idx, probs = _route(router_w, xt_all, k)
    flat_e = idx.reshape(-1)
    flat_gate = gates.reshape(-1).astype(jnp.float32)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    lo = rank_e * e_loc
    is_local = (flat_e >= lo) & (flat_e < lo + e_loc)
    loc_e = jnp.where(is_local, flat_e - lo, e_loc)
    order = jnp.argsort(loc_e)
    cap = min(t * k, int(math.ceil(cfg.capacity_factor * t * k / n_shards)))
    sel = order[:cap]
    sel_e = loc_e[sel]
    sel_tok = flat_tok[sel]
    sel_gate = jnp.where(sel_e < e_loc, flat_gate[sel], 0.0)

    xs = jnp.take(xt_all, sel_tok, axis=0)                     # (cap, d)
    xs_loc = jax.lax.dynamic_slice_in_dim(xs, rank_d * dsh, dsh, 1)
    counts = jnp.bincount(sel_e, length=e_loc + 1)
    gs = jnp.concatenate([counts[:e_loc],
                          jnp.array([cap], jnp.int32) - counts[:e_loc].sum()[None]])
    zg = jnp.zeros((1,) + wg.shape[1:], wg.dtype)
    zd = jnp.zeros((1,) + wd.shape[1:], wd.dtype)
    # partial-K over the local d-slice, completed by psum over the fsdp axis
    h = jax.lax.ragged_dot(xs_loc, jnp.concatenate([cast(wg), cast(zg)]), gs,
                           preferred_element_type=jnp.float32)
    u = jax.lax.ragged_dot(xs_loc, jnp.concatenate([cast(wu), cast(zg)]), gs,
                           preferred_element_type=jnp.float32)
    h = jax.lax.psum(h, fsdp_axis)
    u = jax.lax.psum(u, fsdp_axis)
    act = (jax.nn.silu(h) * u).astype(xt.dtype)
    out = jax.lax.ragged_dot(act, jnp.concatenate([cast(wd), cast(zd)]), gs,
                             preferred_element_type=jnp.float32)  # (cap, dsh)
    y_all = jnp.zeros((t, dsh), jnp.float32).at[sel_tok].add(
        out * sel_gate[:, None])
    y_all = jax.lax.psum(y_all, "model")           # complete over experts
    # back to my token rows, then assemble d from the slice shards
    y_mine = jax.lax.dynamic_slice_in_dim(y_all, rank_d * t_loc, t_loc, 0)
    y = jax.lax.all_gather(y_mine, fsdp_axis, axis=1, tiled=True)  # (T_loc,d)

    aux_axes = tuple(a for a in batch_axes if a != fsdp_axis)
    aux = _aux_loss(probs, idx, e, aux_axes)
    return y.astype(xt.dtype), aux


def _moe_ep(p: Dict, x3: jax.Array, cfg: ModelConfig, mesh):
    b, s, d = x3.shape
    # joint divisibility: axes are consumed left to right so the *product*
    # of included axis sizes divides the batch (pod=2 x data=16 needs b%32==0)
    batch_axes = []
    rem = b
    for a in ("pod", "data"):
        if a in mesh.axis_names and rem % mesh.shape[a] == 0:
            batch_axes.append(a)
            rem //= mesh.shape[a]
    batch_axes = tuple(batch_axes)
    n_shards = mesh.shape["model"]
    can_2d = "data" in mesh.axis_names and d % mesh.shape["data"] == 0

    # --- data-movement mode (the paper's locality rule, see _moe_ep_inner):
    # compare bytes moved by gathering weights vs gathering tokens+partials.
    t_tokens = b * s
    e_loc = cfg.n_experts // max(n_shards, 1)
    nf = mesh.shape["data"] if can_2d else 1
    weight_bytes = 3 * e_loc * d * cfg.moe_d_ff * 2            # bf16 gather
    t_all = t_tokens // max(
        int(np.prod([mesh.shape[a] for a in batch_axes])), 1) * nf
    cap_all = int(math.ceil(cfg.capacity_factor * t_all * cfg.top_k
                            / max(n_shards, 1)))
    token_bytes = (t_all * d * 2 + 4 * cap_all * cfg.moe_d_ff * 4
                   + 2 * t_all * d * 4)
    partial_k = can_2d and "data" in batch_axes and token_bytes < weight_bytes

    if partial_k:
        fsdp = "data"                         # weights stationary, 2D-sharded
        w_spec = P("model", "data", None)
        wd_spec = P("model", None, "data")
    else:
        fsdp = "data" if (can_2d and "data" not in batch_axes) else None
        # weights: experts on model; d optionally FSDP on data
        w_spec = P("model", fsdp, None)
        wd_spec = P("model", None, fsdp)
    bspec = batch_axes if batch_axes else None

    def inner(xl, rw, wg, wu, wd):
        t = xl.shape[0] * xl.shape[1]
        y, aux = _moe_ep_inner(xl.reshape(t, d), rw, wg, wu, wd, cfg=cfg,
                               n_shards=n_shards, fsdp_axis=fsdp,
                               batch_axes=batch_axes, partial_k=partial_k)
        return y.reshape(xl.shape), aux

    # check_vma=False: the FSDP all-gather of expert weights is value-
    # replicated over `data` but VMA inference conservatively marks gathered
    # outputs as varying, rejecting the (correct) replicated out_specs when
    # the token batch does not occupy the data axis (e.g. batch=1 decode).
    # Numerical equivalence with the dense path is asserted in
    # tests/dist_checks.py::check_moe_ep_matches_dense.
    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  w_spec, w_spec, wd_spec),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(x3, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    return y, aux


# ------------------------------------------------------------------ public

def moe_block(p: Dict, x: jax.Array, *, cfg: ModelConfig,
              impl: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Adds shared experts if configured."""
    b, s, d = x.shape
    mesh = ambient_mesh()
    use_ep = (impl == "ep" or
              (impl == "auto" and mesh is not None and
               "model" in getattr(mesh, "axis_names", ()) and
               cfg.n_experts % mesh.shape["model"] == 0 and
               mesh.shape["model"] > 1))
    if use_ep:
        y, aux = _moe_ep(p, x, cfg, mesh)
    else:
        y, aux = _moe_dense(p, x.reshape(b * s, d), cfg)
        y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x)
    return y, aux
