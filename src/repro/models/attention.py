"""Attention variants: GQA (+ sliding window, RoPE/M-RoPE), MLA (DeepSeek-V2),
with functional KV caches for decode.

Pooled-memory decode (the MemPool idea at pod scale): when the KV head count
divides the `model` axis, decode caches are placed on the *head* axis — each
mesh shard holds exactly the cache (or page) slice its own heads read, which
is bit-exact with the replicated layout because softmax/PV reduce over the
local seq dim (DESIGN.md §Sharded serving). Otherwise KV caches fall back to
*sequence*-dimension sharding across `model` (and `data` too when batch
cannot shard, e.g. long_500k's batch=1), where the attention math is written
so GSPMD turns the softmax reductions into partial max/sum + psum over the
cache shards — flash-decoding across chips, i.e. remote "banks" at the group
level of the hierarchy.

Two cache layouts share the math:

  * **dense slot slab** — ``(B, ..., max_len, ...)``, one worst-case-deep
    slab per slot. This is the oracle path.
  * **paged pool** — ``(n_pages, ..., page_tokens, ...)``, a flat page pool
    addressed through per-slot block tables (the two-tier pool of
    DESIGN.md §Paged two-tier pool). Writes resolve
    ``cache_len -> (physical page, offset)`` through the block table;
    reads walk the table (:mod:`repro.kernels.paged_attention`). A paged
    decode is bit-exact with the dense one: the gather reassembles the
    same contiguous view the slab holds.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tiling
from repro.distributed.sharding import (BATCH, MODEL_AXIS, heads_divide,
                                        shard)
from repro.kernels import ops
from repro.kernels.paged_attention import (INT8_QMAX,
                                           decode_attention_masked,
                                           gather_kv_pages,
                                           paged_decode_attention)
from repro.models import layers
from repro.models.config import LayerKind, ModelConfig
from repro.models.layers import cast, linear


def _cache_write(cache_arr: jax.Array, new: jax.Array, cache_len,
                 axis: int) -> jax.Array:
    """Append ``new`` into the cache at position ``cache_len``.

    Single-token traced writes use a masked select instead of
    dynamic_update_slice: a DUS with a traced start on the SEQ-SHARDED cache
    dim forces GSPMD to replicate (all-gather) the whole cache per layer —
    the dominant decode collective before this fix (§Perf, decode/h2). The
    elementwise select keeps the pooled (seq-sharded) layout intact.

    ``cache_len`` may be a scalar (every row at the same offset — one-shot
    generate) or a per-row vector ``(B,)`` (continuous batching: each KV
    slot has its own filled length). The vector case broadcasts against the
    batch axis (axis 0 of every cache array) and also accepts multi-token
    ``new`` — the speculative verify chunk appends k+1 candidate rows at
    ``cache_len + j`` per slot (DESIGN.md §Speculative decoding); rejected
    rows stay behind the rolled-back frontier, masked like any stale K/V.
    """
    new = new.astype(cache_arr.dtype)
    s = new.shape[axis]
    if isinstance(cache_len, jax.Array) and (s == 1 or cache_len.ndim == 1):
        iota = jax.lax.broadcasted_iota(jnp.int32, cache_arr.shape, axis)
        if cache_len.ndim == 1:      # per-slot lengths: (B,) over batch axis 0
            cache_len = cache_len.reshape(
                (-1,) + (1,) * (cache_arr.ndim - 1))
        if s == 1:
            return jnp.where(iota == cache_len, new, cache_arr)
        # multi-token per-slot append: position cache_len + j takes row j of
        # ``new`` — a masked gather-select, so each slot writes at its own
        # offset without a (replicating) per-row DUS
        idx = jnp.clip(iota - cache_len, 0, s - 1)
        gathered = jnp.take_along_axis(new, idx, axis=axis)
        return jnp.where((iota >= cache_len) & (iota < cache_len + s),
                         gathered, cache_arr)
    return jax.lax.dynamic_update_slice_in_dim(cache_arr, new,
                                               cache_len, axis)


def _paged_cache_write(pages: jax.Array, new: jax.Array,
                       cache_len: jax.Array, block_tables: jax.Array,
                       axis: int) -> jax.Array:
    """Block-table-aware token append into the paged pool.

    ``pages`` is ``(n_pages, *page_shape)`` with the token axis at ``axis``
    inside a page (GQA: 1, MLA: 0); ``new`` is the dense write
    ``(B, ..., s, ...)`` — ``s == 1`` for ordinary decode, ``s == k+1`` for
    a speculative verify chunk. Each row's token ``j`` resolves
    ``cache_len + j -> (physical page, in-page offset)`` through its
    block-table row, so a verify chunk's writes cross page boundaries
    correctly. Rows whose frontier is at or past the mapped depth (a
    drained slot's frozen decode) are routed to the reserved null page 0 —
    the paged analogue of the dense iota-select writing nowhere.

    With prefix sharing, the pages these writes resolve to are private to
    the row BY SCHEDULER INVARIANT: shared (ref-counted) pages sit strictly
    behind the frontier and the copy-on-write rule gives every request its
    own frontier page at admission (DESIGN.md §Prefix sharing &
    copy-on-write) — so no guard is needed here.
    """
    pt = pages.shape[1 + axis]
    p_max = block_tables.shape[1]
    s = new.shape[1 + axis]
    new = new.astype(pages.dtype)
    for j in range(s):
        pos = cache_len + j
        logical = jnp.minimum(pos // pt, p_max - 1)
        phys = jnp.take_along_axis(block_tables, logical[:, None],
                                   axis=1)[:, 0]
        phys = jnp.where(pos < p_max * pt, phys, 0)
        off = pos % pt
        if axis == 0:
            pages = pages.at[phys, off].set(new[:, j])
        else:
            pages = pages.at[phys, :, off].set(new[:, :, j])
    return pages


def _paged_cache_write_q(pages: jax.Array, scales: jax.Array, new: jax.Array,
                         cache_len: jax.Array, block_tables: jax.Array,
                         axis: int) -> Tuple[jax.Array, jax.Array]:
    """Int8 block-table token append with a monotone per-page scale.

    Same ``cache_len + j -> (page, offset)`` resolution as
    :func:`_paged_cache_write`, but the pool holds int8 codes plus one f32
    amax-scale per page (DESIGN.md §Tiered KV compression & host parking).
    Per appended token: the page's scale grows to cover the new value
    (``max(old, amax(new)/127)`` — monotone, so history codes only ever
    get COARSER, never clip), the already-resident codes are requantized at
    the grown scale, and the token's codes land at its offset. At
    ``offset == 0`` the scale RESETS to the fresh token's instead: the page
    was just (re)allocated, and inheriting the previous tenant's stale
    amax would poison this sequence's precision for the page's lifetime.
    Junk routed to null page 0 (frontier at/past mapped depth, duplicate
    rows) also writes ``scales[0]`` — never read, like the page itself.

    Shared (prefix-indexed) pages are never requantized here for the same
    reason :func:`_paged_cache_write` needs no guard: writes resolve only
    to pages private to the row by scheduler invariant.
    """
    pt = pages.shape[1 + axis]
    p_max = block_tables.shape[1]
    s = new.shape[1 + axis]
    newf = new.astype(jnp.float32)
    for j in range(s):
        pos = cache_len + j
        logical = jnp.minimum(pos // pt, p_max - 1)
        phys = jnp.take_along_axis(block_tables, logical[:, None],
                                   axis=1)[:, 0]
        phys = jnp.where(pos < p_max * pt, phys, 0)
        off = pos % pt
        tok = newf[:, j] if axis == 0 else newf[:, :, j]
        fresh = jnp.max(jnp.abs(tok),
                        axis=tuple(range(1, tok.ndim))) / INT8_QMAX
        old = scales[phys]
        scl = jnp.where(off == 0, fresh, jnp.maximum(old, fresh))
        safe = jnp.where(scl > 0, scl, 1.0)
        page_shape_ones = (1,) * (pages.ndim - 1)
        page_f = (pages[phys].astype(jnp.float32)
                  * old.reshape((-1,) + page_shape_ones))
        safe_b = safe.reshape((-1,) + page_shape_ones)
        page_new = jnp.clip(jnp.round(page_f / safe_b), -INT8_QMAX,
                            INT8_QMAX)
        tok_codes = jnp.clip(
            jnp.round(tok / safe.reshape((-1,) + (1,) * (tok.ndim - 1))),
            -INT8_QMAX, INT8_QMAX)
        iota = jax.lax.broadcasted_iota(jnp.int32, page_new.shape, 1 + axis)
        off_b = off.reshape((-1,) + page_shape_ones)
        page_new = jnp.where(iota == off_b,
                             jnp.expand_dims(tok_codes, 1 + axis), page_new)
        pages = pages.at[phys].set(page_new.astype(pages.dtype))
        scales = scales.at[phys].set(scl)
    return pages, scales


# ---------------------------------------------------------------------- GQA

def init_gqa(cfg: ModelConfig, key) -> Dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * hd), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (hq * hd, d), jnp.float32)
        * (1.0 / math.sqrt(hq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict:
    shape = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_gqa_pages(cfg: ModelConfig, n_pages: int, page_tokens: int,
                   dtype=jnp.bfloat16, quant_scales: bool = False) -> Dict:
    """Flat page pool replacing the per-slot slab (page 0 = null page).

    With ``quant_scales`` (the int8 tier codec) each page also carries one
    f32 amax scale per leaf, stored as sibling ``*_scale`` arrays so they
    travel through every tier copy / park blob alongside their codes."""
    shape = (n_pages, cfg.n_kv_heads, page_tokens, cfg.head_dim)
    out = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if quant_scales:
        out["k_scale"] = jnp.zeros((n_pages,), jnp.float32)
        out["v_scale"] = jnp.zeros((n_pages,), jnp.float32)
    return out


def gqa_attention(p: Dict, x: jax.Array, *, cfg: ModelConfig,
                  kind: LayerKind,
                  positions: jax.Array,
                  cache: Optional[Dict] = None,
                  cache_len: Optional[jax.Array] = None,
                  positions3: Optional[jax.Array] = None,
                  cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                  causal: bool = True,
                  plan: Optional[tiling.AttentionPlan] = None,
                  block_tables: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d). Returns (out, updated_cache).

    Modes: training/prefill (cache=None, full seq); decode (cache given,
    S is the new-token count, cache_len the filled prefix length);
    cross-attention (cross_kv given: precomputed encoder K/V, no cache write).
    With ``block_tables`` the cache is the paged page pool instead of a
    per-slot slab: write + attention both walk the table (S == 1 for
    ordinary decode; S == k+1 for a speculative verify chunk).
    """
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = linear(x, p["wq"], p.get("bq"))
    q = q.reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    if cross_kv is None:
        k = linear(x, p["wk"], p.get("bk")).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        v = linear(x, p["wv"], p.get("bv")).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        if positions3 is not None and cfg.mrope:
            q = layers.apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
            k = layers.apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv

    if cache is not None and block_tables is not None:
        # paged two-tier pool: block-table write, page-walk attention. An
        # int8 pool (sibling ``*_scale`` leaves present) takes the
        # scale-aware write and hands the scales to the dequant-on-gather
        # attention; an fp8 pool needs neither — the plain write's astype
        # is the encode and the gather's upcast is the decode.
        k_scales = v_scales = None
        if "k_scale" in cache:
            k_pages, k_scales = _paged_cache_write_q(
                cache["k"], cache["k_scale"], k, cache_len, block_tables,
                axis=1)
            v_pages, v_scales = _paged_cache_write_q(
                cache["v"], cache["v_scale"], v, cache_len, block_tables,
                axis=1)
            new_cache = {"k": k_pages, "v": v_pages,
                         "k_scale": k_scales, "v_scale": v_scales}
        else:
            k_pages = _paged_cache_write(cache["k"], k, cache_len,
                                         block_tables, axis=1)
            v_pages = _paged_cache_write(cache["v"], v, cache_len,
                                         block_tables, axis=1)
            new_cache = {"k": k_pages, "v": v_pages}
        if heads_divide(hkv):
            # head-axis page placement: each mesh shard holds the page slice
            # its own KV heads read (q heads follow by GQA grouping), so the
            # page walk is shard-local — softmax/PV reduce over the seq dim,
            # which never crosses shards, making this bit-exact with the
            # replicated layout. Per-shard pool bytes drop by the model-axis
            # size; the geometry prices against the scaled aggregate
            # (DESIGN.md §Sharded serving).
            q = shard(q, BATCH, MODEL_AXIS, None, None)
            k_pages = shard(k_pages, None, MODEL_AXIS, None, None)
            v_pages = shard(v_pages, None, MODEL_AXIS, None, None)
        else:
            # heads don't divide: the page axis takes the seq shards' role
            # (pages spread over `model`); q replicates exactly as in the
            # dense pooled-decode layout.
            q = shard(q, BATCH, None, None, None)
            k_pages = shard(k_pages, MODEL_AXIS, None, None, None)
            v_pages = shard(v_pages, MODEL_AXIS, None, None, None)
        out = paged_decode_attention(q, k_pages, v_pages, block_tables,
                                     cache_len, window=kind.window,
                                     causal=causal, k_scale=k_scales,
                                     v_scale=v_scales)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
        return shard(linear(out, p["wo"]), BATCH, None, None), new_cache

    new_cache = None
    q_offset = 0
    if cache is not None:
        # functional cache append at cache_len
        k_all = _cache_write(cache["k"], k, cache_len, axis=2)
        v_all = _cache_write(cache["v"], v, cache_len, axis=2)
        new_cache = {"k": k_all, "v": v_all}
        k, v = k_all, v_all
        q_offset = cache_len

    if cache is not None and heads_divide(hkv):
        # dense slab, heads divide the model axis: same head-axis placement
        # as the paged pool above, so dense and paged serve paths (and the
        # one-shot reference) stay bit-identical at any mesh size — a
        # seq-sharded softmax here would reassociate the reduction
        # (partial-stat psums) and break the equivalence matrix.
        q = shard(q, BATCH, MODEL_AXIS, None, None)
        k = shard(k, BATCH, MODEL_AXIS, None, None)
        v = shard(v, BATCH, MODEL_AXIS, None, None)
    elif cache is not None:
        # pooled KV: sequence dim spread over the model axis (flash-decoding).
        # q heads REPLICATE here — a head-sharded q against seq-sharded KV
        # forces GSPMD into replicate-and-reslice copies of the whole cache
        # per layer (§Perf, deepseek/h1); with q replicated, the softmax and
        # PV contractions reduce over the seq shards with small stat psums.
        q = shard(q, BATCH, None, None, None)
        k = shard(k, BATCH, None, "model", None)
        v = shard(v, BATCH, None, "model", None)
    else:
        q = shard(q, BATCH, "model", None, None)
        k = shard(k, BATCH, "model", None, None)
        v = shard(v, BATCH, "model", None, None)

    if cache is not None and isinstance(q_offset, jax.Array) and (
            s == 1 or q_offset.ndim == 1):
        # decode with traced offset: direct masked attention over the cache.
        # s > 1 with per-slot offsets is the speculative verify chunk — the
        # same oracle scores every candidate with causal-within-chunk masks
        # at qpos = cache_len + arange(s) (DESIGN.md §Speculative decoding)
        out = _decode_attention(q, k, v, q_offset, window=kind.window,
                                causal=causal)
    else:
        # prefill — including multi-token chunks resuming at a TRACED
        # cursor (chunked prefill): the offset only shifts the causal mask,
        # so this is the same blockwise math as a static-offset prefill
        out = ops.attention(q, k, v, causal=causal and cross_kv is None,
                            window=kind.window,
                            q_offset=(q_offset
                                      if isinstance(q_offset, jax.Array)
                                      else int(q_offset)),
                            plan=plan)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    out = linear(out, p["wo"])
    return shard(out, BATCH, None, None), new_cache


# The masked decode-attention oracle lives in kernels/paged_attention so the
# paged page-walk path can share its exact math (paged == dense bit-exact);
# the dense slab path below calls the same function.
_decode_attention = decode_attention_masked


# ---------------------------------------------------------------------- MLA

def init_mla(cfg: ModelConfig, key) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope_d, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qd = nope + rope_d
    ks = jax.random.split(key, 6)
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = jax.random.normal(ks[0], (d, cfg.q_lora_rank), jnp.float32) / math.sqrt(d)
        p["q_norm"] = layers.init_rmsnorm(cfg.q_lora_rank)
        p["wq_b"] = jax.random.normal(ks[1], (cfg.q_lora_rank, h * qd), jnp.float32) / math.sqrt(cfg.q_lora_rank)
    else:
        p["wq_b"] = jax.random.normal(ks[1], (d, h * qd), jnp.float32) / math.sqrt(d)
    p["wkv_a"] = jax.random.normal(ks[2], (d, cfg.kv_lora_rank + rope_d), jnp.float32) / math.sqrt(d)
    p["kv_norm"] = layers.init_rmsnorm(cfg.kv_lora_rank)
    p["wkv_b"] = jax.random.normal(ks[3], (cfg.kv_lora_rank, h * (nope + vdim)), jnp.float32) / math.sqrt(cfg.kv_lora_rank)
    p["wo"] = jax.random.normal(ks[4], (h * vdim, d), jnp.float32) / math.sqrt(h * vdim)
    return p


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def init_mla_pages(cfg: ModelConfig, n_pages: int, page_tokens: int,
                   dtype=jnp.bfloat16, quant_scales: bool = False) -> Dict:
    """Paged latent pool: pages of the 576-dim latent, not per-head K/V."""
    out = {
        "ckv": jnp.zeros((n_pages, page_tokens, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((n_pages, page_tokens, cfg.qk_rope_head_dim),
                           dtype),
    }
    if quant_scales:
        out["ckv_scale"] = jnp.zeros((n_pages,), jnp.float32)
        out["krope_scale"] = jnp.zeros((n_pages,), jnp.float32)
    return out


def mla_attention(p: Dict, x: jax.Array, *, cfg: ModelConfig,
                  kind: LayerKind,
                  positions: jax.Array,
                  cache: Optional[Dict] = None,
                  cache_len: Optional[jax.Array] = None,
                  plan: Optional[tiling.AttentionPlan] = None,
                  block_tables: Optional[jax.Array] = None,
                  **_unused) -> Tuple[jax.Array, Optional[Dict]]:
    """Multi-head latent attention. Cache stores only the 576-dim latent —
    the paper's 'more capacity in the same footprint', algorithmically."""
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope_d, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        q = linear(layers.rmsnorm(p["q_norm"], linear(x, p["wq_a"])), p["wq_b"])
    else:
        q = linear(x, p["wq_b"])
    q = q.reshape(b, s, h, nope + rope_d).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = linear(x, p["wkv_a"])                        # (B,S,kv_lora+rope)
    ckv = layers.rmsnorm(p["kv_norm"], kv_a[..., :cfg.kv_lora_rank])
    k_rope = kv_a[..., cfg.kv_lora_rank:]               # single shared head
    k_rope = layers.apply_rope(k_rope[:, None], positions, cfg.rope_theta)[:, 0]

    new_cache = None
    q_offset = 0
    if cache is not None and block_tables is not None:
        # paged latent pool: block-table write, then gather back the same
        # contiguous per-slot view the dense slab holds — the absorbed
        # decode below is untouched and bit-exact with the dense path. An
        # int8 latent pool dequantizes on the gather (codes × per-page
        # scale); fp8 upcasts in the gather's astype. Beyond-frontier
        # positions hold junk either way — masked exactly like stale K/V.
        if "ckv_scale" in cache:
            ckv_pages, ckv_scales = _paged_cache_write_q(
                cache["ckv"], cache["ckv_scale"], ckv, cache_len,
                block_tables, axis=0)
            krope_pages, krope_scales = _paged_cache_write_q(
                cache["krope"], cache["krope_scale"], k_rope, cache_len,
                block_tables, axis=0)
            new_cache = {"ckv": ckv_pages, "krope": krope_pages,
                         "ckv_scale": ckv_scales,
                         "krope_scale": krope_scales}
            pt = ckv_pages.shape[1]
            ckv = (gather_kv_pages(ckv_pages, block_tables, seq_axis=0)
                   .astype(jnp.float32)
                   * jnp.repeat(ckv_scales[block_tables], pt,
                                axis=1)[:, :, None])
            k_rope = (gather_kv_pages(krope_pages, block_tables, seq_axis=0)
                      .astype(jnp.float32)
                      * jnp.repeat(krope_scales[block_tables], pt,
                                   axis=1)[:, :, None])
        else:
            ckv_pages = _paged_cache_write(cache["ckv"], ckv, cache_len,
                                           block_tables, axis=0)
            krope_pages = _paged_cache_write(cache["krope"], k_rope,
                                             cache_len, block_tables, axis=0)
            new_cache = {"ckv": ckv_pages, "krope": krope_pages}
            ckv = gather_kv_pages(ckv_pages, block_tables, seq_axis=0)
            k_rope = gather_kv_pages(krope_pages, block_tables, seq_axis=0)
            if ckv.dtype not in (jnp.bfloat16, jnp.float16, jnp.float32):
                ckv = ckv.astype(jnp.float32)       # fp8 tier: decode=upcast
                k_rope = k_rope.astype(jnp.float32)
        q_offset = cache_len
    elif cache is not None:
        ckv = _cache_write(cache["ckv"], ckv, cache_len, axis=1)
        k_rope = _cache_write(cache["krope"], k_rope, cache_len, axis=1)
        new_cache = {"ckv": ckv, "krope": k_rope}
        q_offset = cache_len

    scale = (nope + rope_d) ** -0.5

    if cache is not None and isinstance(q_offset, jax.Array) and (
            s == 1 or q_offset.ndim == 1):
        # ---- ABSORBED (latent-space) decode: never materialize per-head
        # K/V. q_nope is folded through wkv_b's K half so scores/values are
        # computed directly against the 576-dim latent cache — O(T*(l+r))
        # per query instead of O(T*h*(d_k+d_v)) decompression, and the
        # seq-sharded latent never reshards (§Perf, deepseek/h1).
        if heads_divide(h):
            # heads divide: replicate the latent (it has no head axis to
            # place) and shard the folded-q heads instead — each shard scores
            # its own heads against the whole local latent, bit-exact with
            # the replicated layout. MLA pool capacity therefore does NOT
            # scale with model shards (repro.serve.scheduler.kv_shards).
            ckv = shard(ckv, BATCH, None, None)
            k_rope = shard(k_rope, BATCH, None, None)
        else:
            ckv = shard(ckv, BATCH, "model", None)      # pooled latent
            k_rope = shard(k_rope, BATCH, "model", None)
        w = cast(p["wkv_b"]).reshape(cfg.kv_lora_rank, h, nope + vdim)
        wk, wv = w[..., :nope], w[..., nope:]           # (l, h, n) / (l, h, v)
        qf = q_nope.astype(jnp.float32)                 # (B, H, S, n)
        if heads_divide(h):
            qf = shard(qf, BATCH, MODEL_AXIS, None, None)
        q_lat = jnp.einsum("bhsn,lhn->bhsl", qf, wk.astype(jnp.float32))
        ckv_f = ckv.astype(jnp.float32)                 # (B, T, l)
        kr_f = k_rope.astype(jnp.float32)               # (B, T, r)
        scores = (jnp.einsum("bhsl,btl->bhst", q_lat, ckv_f)
                  + jnp.einsum("bhsr,btr->bhst",
                               q_rope.astype(jnp.float32), kr_f)) * scale
        t_pos = jnp.arange(ckv.shape[1])[None, :]
        if q_offset.ndim == 1:                          # per-slot lengths (B,)
            q_offset = q_offset.reshape(b, 1, 1, 1)
        q_pos = q_offset + jnp.arange(s)[:, None]
        mask = t_pos <= q_pos                           # causal + written
        scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btl->bhsl", probs, ckv_f)
        out = jnp.einsum("bhsl,lhv->bhsv", o_lat, wv.astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        # prefill/train: decompress + flash attention (compute-optimal for
        # long query blocks; the latent trick only wins at small s). A
        # multi-token chunk resuming at a TRACED cursor lands here too —
        # the same decompression an unchunked (static-offset) prefill does.
        ckv = shard(ckv, BATCH, None, None)
        kv = linear(ckv, p["wkv_b"]).reshape(*ckv.shape[:2], h, nope + vdim)
        k_nope = kv[..., :nope].transpose(0, 2, 1, 3)   # (B,H,Skv,nope)
        v = kv[..., nope:].transpose(0, 2, 1, 3)        # (B,H,Skv,v)
        k_rope_b = jnp.broadcast_to(k_rope[:, None], (b, h, *k_rope.shape[1:]))
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = ops.attention(q_full, k, v, causal=True, window=kind.window,
                            scale=scale,
                            q_offset=(q_offset
                                      if isinstance(q_offset, jax.Array)
                                      else int(q_offset)),
                            plan=plan)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * vdim)
    return linear(out, p["wo"]), new_cache


INIT = {"gqa": init_gqa, "mla": init_mla}
APPLY = {"gqa": gqa_attention, "mla": mla_attention}
