"""Shared building blocks: norms, MLPs, embeddings, rotary embeddings."""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import BATCH, shard

COMPUTE_DTYPE = jnp.bfloat16


def cast(x: jax.Array, dtype=COMPUTE_DTYPE) -> jax.Array:
    return x.astype(dtype)


# --------------------------------------------------------------------- norms

def init_rmsnorm(d: int) -> Dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ----------------------------------------------------------------- linear

def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                scale: Optional[float] = None, name: str = "w") -> Dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {name: jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b" + name[1:]] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    out = jnp.dot(x, cast(w), preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b
    return out.astype(x.dtype)


# ----------------------------------------------------------------- SwiGLU

def init_mlp(key, d_model: int, d_ff: int) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_out,
    }


def mlp(p: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_up"])
    h = shard(h, BATCH, None, "model")
    return linear(h, p["w_down"])


# ------------------------------------------------------------- embeddings

def init_embed(key, vocab: int, d_model: int, *, tie: bool) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {"embed": jax.random.normal(k1, (vocab, d_model), jnp.float32) * 0.02}
    if not tie:
        p["unembed"] = jax.random.normal(k2, (vocab, d_model), jnp.float32) * 0.02
    return p


def embed(p: Dict, tokens: jax.Array) -> jax.Array:
    return cast(p["embed"])[tokens]


def unembed_logits(p: Dict, x: jax.Array) -> jax.Array:
    from jax.ad_checkpoint import checkpoint_name
    table = p.get("unembed", p["embed"])
    # named so the chunked-loss remat policy can SAVE the (bf16, gathered)
    # table instead of re-gathering it per chunk in the backward pass
    table_b = checkpoint_name(cast(table), "unembed_table")
    logits = jnp.dot(x, table_b.T, preferred_element_type=jnp.float32)
    return shard(logits, BATCH, None, "model")


# ------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): head_dim/2 split into (t, h, w) sections.

    positions3: (3, B, S) int32 — temporal / height / width position ids.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                              # (half,)
    # pick, per frequency index, which of the 3 position streams drives it
    sect_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                         total_repeat_length=half)            # (half,)
    pos = positions3[sect_id, :, :]                            # (half, B, S)
    angles = pos.transpose(1, 2, 0).astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, None]                             # (B,1,S,half)
    sin = jnp.sin(angles)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
