"""Modality frontend STUBS (per the assignment: [audio]/[vlm] entries specify
the transformer backbone only; `input_specs()` provides precomputed
frame/patch embeddings)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def frontend_spec(cfg: ModelConfig, batch: int,
                  dtype=jnp.bfloat16) -> jax.ShapeDtypeStruct:
    """ShapeDtypeStruct stand-in for precomputed frame/patch embeddings."""
    return jax.ShapeDtypeStruct((batch, cfg.frontend_len, cfg.d_model), dtype)


def fake_frontend(cfg: ModelConfig, batch: int, key,
                  dtype=jnp.bfloat16) -> jax.Array:
    """Synthetic embeddings for smoke tests / examples."""
    return (jax.random.normal(key, (batch, cfg.frontend_len, cfg.d_model))
            * 0.02).astype(dtype)
