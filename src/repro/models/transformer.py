"""Decoder-only LM: init / forward / loss / prefill / decode.

The layer stack is factorized into repeated superblocks
(:meth:`ModelConfig.layer_groups`) and driven with `jax.lax.scan` over stacked
params — HLO size stays constant in depth, which keeps the 512-device dry-run
compiles tractable. Heterogeneous patterns (gemma3 5:1 local:global, jamba
1:7+MoE) unroll *inside* the superblock; homogeneous stacks get a period-1
pattern automatically.

Remat: each superblock body is `jax.checkpoint`ed (policy configurable), so
backward memory is one superblock's activations + the per-superblock carried
x — the scan-remat standard.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.planner import KernelPlans
from repro.distributed.sharding import BATCH, shard
from repro.models import attention as attn_mod
from repro import runtime_flags
from repro.models import layers, moe as moe_mod, ssm
from repro.models.config import LayerGroup, LayerKind, ModelConfig

Params = Dict[str, Any]


# ----------------------------------------------------------------- init

def _init_layer(cfg: ModelConfig, kind: LayerKind, key) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {"ln1": layers.init_rmsnorm(cfg.d_model)}
    if kind.attn == "mamba":
        p["mamba"] = ssm.init_mamba(cfg, ks[0])
        if kind.mlp != "none":
            p["ln2"] = layers.init_rmsnorm(cfg.d_model)
    else:
        p["attn"] = attn_mod.INIT[kind.attn](cfg, ks[0])
        p["ln2"] = layers.init_rmsnorm(cfg.d_model)
    if kind.mlp == "mlp":
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    elif kind.mlp == "moe":
        p["moe"] = moe_mod.init_moe(cfg, ks[1])
    return p


def _stack_init(init_fn, n: int, key):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_lm(cfg: ModelConfig, key) -> Params:
    k_emb, k_layers, k_enc = jax.random.split(key, 3)
    params: Params = {
        "tok": layers.init_embed(k_emb, cfg.padded_vocab, cfg.d_model,
                                 tie=cfg.tie_embeddings),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
        "groups": {},
    }
    for gi, group in enumerate(cfg.layer_groups()):
        gkey = jax.random.fold_in(k_layers, gi)
        gp = {}
        for pos, kind in enumerate(group.pattern):
            pkey = jax.random.fold_in(gkey, pos)
            gp[f"pos{pos}"] = _stack_init(
                functools.partial(_init_layer, cfg, kind), group.n_repeat, pkey)
        params["groups"][group.name] = gp
    return params


# ----------------------------------------------------------- layer apply

def _apply_layer(cfg: ModelConfig, kind: LayerKind, p: Params, x: jax.Array,
                 *, positions, positions3, cache, cache_len,
                 plans: Optional[KernelPlans] = None, block_tables=None):
    """Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind.attn == "mamba":
        # recurrent state is per-slot resident, never paged — block tables
        # only address the attention page pools
        y, new_attn_cache = ssm.mamba_block(
            p["mamba"], h, cfg=cfg, cache=cache,
            plan=plans.scan_chunk if plans else None)
    else:
        y, new_attn_cache = attn_mod.APPLY[kind.attn](
            p["attn"], h, cfg=cfg, kind=kind, positions=positions,
            positions3=positions3, cache=cache, cache_len=cache_len,
            plan=plans.attention if plans else None,
            block_tables=block_tables)
    x = x + y
    if kind.mlp == "mlp":
        x = x + layers.mlp(p["mlp"], layers.rmsnorm(p["ln2"], x, cfg.norm_eps))
    elif kind.mlp == "moe":
        y, aux = moe_mod.moe_block(p["moe"],
                                   layers.rmsnorm(p["ln2"], x, cfg.norm_eps),
                                   cfg=cfg)
        x = x + y
    return x, aux, new_attn_cache


def _superblock(cfg: ModelConfig, group: LayerGroup, stacked: Params,
                x: jax.Array, caches, cache_len, positions, positions3,
                aux: jax.Array, plans: Optional[KernelPlans] = None,
                block_tables=None):
    """Apply one repetition of ``group.pattern``. stacked/caches are the
    per-repetition slices (no leading axis here)."""
    new_caches = {}
    for pos, kind in enumerate(group.pattern):
        cache_i = caches.get(f"pos{pos}") if caches else None
        x, aux_i, nc = _apply_layer(cfg, kind, stacked[f"pos{pos}"], x,
                                    positions=positions, positions3=positions3,
                                    cache=cache_i, cache_len=cache_len,
                                    plans=plans, block_tables=block_tables)
        aux = aux + aux_i
        if nc is not None:
            new_caches[f"pos{pos}"] = nc
    return x, aux, new_caches


def _run_groups(cfg: ModelConfig, params: Params, x: jax.Array, *,
                positions, positions3=None, caches=None, cache_len=None,
                remat: bool = True, plans: Optional[KernelPlans] = None,
                block_tables=None):
    aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    for group in cfg.layer_groups():
        stacked = params["groups"][group.name]
        g_caches = caches.get(group.name) if caches else None

        def body(carry, xs, _group=group):
            xc, auxc = carry
            p_slice, c_slice = xs
            xo, auxo, nc = _superblock(cfg, _group, p_slice, xc, c_slice,
                                       cache_len, positions, positions3, auxc,
                                       plans, block_tables)
            return (xo, auxo), nc

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), nc_stacked = jax.lax.scan(
            body, (x, aux), (stacked, g_caches),
            unroll=runtime_flags.scan_unroll(group.n_repeat))
        if caches is not None:
            new_caches[group.name] = nc_stacked
    return x, aux, new_caches


# ----------------------------------------------------------------- public

def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            frontend_embeds: Optional[jax.Array] = None,
            caches=None, cache_len=None, remat: bool = True,
            positions: Optional[jax.Array] = None,
            plans: Optional[KernelPlans] = None,
            block_tables: Optional[jax.Array] = None):
    """tokens: (B, S) int32. Optional frontend prefix embeds (B, Sf, d) are
    concatenated before the token embeddings (vlm/audio stubs).

    Returns (logits_f32 (B, S_total, padded_vocab), aux, new_caches).
    """
    x = layers.embed(params["tok"], tokens)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    x = shard(x, BATCH, None, None)
    if positions is None:
        start = cache_len if cache_len is not None else 0
        if isinstance(start, jax.Array) and start.ndim == 1:
            # per-slot cache lengths (B,): each row continues from its own
            # frontier (continuous batching — see DESIGN.md §Serving)
            start = start[:, None]
        positions = start + jnp.arange(s, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    positions3 = None
    if cfg.mrope:
        positions3 = jnp.broadcast_to(positions[None], (3, b, s))
    x, aux, new_caches = _run_groups(cfg, params, x, positions=positions,
                                     positions3=positions3, caches=caches,
                                     cache_len=cache_len, remat=remat,
                                     plans=plans, block_tables=block_tables)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, new_caches


def lm_loss(cfg: ModelConfig, params: Params, tokens: jax.Array,
            labels: jax.Array, *, frontend_embeds=None, remat: bool = True,
            loss_chunk: int = 2048,
            plans: Optional[KernelPlans] = None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal LM loss. labels: (B, S) int32, -1 = ignore. The vocab
    projection + softmax runs in sequence chunks so the (tokens x vocab)
    logits tensor never materializes whole (capacity-aware, VMEM-sized)."""
    x, aux, _ = forward(cfg, params, tokens, frontend_embeds=frontend_embeds,
                        remat=remat, plans=plans)
    if frontend_embeds is not None:
        pad = jnp.full(frontend_embeds.shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    b, s, d = x.shape
    chunk = min(loss_chunk, s)
    while s % chunk:
        chunk //= 2
    xc = x.reshape(b, s // chunk, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, s // chunk, chunk).swapaxes(0, 1)

    def chunk_loss(carry, inp):
        xi, li = inp
        logits = layers.unembed_logits(params["tok"], xi)     # (B,c,Vpad) f32
        logits = logits.astype(jnp.float32)
        # mask padded vocab
        neg = jnp.finfo(jnp.float32).min
        v = cfg.vocab_size
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col[None, None, :] < v, logits, neg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        tot, cnt = carry
        return (tot + nll.sum(), cnt + valid.sum()), None

    body = jax.checkpoint(
        chunk_loss,
        policy=jax.checkpoint_policies.save_only_these_names("unembed_table"),
    ) if remat else chunk_loss
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xc, lc),
                                 unroll=runtime_flags.scan_unroll(s // chunk))
    loss = tot / jnp.maximum(cnt, 1.0)
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "aux": aux, "tokens": cnt}


# -------------------------------------------------------------- caches

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Per-layer decode caches, grouped ``{group: {posN: {leaf: array}}}``.

    The leaf names are a sharding contract, not just labels:
    ``repro.distributed.sharding.spec_for_cache`` matches ``k``/``v``
    (head axis at rank-3 from the right -> sharded over `model` under a
    mesh) and ``ckv``/``krope``/``conv``/``ssm`` (no head axis ->
    replicated) by exact final path component. Renaming a leaf silently
    demotes that cache to replicated placement and desyncs the
    per-shard pool budgets in ``repro.serve.scheduler.kv_shards``.
    """
    caches: Dict[str, Any] = {}
    for group in cfg.layer_groups():
        g: Dict[str, Any] = {}
        for pos, kind in enumerate(group.pattern):
            if kind.attn == "mamba":
                one = ssm.init_mamba_cache(cfg, batch)
            elif kind.attn == "mla":
                one = attn_mod.init_mla_cache(cfg, batch, max_len, dtype)
            else:
                # NOTE: window layers could use a rotating window-sized cache;
                # we keep max_len and shard the seq dim instead (pooled KV) —
                # the rotating-buffer variant is logged as a §Perf candidate.
                one = attn_mod.init_gqa_cache(cfg, batch, max_len, dtype)
            g[f"pos{pos}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (group.n_repeat,) + a.shape), one)
        caches[group.name] = g
    return caches


def init_paged_caches(cfg: ModelConfig, batch: int, n_pages: int,
                      page_tokens: int, dtype=jnp.bfloat16,
                      quant_scales: bool = False) -> Dict[str, Any]:
    """Paged two-tier pool caches: attention layers share a flat page pool
    (``n_pages`` pages of ``page_tokens`` tokens, page 0 = null); recurrent
    SSM state stays per-slot resident exactly as in :func:`init_caches`.

    ``dtype``/``quant_scales`` come from the tier's codec (DESIGN.md
    §Tiered KV compression & host parking): an int8 tier stores codes in
    the page leaves plus one f32 amax scale per page in sibling
    ``*_scale`` leaves; recurrent state never quantizes (the scheduler
    rejects quantized codecs for recurrent families upstream)."""
    caches: Dict[str, Any] = {}
    for group in cfg.layer_groups():
        g: Dict[str, Any] = {}
        for pos, kind in enumerate(group.pattern):
            if kind.attn == "mamba":
                one = ssm.init_mamba_cache(cfg, batch)
            elif kind.attn == "mla":
                one = attn_mod.init_mla_pages(cfg, n_pages, page_tokens,
                                              dtype, quant_scales)
            else:
                one = attn_mod.init_gqa_pages(cfg, n_pages, page_tokens,
                                              dtype, quant_scales)
            g[f"pos{pos}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (group.n_repeat,) + a.shape), one)
        caches[group.name] = g
    return caches


def paged_cache_kinds(cfg: ModelConfig):
    """Yield ``(group_name, pos_key, is_paged)`` for every cache entry —
    the walk order engine-side spill/restore and page scatter share.
    ``is_paged`` is False for recurrent (per-slot resident) entries."""
    for group in cfg.layer_groups():
        for pos, kind in enumerate(group.pattern):
            yield group.name, f"pos{pos}", kind.attn != "mamba"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            max_len: int, *, frontend_embeds=None,
            plans: Optional[KernelPlans] = None,
            caches=None, prefix_len=0):
    """Run the prompt, building caches. Returns (x_last, caches).

    ``caches``/``prefix_len`` enable *resumed* prefill — prefix-share
    suffixes and chunked-prefill chunks: ``caches`` already holds the K/V
    of the first ``prefix_len`` positions (gathered from shared pages or
    the request's own earlier chunks), ``tokens`` is only the tail, and
    RoPE positions/causal masks start at ``prefix_len``. A python-int
    ``prefix_len`` is jit-specialized (one compile per offset — the suffix
    path); a traced int32 scalar rides into the mask/position arithmetic
    instead (one compile per chunk-length bucket — the chunked path). Both
    route multi-token tails through the SAME blockwise prefill attention,
    so a resumed row's math is bit-identical to the same row of a full
    prefill.
    """
    if caches is None:
        caches = init_caches(cfg, tokens.shape[0], max_len)
    if not isinstance(prefix_len, jax.Array):
        prefix_len = int(prefix_len)
    x, aux, caches = forward(cfg, params, tokens,
                             frontend_embeds=frontend_embeds,
                             caches=caches, cache_len=prefix_len,
                             remat=False, plans=plans)
    return x, caches


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                caches, cache_len: jax.Array,
                plans: Optional[KernelPlans] = None,
                block_tables: Optional[jax.Array] = None):
    """One decode step. tokens: (B, 1); cache_len: scalar or per-slot (B,)
    filled-prefix lengths. With ``block_tables`` (B, P) the caches are the
    paged pool. Returns (logits (B,1,Vpad), caches)."""
    x, _, new_caches = forward(cfg, params, tokens, caches=caches,
                               cache_len=cache_len, remat=False, plans=plans,
                               block_tables=block_tables)
    logits = layers.unembed_logits(params["tok"], x)
    return logits, new_caches


def verify_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                caches, cache_len: jax.Array,
                plans: Optional[KernelPlans] = None,
                block_tables: Optional[jax.Array] = None):
    """Multi-position decode for speculative verify (DESIGN.md
    §Speculative decoding).

    ``tokens`` is ``(B, k+1)`` — each slot's last emitted token followed by
    its k draft tokens — and ``cache_len`` the per-slot ``(B,)`` frontier
    vector. Column ``j`` runs at RoPE position ``cache_len + j`` with a
    causal-within-chunk mask over the (dense or paged) cache, and its K/V
    is appended at ``cache_len + j``; logits column ``j`` therefore scores
    the token AFTER ``tokens[:, :j+1]`` exactly as ``j`` successive
    single-token :func:`decode_step` calls would — greedy acceptance is
    bit-exact by construction. Rejected suffix K/V stays behind the
    rolled-back frontier: masked like any stale row, overwritten as decode
    advances. This is :func:`decode_step` at S == k+1; the wrapper exists
    so the verify contract is named at every layer it threads through.
    """
    return decode_step(cfg, params, tokens, caches, cache_len, plans=plans,
                       block_tables=block_tables)
