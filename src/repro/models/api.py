"""Unified model facade: init / loss / prefill / decode_step / slot_update.

Every assigned architecture is driven through this one API by the trainer,
the serving engine, the dry-run, and the benchmarks. Serving entry points
are slot-aware: ``prefill`` can gather logits at per-row prompt ends,
``decode_step`` takes scalar or per-slot ``cache_len`` vectors, and
``slot_update`` scatters a prefilled row into the pooled KV cache — the
pieces the continuous-batching engine (DESIGN.md §Serving) builds on.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.planner import KernelPlans, Mem3DPlanner
from repro.core.target import HardwareTarget
from repro.kernels.paged_attention import quantize_page_int8
from repro.models import encdec, frontends, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


class Model:
    """Family-dispatching facade over the substrate.

    The model owns a :class:`Mem3DPlanner` for the given hardware target
    (default: the process-wide current target). Kernel block plans are
    obtained ONCE per distinct (seq_q, seq_kv) through the planner's LRU
    cache and threaded into every kernel call, instead of each op
    re-planning per invocation.
    """

    def __init__(self, cfg: ModelConfig,
                 target: Optional[HardwareTarget] = None):
        self.cfg = cfg
        self.planner = Mem3DPlanner(target)

    # ------------------------------------------------------------ plans
    def kernel_plans(self, seq_q: int, seq_kv: Optional[int] = None, *,
                     tokens: Optional[int] = None) -> KernelPlans:
        """Capacity-partitioned block plans for this arch at one shape cell."""
        cfg = self.cfg
        seq_kv = seq_q if seq_kv is None else seq_kv
        if cfg.use_mla:
            head_dim = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
                        + cfg.v_head_dim) // 2
        else:
            head_dim = cfg.head_dim if cfg.n_heads else 0
        return self.planner.plan_for(
            d_model=cfg.d_model, d_ff=max(cfg.d_ff, cfg.moe_d_ff),
            seq_q=max(seq_q, 1), seq_kv=max(seq_kv, 1), head_dim=head_dim,
            tokens_per_device=max(tokens or seq_q, 1),
            ssm_d_inner=cfg.ssm_d_inner if cfg.ssm_d_state else 0,
            ssm_d_state=cfg.ssm_d_state)

    # ------------------------------------------------------------- init
    def init(self, key) -> Any:
        if self.cfg.family == "encdec":
            return encdec.init_encdec(self.cfg, key)
        return transformer.init_lm(self.cfg, key)

    # ------------------------------------------------------------- loss
    def loss(self, params, batch: Dict[str, jax.Array], *,
             remat: bool = True,
             plans: Optional[KernelPlans] = None
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        if cfg.family == "encdec":
            s = batch["src_embeds"].shape[1]
            plans = plans or self.kernel_plans(s)
            return encdec.encdec_loss(cfg, params, batch["src_embeds"],
                                      batch["tokens"], batch["labels"],
                                      remat=remat, plans=plans)
        s = batch["tokens"].shape[1] + cfg.frontend_len
        plans = plans or self.kernel_plans(s)
        return transformer.lm_loss(cfg, params, batch["tokens"],
                                   batch["labels"],
                                   frontend_embeds=batch.get("frontend_embeds"),
                                   remat=remat, plans=plans)

    # ---------------------------------------------------------- serving
    def prefill(self, params, batch: Dict[str, jax.Array], max_len: int, *,
                plans: Optional[KernelPlans] = None,
                last_pos: Optional[jax.Array] = None,
                prefix_len: int = 0,
                prefix_state: Optional[Dict[str, Any]] = None):
        """Run the prompt, building ``max_len``-sized KV caches.

        Returns ``(logits (B, 1, padded_vocab), state)``. By default logits
        come from the final sequence position; ``last_pos`` (per-row ``(B,)``
        int32) instead gathers each row's logits at that position — the
        continuous-batching path prefills right-padded prompt buckets and
        reads logits at the true last prompt token (DESIGN.md §Serving).

        ``prefix_len``/``prefix_state`` run a *resumed* prefill — the
        prefix-share suffix path (DESIGN.md §Prefix sharing &
        copy-on-write) and the chunked-prefill chunk path (DESIGN.md
        §Chunked prefill): the state already caches the first
        ``prefix_len`` positions, ``tokens`` is the tail only, and RoPE
        positions start at ``prefix_len``. A static int offset is
        jit-specialized; a traced int32 scalar (the chunk cursor) rides
        into the mask arithmetic instead. Decoder-only token models only —
        exactly the families paged serving admits.
        """
        cfg = self.cfg
        from repro.models import layers
        resumed = (isinstance(prefix_len, jax.Array)
                   or prefix_len or prefix_state is not None)
        if resumed and (cfg.family == "encdec" or cfg.frontend_len):
            raise NotImplementedError(
                "resumed prefill targets decoder-only token-prompt models")

        def _last(x: jax.Array) -> jax.Array:
            if last_pos is None:
                return x[:, -1:]
            idx = jnp.broadcast_to(last_pos.reshape(-1, 1, 1),
                                   (x.shape[0], 1, x.shape[2]))
            return jnp.take_along_axis(x, idx, axis=1)

        if cfg.family == "encdec":
            s = batch["src_embeds"].shape[1]
            plans = plans or self.kernel_plans(s, max_len)
            enc_out = encdec.encode(cfg, params, batch["src_embeds"],
                                    remat=False, plans=plans)
            caches = encdec.init_dec_caches(cfg, batch["tokens"].shape[0],
                                            max_len)
            x, caches = encdec.decode(cfg, params, batch["tokens"], enc_out,
                                      caches=caches, cache_len=0, remat=False,
                                      plans=plans)
            logits = layers.unembed_logits(params["tok"], _last(x))
            return logits, {"caches": caches, "enc_out": enc_out}
        s = batch["tokens"].shape[1] + cfg.frontend_len
        plans = plans or self.kernel_plans(s, max_len)
        x, caches = transformer.prefill(cfg, params, batch["tokens"], max_len,
                                        frontend_embeds=batch.get("frontend_embeds"),
                                        plans=plans,
                                        caches=(prefix_state or {}).get("caches"),
                                        prefix_len=prefix_len)
        logits = layers.unembed_logits(params["tok"], _last(x))
        return logits, {"caches": caches}

    def decode_step(self, params, tokens: jax.Array, state: Dict[str, Any],
                    cache_len: jax.Array, *,
                    plans: Optional[KernelPlans] = None,
                    block_tables: Optional[jax.Array] = None):
        """One decode step for every row of the batch.

        ``cache_len`` is the filled KV prefix per row: a scalar when all rows
        share one frontier (one-shot ``Engine.generate``) or a ``(B,)``
        vector when rows are independent slots of the pooled KV cache
        (continuous batching). With ``block_tables`` (B, P) the state holds
        the paged two-tier pool and every attention layer walks the table.
        All masking stays on-device.
        """
        cfg = self.cfg
        if cfg.family == "encdec":
            x, caches = encdec.decode(cfg, params, tokens, state["enc_out"],
                                      caches=state["caches"],
                                      cache_len=cache_len, remat=False,
                                      plans=plans)
            from repro.models import layers
            logits = layers.unembed_logits(params["tok"], x)
            return logits, {**state, "caches": caches}
        logits, caches = transformer.decode_step(cfg, params, tokens,
                                                 state["caches"], cache_len,
                                                 plans=plans,
                                                 block_tables=block_tables)
        return logits, {**state, "caches": caches}

    def verify_step(self, params, tokens: jax.Array, state: Dict[str, Any],
                    cache_len: jax.Array, *,
                    plans: Optional[KernelPlans] = None,
                    block_tables: Optional[jax.Array] = None):
        """Score k draft tokens per slot in ONE batched forward — the
        verify half of speculative decoding (DESIGN.md §Speculative
        decoding).

        ``tokens`` is ``(B, k+1)``: each slot's last emitted token followed
        by its k proposed drafts. ``cache_len`` is the per-slot ``(B,)``
        frontier vector. Returns ``(logits (B, k+1, Vpad), state)`` where
        logits column ``j`` is what single-token :meth:`decode_step` would
        produce after feeding ``tokens[:, :j+1]`` — greedy acceptance over
        these columns is bit-exact with the one-token-per-step path by
        construction. All k+1 K/V rows are written at ``cache_len + j``
        (dense slab or paged pool via ``block_tables``); the engine rolls
        back rejected suffixes by NOT advancing ``cache_len`` past the
        accepted prefix. Attention-only decoder families: recurrent SSM
        state integrates every token it sees and cannot roll back a
        rejected suffix.
        """
        cfg = self.cfg
        if cfg.family == "encdec" or cfg.frontend_len:
            raise NotImplementedError(
                "speculative verify targets decoder-only token-prompt "
                "models; others go through one-shot generate()")
        for group in cfg.layer_groups():
            for kind in group.pattern:
                if kind.attn == "mamba":
                    raise ValueError(
                        "speculative decoding requires attention-only "
                        "models: recurrent SSM state cannot roll back "
                        "rejected draft tokens (docs/SERVING.md)")
        logits, caches = transformer.verify_step(cfg, params, tokens,
                                                 state["caches"], cache_len,
                                                 plans=plans,
                                                 block_tables=block_tables)
        return logits, {**state, "caches": caches}

    def slot_update(self, pool_state: Dict[str, Any],
                    row_state: Dict[str, Any], slot: jax.Array
                    ) -> Dict[str, Any]:
        """Write a freshly prefilled row state into the pooled KV cache.

        ``pool_state`` holds slot-major caches (batch axis = the slot table);
        ``row_state`` is the state of a single prefilled request (batch 1, or
        a contiguous run of rows inserted at ``slot``). Cache arrays are
        stacked per layer group as ``(n_repeat, B, ...)`` — batch lives on
        axis 1 — while auxiliary per-sequence tensors (``enc_out``) carry
        batch on axis 0. This is the only place slot indices touch cache
        memory; everything else addresses slots through ``cache_len`` masks.
        """
        def _scatter(axis):
            def upd(pool: jax.Array, row: jax.Array) -> jax.Array:
                return jax.lax.dynamic_update_slice_in_dim(
                    pool, row.astype(pool.dtype), slot, axis=axis)
            return upd

        new_state = dict(pool_state)
        new_state["caches"] = jax.tree.map(_scatter(1), pool_state["caches"],
                                           row_state["caches"])
        if "enc_out" in pool_state:
            new_state["enc_out"] = _scatter(0)(pool_state["enc_out"],
                                               row_state["enc_out"])
        return new_state

    def slot_update_paged(self, pool_state: Dict[str, Any],
                          row_state: Dict[str, Any], slot: jax.Array,
                          block_row: jax.Array, page_tokens: int
                          ) -> Dict[str, Any]:
        """Scatter a prefilled dense row into the paged two-tier pool.

        The row's contiguous ``depth = P * page_tokens`` KV is cut into P
        pages and written at the physical pages ``block_row`` maps (the
        slot's block-table row; unmapped tail entries point at null page 0,
        so their junk lands in memory no sequence reads). Recurrent SSM
        state keeps the dense per-slot scatter at ``slot``.

        Layout-preserving under head-axis page placement (DESIGN.md
        §Sharded serving): both the pool and the cut row carry the head
        dim, so a head-sharded scatter writes each shard's own head
        slice locally — the page-indexed ``at[:, block_row]`` update
        never moves bytes across shards.

        An int8 pool (DESIGN.md §Tiered KV compression & host parking)
        carries sibling ``*_scale`` leaves the dense row lacks: page cuts
        quantize with FRESH per-page amax scales written alongside their
        codes — a chunked-prefill frontier page re-scattered next chunk
        re-quantizes cleanly, and a reused page's stale tenant scale never
        leaks in.
        """
        p_max = block_row.shape[0]

        def cut_gqa(row):
            r, _, hkv, _, hd = row.shape
            cut = row[:, 0].reshape(r, hkv, p_max, page_tokens, hd)
            return jnp.moveaxis(cut, 2, 1)        # (r, P, hkv, pt, hd)

        def cut_mla(row):
            r, _, _, lat = row.shape
            return row[:, 0].reshape(r, p_max, page_tokens, lat)

        def scatter_slot(pool, row):
            return jax.lax.dynamic_update_slice_in_dim(
                pool, row.astype(pool.dtype), slot, axis=1)

        def scatter_pages(pool_leaf, row_leaf, cut_fn):
            out = dict(pool_leaf)
            for name, pool in pool_leaf.items():
                if name.endswith("_scale"):
                    continue                       # written with their codes
                cut = cut_fn(row_leaf[name])
                scale_name = name + "_scale"
                if scale_name in pool_leaf:
                    codes, scl = quantize_page_int8(
                        cut, tuple(range(2, cut.ndim)))
                    out[name] = pool.at[:, block_row].set(codes)
                    out[scale_name] = (pool_leaf[scale_name]
                                       .at[:, block_row].set(scl))
                else:
                    out[name] = pool.at[:, block_row].set(
                        cut.astype(pool.dtype))
            return out

        new_caches: Dict[str, Any] = {}
        for group in self.cfg.layer_groups():
            g: Dict[str, Any] = {}
            for pos, kind in enumerate(group.pattern):
                key = f"pos{pos}"
                pool_leaf = pool_state["caches"][group.name][key]
                row_leaf = row_state["caches"][group.name][key]
                if kind.attn == "mamba":
                    g[key] = jax.tree.map(scatter_slot, pool_leaf, row_leaf)
                else:
                    g[key] = scatter_pages(
                        pool_leaf, row_leaf,
                        cut_mla if kind.attn == "mla" else cut_gqa)
            new_caches[group.name] = g
        return {**pool_state, "caches": new_caches}

    def gather_row_paged(self, pool_state: Dict[str, Any],
                         block_row: jax.Array, page_tokens: int
                         ) -> Dict[str, Any]:
        """Assemble one slot's dense (batch-1) cache view from the paged
        pool — the inverse of :meth:`slot_update_paged`'s page cut.

        ``block_row`` maps logical page indices to the physical pages to
        read; null entries (page 0) gather zeros that downstream masking
        hides, exactly like unwritten positions of a fresh dense cache.
        This is the read half of suffix prefill: shared prefix pages (and
        the copy-on-write source page) are gathered into the contiguous
        view the suffix tokens attend over. Attention-only models — shared
        pages cannot carry recurrent SSM state.

        Quantized pools dequantize here (int8: codes × per-page scale;
        fp8: upcast) into bf16 dense rows, so suffix-prefill compute is
        identical whatever codec the pool stores.
        """
        p_max = block_row.shape[0]

        def merge_gqa(sel):
            r, _, hkv, pt, hd = sel.shape                  # (r, P, hkv, pt, hd)
            g = jnp.moveaxis(sel, 1, 2)
            return g.reshape(r, hkv, p_max * pt, hd)[:, None]

        def merge_mla(sel):
            r = sel.shape[0]                               # (r, P, pt, lat)
            return sel.reshape(r, p_max * page_tokens, -1)[:, None]

        def gather_leaves(pool_leaf, merge):
            out: Dict[str, Any] = {}
            for name, pages in pool_leaf.items():
                if name.endswith("_scale"):
                    continue
                sel = pages[:, block_row]
                scale_name = name + "_scale"
                if scale_name in pool_leaf:
                    scl = pool_leaf[scale_name][:, block_row]
                    sel = (sel.astype(jnp.float32)
                           * scl.reshape(scl.shape + (1,) * (sel.ndim - 2))
                           ).astype(jnp.bfloat16)
                elif sel.dtype not in (jnp.bfloat16, jnp.float16,
                                       jnp.float32):
                    sel = sel.astype(jnp.bfloat16)         # fp8 tier
                out[name] = merge(sel)
            return out

        caches: Dict[str, Any] = {}
        for group in self.cfg.layer_groups():
            g: Dict[str, Any] = {}
            for pos, kind in enumerate(group.pattern):
                if kind.attn == "mamba":
                    raise NotImplementedError(
                        "prefix sharing requires attention-only models: "
                        "recurrent SSM state is per-sequence, not per-page")
                g[f"pos{pos}"] = gather_leaves(
                    pool_state["caches"][group.name][f"pos{pos}"],
                    merge_mla if kind.attn == "mla" else merge_gqa)
            caches[group.name] = g
        return {"caches": caches}

    def gather_row(self, pool_state: Dict[str, Any],
                   slot: jax.Array) -> Dict[str, Any]:
        """Slice one slot's dense (batch-1) cache view out of the slot-major
        pool — the read half of DENSE chunked prefill, the inverse of
        :meth:`slot_update`. The slice carries everything earlier chunks
        wrote for this slot; unwritten positions hold zeros that the
        resumed prefill's masks hide. Attention-only models — chunked
        admission is exact-length gated off for SSM/hybrid families."""
        for group in self.cfg.layer_groups():
            for kind in group.pattern:
                if kind.attn == "mamba":
                    raise NotImplementedError(
                        "chunked prefill requires attention-only models: "
                        "recurrent SSM state has no resumable KV prefix")

        def take(pool: jax.Array) -> jax.Array:
            return jax.lax.dynamic_slice_in_dim(pool, slot, 1, axis=1)

        return {"caches": jax.tree.map(take, pool_state["caches"])}

    # ------------------------------------------------------ input specs
    def input_specs(self, shape: ShapeCfg,
                    token_dtype=jnp.int32) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        b = shape.global_batch
        if shape.kind in ("train", "prefill"):
            s = shape.seq_len
            batch: Dict[str, Any] = {}
            if cfg.family == "encdec":
                # source frames take the seq budget; decoder gets same length
                batch["src_embeds"] = jax.ShapeDtypeStruct(
                    (b, s, cfg.d_model), jnp.bfloat16)
                batch["tokens"] = jax.ShapeDtypeStruct((b, s), token_dtype)
                batch["labels"] = jax.ShapeDtypeStruct((b, s), token_dtype)
                return batch
            s_text = s - cfg.frontend_len
            batch["tokens"] = jax.ShapeDtypeStruct((b, s_text), token_dtype)
            batch["labels"] = jax.ShapeDtypeStruct((b, s_text), token_dtype)
            if cfg.frontend_len:
                batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
            return batch
        # decode: one new token against a seq_len cache
        max_len = shape.seq_len
        if cfg.family == "encdec":
            state = jax.eval_shape(
                lambda: {"caches": encdec.init_dec_caches(cfg, b, max_len),
                         "enc_out": jnp.zeros((b, max_len, cfg.d_model),
                                              jnp.bfloat16)})
        else:
            state = jax.eval_shape(
                lambda: {"caches": transformer.init_caches(cfg, b, max_len)})
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), token_dtype),
            "state": state,
            "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def runnable_shapes(self) -> Tuple[str, ...]:
        """Which assigned shapes this arch runs (skip rules from DESIGN.md)."""
        cfg = self.cfg
        shapes = ["train_4k", "prefill_32k", "decode_32k"]
        subquadratic = (cfg.family in ("ssm", "hybrid")
                        or cfg.local_global_ratio > 0)
        if subquadratic:
            shapes.append("long_500k")
        return tuple(shapes)


def build_model(cfg: ModelConfig,
                target: Optional[HardwareTarget] = None) -> Model:
    return Model(cfg, target)
