"""Mamba-1 block (falcon-mamba, jamba's SSM layers).

Selective scan runs through :func:`repro.kernels.ops.selective_scan` — the
chunked Pallas kernel on TPU, the jnp oracle on CPU. Decode carries
(conv_state, ssm_state): O(1) memory per token, which is why the SSM archs
run the long_500k shape.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tiling
from repro.distributed.sharding import BATCH, shard
from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import cast, linear


def init_mamba(cfg: ModelConfig, key) -> Dict:
    d, di, ds, dr = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_d_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias init for softplus ~ [1e-3, 1e-1]
    a_init = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
    dt = jnp.exp(jax.random.uniform(ks[0], (di,)) *
                 (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "in_proj": jax.random.normal(ks[1], (d, 2 * di), jnp.float32) / math.sqrt(d),
        "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv, di), jnp.float32) / math.sqrt(cfg.ssm_conv),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": jax.random.normal(ks[3], (di, dr + 2 * ds), jnp.float32) / math.sqrt(di),
        "dt_proj": jax.random.normal(ks[4], (dr, di), jnp.float32) / math.sqrt(dr),
        "dt_bias": dt_bias,
        "a_log": jnp.log(a_init),
        "ssm_d": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), jnp.float32) / math.sqrt(di),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    di, ds = cfg.ssm_d_inner, cfg.ssm_d_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, ds), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B, L, Di); w: (K, Di). Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)    # (B, K-1+L, Di)
    y = sum(xp[:, i:i + x.shape[1], :] * cast(w[i])[None, None] for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return y + cast(b)[None, None], new_state


def mamba_block(p: Dict, x: jax.Array, *, cfg: ModelConfig,
                cache: Optional[Dict] = None,
                plan: Optional[tiling.ScanChunkPlan] = None,
                **_unused) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d) -> (out, new_cache)."""
    b, s, _ = x.shape
    di, ds, dr = cfg.ssm_d_inner, cfg.ssm_d_state, cfg.dt_rank

    xz = linear(x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, BATCH, None, "model")

    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)

    proj = linear(xs, p["x_proj"])
    # pin batch sharding through the low-rank dt path: without this GSPMD
    # batch-replicates the (B, L, dt_rank) intermediates around the time-scan
    # boundary, costing a full-batch f32 all-reduce per layer (§Perf jamba/h3)
    proj = shard(proj, BATCH, None, None)
    dt, bmat, cmat = jnp.split(proj, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(linear(dt, p["dt_proj"]) + p["dt_bias"])
    dt = shard(dt, BATCH, None, "model")
    a = -jnp.exp(p["a_log"])

    if cache is not None:
        # single/multi-step decode: carry the ssm state
        y, h_t = ops.selective_scan(xs, dt, a, bmat, cmat, p["ssm_d"],
                                    h0=cache["ssm"], return_state=True,
                                    impl="ref")
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": h_t}
    else:
        y = ops.selective_scan(xs, dt, a, bmat, cmat, p["ssm_d"], plan=plan)
        new_cache = None

    y = y * jax.nn.silu(z)
    out = linear(y, p["out_proj"])
    return shard(out, BATCH, None, None), new_cache
