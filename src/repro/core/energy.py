"""Performance / energy-efficiency / PDP / EDP model (paper §V-B and §VI-B).

Everything here is *derived* from the primitive Table II rows stored in
:mod:`repro.core.hw_profiles` plus the cycle model of
:mod:`repro.core.perf_model` — reproducing the paper's derived rows and
Figures 7, 8 and 9:

    PDP        = power / frequency                           (Table II row)
    runtime    = cycles / frequency
    performance= 1 / runtime                                 (Fig. 7)
    energy     = power * runtime
    efficiency = performance / power = frequency/(cycles*P)  (Fig. 8)
    EDP        = energy * runtime                            (Fig. 9)

All values are normalized to MemPool-2D(1 MiB) at 16 B/cycle, exactly like the
paper's figures.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import perf_model
from repro.core.hw_profiles import (MEMPOOL_PROFILES, MiB, MemPoolProfile,
                                    SPM_CAPACITIES_MIB, mempool_profile)


@dataclasses.dataclass(frozen=True)
class DerivedMetrics:
    name: str
    flow: str
    spm_mib: int
    pdp: float            # power-delay product (clock delay), Table II
    cycles: float         # kernel cycles (perf model)
    performance: float    # Fig. 7 (normalized)
    energy: float
    efficiency: float     # Fig. 8 (normalized)
    edp: float            # Fig. 9 (normalized)


def derive(flow: str, mib: int, *, bw_bytes_per_cycle: float = 16,
           base_flow: str = "2D", base_mib: int = 1) -> DerivedMetrics:
    prof = mempool_profile(flow, mib)
    base = mempool_profile(base_flow, base_mib)

    cycles = perf_model.matmul_cycles(
        spm_bytes=mib * MiB, bw_bytes_per_cycle=bw_bytes_per_cycle).total
    cycles_base = perf_model.matmul_cycles(
        spm_bytes=base_mib * MiB, bw_bytes_per_cycle=bw_bytes_per_cycle).total

    # Normalized quantities (baseline == 1.0 by construction).
    runtime = (cycles / prof.freq_norm) / (cycles_base / base.freq_norm)
    performance = 1.0 / runtime
    power = prof.power_norm / base.power_norm
    energy = power * runtime
    efficiency = performance / power
    edp = energy * runtime
    pdp = prof.power_norm / prof.freq_norm
    return DerivedMetrics(name=prof.name, flow=flow, spm_mib=mib, pdp=pdp,
                          cycles=cycles, performance=performance,
                          energy=energy, efficiency=efficiency, edp=edp)


def derive_all(bw_bytes_per_cycle: float = 16) -> Dict[str, DerivedMetrics]:
    out = {}
    for flow in ("2D", "3D"):
        for mib in SPM_CAPACITIES_MIB:
            m = derive(flow, mib, bw_bytes_per_cycle=bw_bytes_per_cycle)
            out[m.name] = m
    return out


def pdp_table() -> Dict[str, float]:
    """Table II's PDP row, normalized to the 2D-1MiB baseline."""
    base = mempool_profile("2D", 1)
    base_pdp = base.power_norm / base.freq_norm
    return {name: (p.power_norm / p.freq_norm) / base_pdp
            for name, p in MEMPOOL_PROFILES.items()}
