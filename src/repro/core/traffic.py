"""Analytic per-device HBM traffic model (the roofline's memory term).

Why analytic: the dry-run compiles on the CPU backend, whose cost analysis
counts op-boundary bytes with CPU-grade fusion — it overstates TPU HBM
traffic by 1-2 orders of magnitude (measured ~75x on qwen2.5-3b; the value is
kept in the artifacts for reference). TPU fusion keeps elementwise chains in
VMEM/registers; what actually hits HBM is enumerated here per component:

  params    FSDP-gathered bf16 weights: F_P passes x 2 bytes x N/TP x n_micro
            (gather-write, fwd read, remat read, bwd read+dW -> F_P = 6)
  acts      per-layer streams at TP-sharded width: qkv/attn-out/mlp-hidden/
            residuals+norms, x PASSES (fwd + remat + bwd ~ 3.5)
  attn      flash-kernel streams from the *planner's* block plan: Q in/out +
            visibility-weighted KV re-reads (causal/window-aware)
  loss      chunked logits: tokens x padded_vocab / TP, ~4 passes
  optimizer f32 master + moments read/write on the 1/n_dev shard
  cache     (decode) KV/latent/SSM state read + one-token write

Every component is reported separately so §Perf iterations can attack the
dominant one — this module is the "napkin math" the hillclimb loop runs on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import planner
from repro.models.config import LayerKind, ModelConfig

BF16 = 2
F32 = 4

PARAM_PASSES_TRAIN = 6.0     # gather-write x2 (fwd+bwd remat) + 4 reads
ACT_PASSES_TRAIN = 3.5       # fwd + remat-fwd + bwd(~1.5)
LOSS_PASSES = 4.0            # logits w+r fwd, w+r bwd
OPT_BYTES_PER_PARAM = 28.0   # master rw (8) + m rw (8) + v rw (8) + grad r (4)


def _visible_kv(sq: int, skv: int, bq: int, bkv: int, causal: bool,
                window: Optional[int]) -> int:
    total = 0
    for i in range(-(-sq // bq)):
        hi = min(skv, (i + 1) * bq) if causal else skv
        lo = max(0, i * bq - window) if window is not None else 0
        total += max(0, min(skv, -(-hi // bkv) * bkv) - (lo // bkv) * bkv)
    return total


@dataclasses.dataclass(frozen=True)
class MeshDims:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def n_dev(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


def _attn_traffic_layer(cfg: ModelConfig, kind: LayerKind, t_dev: int,
                        sq: int, skv: int, *, train: bool,
                        mesh: MeshDims) -> float:
    """Flash-attention HBM bytes per device for one layer."""
    if kind.attn == "mamba":
        # conv + scan streams: x/dt/B/C/y at sharded width, plus chunked state
        di = cfg.ssm_d_inner / mesh.model
        ds = cfg.ssm_d_state
        per_tok = (4 * di + 2 * ds) * BF16
        passes = ACT_PASSES_TRAIN if train else 1.0
        return t_dev * per_tok * passes
    if kind.attn == "mla":
        hq = cfg.n_heads
        d_k = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        d_v = cfg.v_head_dim
        hkv, d_kv = hq, (d_k + d_v) / 2  # decompressed per-head K/V
    else:
        hq, hkv, d_kv = cfg.n_heads, max(cfg.n_kv_heads, 1), cfg.head_dim
        d_k = d_v = cfg.head_dim
    plan = planner.attention_plan(max(sq, 1), skv, int(d_kv))
    r = _visible_kv(sq, skv, plan.block_q, plan.block_kv, True, kind.window)
    batch_dev = max(t_dev // max(sq, 1), 1)
    hq_dev = max(hq / mesh.model, 1.0)
    hkv_dev = hkv / mesh.model if hkv % mesh.model == 0 else hkv
    q_io = t_dev * hq_dev * (d_k + d_v) * BF16 * 2          # Q read + O write
    kv_io = batch_dev * hkv_dev * r * (d_k + d_v) * BF16    # streamed blocks
    mult = 3.0 if train else 1.0                            # bwd re-streams
    return (q_io + kv_io) * mult


def _layer_act_traffic(cfg: ModelConfig, kind: LayerKind, t_dev: int,
                       mesh: MeshDims, train: bool) -> float:
    d = cfg.d_model
    if kind.attn == "mla":
        proj = (cfg.n_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
                               + cfg.v_head_dim)) / mesh.model
    elif kind.attn == "mamba":
        proj = 2 * cfg.ssm_d_inner / mesh.model
    else:
        proj = ((cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
                + cfg.n_heads * cfg.head_dim) / mesh.model
    if kind.mlp == "mlp":
        hidden = 2 * cfg.d_ff / mesh.model
    elif kind.mlp == "moe":
        k_act = cfg.top_k + cfg.n_shared_experts
        hidden = k_act * 2 * cfg.moe_d_ff / mesh.model + 2 * d  # + dispatch
    else:
        hidden = 0.0
    resid = 4 * d
    passes = ACT_PASSES_TRAIN if train else 1.0
    return t_dev * (proj + hidden + resid) * BF16 * passes


def step_traffic(cfg: ModelConfig, *, kind: str, seq_len: int,
                 global_batch: int, mesh: MeshDims,
                 n_micro: int = 1) -> Dict[str, float]:
    """Per-device HBM bytes for one train/prefill/decode step."""
    train = kind == "train"
    n_total, _ = cfg.param_count()
    if kind == "decode":
        t_dev = max(global_batch // mesh.dp, 1)
        sq, skv = 1, seq_len
    else:
        t_dev = seq_len * global_batch // mesh.dp
        sq = skv = seq_len

    comp: Dict[str, float] = {}
    # --- params
    if train:
        comp["params"] = (PARAM_PASSES_TRAIN * BF16 * (n_total / mesh.model)
                          * n_micro)
        comp["optimizer"] = OPT_BYTES_PER_PARAM * n_total / mesh.n_dev
    else:
        comp["params"] = BF16 * n_total / mesh.model
        comp["optimizer"] = 0.0

    # --- per-layer streams
    acts = attn = 0.0
    enc_layers = cfg.n_encoder_layers
    for i in range(cfg.n_layers):
        lk = cfg.kind_for_layer(i)
        acts += _layer_act_traffic(cfg, lk, t_dev, mesh, train)
        if kind == "decode":
            attn += _decode_attn_traffic(cfg, lk, t_dev, skv, mesh)
        else:
            attn += _attn_traffic_layer(cfg, lk, t_dev, sq, skv,
                                        train=train, mesh=mesh)
    if enc_layers:
        ek = LayerKind(attn="gqa", mlp="mlp")
        for _ in range(enc_layers):
            acts += _layer_act_traffic(cfg, ek, t_dev, mesh, train)
            if kind != "decode":
                attn += _attn_traffic_layer(cfg, ek, t_dev, sq, skv,
                                            train=train, mesh=mesh)
    comp["acts"] = acts
    comp["attn"] = attn

    # --- loss / logits
    if train:
        comp["loss"] = (t_dev * cfg.padded_vocab / mesh.model) * BF16 * LOSS_PASSES
    elif kind == "prefill":
        comp["loss"] = 0.0
    else:
        comp["loss"] = (t_dev * cfg.padded_vocab / mesh.model) * BF16

    # --- caches
    if kind == "decode":
        comp["cache"] = _cache_bytes_per_device(cfg, global_batch, skv, mesh)
    elif kind == "prefill":
        comp["cache"] = _cache_bytes_per_device(cfg, global_batch, skv, mesh)
    else:
        comp["cache"] = 0.0

    comp["total"] = sum(comp.values())
    return comp


def _decode_attn_traffic(cfg: ModelConfig, kind_l: LayerKind, b_dev: int,
                         skv: int, mesh: MeshDims) -> float:
    """Decode reads the (pooled, seq-sharded) cache slice once per step."""
    if kind_l.attn == "mamba":
        return (cfg.ssm_d_inner / mesh.model) * (cfg.ssm_d_state + cfg.ssm_conv) \
            * F32 * 2 * b_dev
    eff = skv if kind_l.window is None else min(skv, kind_l.window)
    if kind_l.attn == "mla":
        per_tok = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * BF16
        # decompression reads wkv_b once (counted in params) per step
        return b_dev * (eff / mesh.model) * per_tok
    hkv = max(cfg.n_kv_heads, 1)
    return b_dev * (eff / mesh.model) * 2 * hkv * cfg.head_dim * BF16


def _cache_bytes_per_device(cfg: ModelConfig, batch: int, max_len: int,
                            mesh: MeshDims) -> float:
    """One read of the written cache + one-token write, per step."""
    per_tok = 0.0
    for i in range(cfg.n_layers):
        lk = cfg.kind_for_layer(i)
        if lk.attn == "mamba":
            continue
        if lk.attn == "mla":
            per_tok += (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * BF16
        else:
            per_tok += 2 * max(cfg.n_kv_heads, 1) * cfg.head_dim * BF16
    if cfg.n_encoder_layers:
        per_tok += 2 * max(cfg.n_kv_heads, 1) * cfg.head_dim * BF16 * 2
    total = per_tok * max_len * batch
    return total / mesh.n_dev


def _expert_param_split(cfg: ModelConfig) -> Tuple[float, float]:
    """(expert_params, other_params): experts shard over (model, data) per
    the we_* rules; everything else is TP-sharded on `model` only."""
    n_total, _ = cfg.param_count()
    expert = 0.0
    if cfg.n_experts:
        per_layer = cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
        n_moe_layers = sum(1 for i in range(cfg.n_layers)
                           if cfg.kind_for_layer(i).mlp == "moe")
        expert = float(per_layer * n_moe_layers)
    return expert, n_total - expert


def hbm_residency(cfg: ModelConfig, *, kind: str, seq_len: int,
                  global_batch: int, mesh: MeshDims,
                  quantized_moments: bool = False) -> Dict[str, float]:
    """Static per-device HBM residency (capacity check, complements the
    dry-run's memory_analysis).

    Training: f32 master + moments + grads are fully sharded (FSDP x TP over
    all devices); the bf16 compute copy is a *transient* — with scan over
    superblocks and remat, only the current superblock's gathered weights are
    live at once (the paper's resident-tile discipline applied to weights).
    Inference: bf16 params resident; experts shard over (model, data),
    non-expert weights over `model` only.
    """
    n_total, _ = cfg.param_count()
    expert, other = _expert_param_split(cfg)
    comp = {}
    if kind == "train":
        mom = 2.0 if quantized_moments else 8.0
        comp["master+moments"] = (F32 + mom) * n_total / mesh.n_dev
        comp["grads"] = F32 * n_total / mesh.n_dev
        max_pattern = max(len(g.pattern) for g in cfg.layer_groups())
        per_layer = n_total / max(cfg.n_layers + cfg.n_encoder_layers, 1)
        comp["bf16_superblock"] = BF16 * per_layer * max_pattern / mesh.model
    else:
        comp["bf16_params"] = BF16 * (other / mesh.model + expert / mesh.n_dev)
        comp["cache"] = _cache_bytes_per_device(cfg, global_batch, seq_len, mesh)
    comp["total"] = sum(comp.values())
    return comp
