"""Mem3DPlanner — the paper's co-exploration loop as a framework service.

MemPool-3D's thesis is that scratchpad capacity, tiling and the interconnect
hierarchy must be chosen *together*. On TPU this becomes: given a workload
(an architecture x input shape), a mesh, and a hardware profile, jointly pick

  * Pallas block plans for every hot op (matmul / attention / scan chunk) so
    each working set fills VMEM (:mod:`repro.core.tiling`),
  * where each traffic class lives in the interconnect hierarchy (HBM-local /
    intra-pod ICI / inter-pod DCI — MemPool's tile / group / cluster levels),

and report the resulting three-term roofline. The dry-run feeds *measured*
HLO FLOPs/bytes/collective-bytes back into :class:`RooflineReport`, closing
the same loop the paper closes with RTL cycle counts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import tiling
from repro.core.hw_profiles import TpuProfile, TPU_V5E


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    """Three-term roofline for one (arch x shape x mesh) cell."""

    name: str
    n_chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float        # summed operand bytes of ICI collectives
    model_flops: float             # 6*N*D (dense) or 6*N_active*D (MoE)
    profile: TpuProfile = TPU_V5E
    pod_collective_bytes: float = 0.0   # traffic crossing the pod boundary

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.n_chips * self.profile.peak_flops_bf16)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.n_chips * self.profile.hbm_bw)

    @property
    def collective_s(self) -> float:
        ici = self.collective_bytes / (self.n_chips * self.profile.ici_link_bw)
        dci = self.pod_collective_bytes / (self.n_chips * self.profile.dci_bw)
        return ici + dci

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: the roofline step time is max(terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundancy waste."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the *useful* model FLOPs achieve at bound speed."""
        peak = self.n_chips * self.profile.peak_flops_bf16
        return (self.model_flops / self.step_time_s) / peak if self.step_time_s else 0.0

    def to_dict(self) -> Dict:
        return dict(name=self.name, n_chips=self.n_chips,
                    hlo_flops=self.hlo_flops, hlo_bytes=self.hlo_bytes,
                    collective_bytes=self.collective_bytes,
                    pod_collective_bytes=self.pod_collective_bytes,
                    model_flops=self.model_flops,
                    compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s, bound=self.bound,
                    useful_flops_ratio=self.useful_flops_ratio,
                    roofline_fraction=self.roofline_fraction)


@dataclasses.dataclass(frozen=True)
class KernelPlans:
    """Capacity-aware block plans for a model's hot ops."""

    matmul: tiling.MatmulPlan
    attention: Optional[tiling.AttentionPlan]
    scan_chunk: Optional[tiling.ScanChunkPlan]


class Mem3DPlanner:
    """Joint capacity/tiling/hierarchy planner."""

    def __init__(self, profile: TpuProfile = TPU_V5E):
        self.profile = profile

    def plan_for(self, *, d_model: int, d_ff: int, seq_q: int, seq_kv: int,
                 head_dim: int, tokens_per_device: int,
                 ssm_d_inner: int = 0, ssm_d_state: int = 0) -> KernelPlans:
        mm = tiling.plan_matmul(tokens_per_device, d_model, d_ff,
                                profile=self.profile)
        attn = None
        if head_dim:
            attn = tiling.plan_attention(seq_q, seq_kv, head_dim,
                                         profile=self.profile)
        scan = None
        if ssm_d_inner:
            scan = tiling.plan_scan_chunk(seq_q, ssm_d_inner, ssm_d_state,
                                          profile=self.profile)
        return KernelPlans(matmul=mm, attention=attn, scan_chunk=scan)
