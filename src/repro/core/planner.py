"""Mem3DPlanner — the paper's co-exploration loop as a framework service.

MemPool-3D's thesis is that scratchpad capacity, tiling and the interconnect
hierarchy must be chosen *together*. On TPU this becomes: given a workload
(an architecture x input shape), a mesh, and a hardware target, jointly pick

  * Pallas block plans for every hot op (matmul / attention / scan chunk) so
    each working set fills the target's scratchpad partition
    (:mod:`repro.core.tiling`),
  * where each traffic class lives in the interconnect hierarchy (HBM-local /
    intra-pod ICI / inter-pod DCI — MemPool's tile / group / cluster levels),

and report the resulting three-term roofline. The dry-run feeds *measured*
HLO FLOPs/bytes/collective-bytes back into :class:`RooflineReport`, closing
the same loop the paper closes with RTL cycle counts.

Plans are memoized in an LRU cache keyed on (target, shapes, dtypes) — the
kernel entry points in :mod:`repro.kernels.ops` call the ``*_kernel_plan``
helpers below on every invocation, so planning and the block pad/clamp
derivation run once per distinct problem, not once per call.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

from repro.core import tiling
from repro.core.hw_profiles import TpuProfile
from repro.core.target import HardwareTarget, get_target


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    """Three-term roofline for one (arch x shape x mesh) cell.

    ``profile`` carries the TPU roofline constants; when ``None`` it resolves
    to the current target's profile at property-access time.
    """

    name: str
    n_chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float        # summed operand bytes of ICI collectives
    model_flops: float             # 6*N*D (dense) or 6*N_active*D (MoE)
    profile: Optional[TpuProfile] = None
    pod_collective_bytes: float = 0.0   # traffic crossing the pod boundary

    @property
    def _prof(self) -> TpuProfile:
        if self.profile is not None:
            return self.profile
        prof = get_target().profile
        assert isinstance(prof, TpuProfile), \
            "RooflineReport needs a TPU target (roofline constants)"
        return prof

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.n_chips * self._prof.peak_flops_bf16)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.n_chips * self._prof.hbm_bw)

    @property
    def collective_s(self) -> float:
        ici = self.collective_bytes / (self.n_chips * self._prof.ici_link_bw)
        dci = self.pod_collective_bytes / (self.n_chips * self._prof.dci_bw)
        return ici + dci

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: the roofline step time is max(terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundancy waste."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the *useful* model FLOPs achieve at bound speed."""
        peak = self.n_chips * self._prof.peak_flops_bf16
        return (self.model_flops / self.step_time_s) / peak if self.step_time_s else 0.0

    def to_dict(self) -> Dict:
        return dict(name=self.name, n_chips=self.n_chips,
                    hlo_flops=self.hlo_flops, hlo_bytes=self.hlo_bytes,
                    collective_bytes=self.collective_bytes,
                    pod_collective_bytes=self.pod_collective_bytes,
                    model_flops=self.model_flops,
                    compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s, bound=self.bound,
                    useful_flops_ratio=self.useful_flops_ratio,
                    roofline_fraction=self.roofline_fraction)


# ---------------------------------------------------------------------------
# Shared pad/clamp logic: one place that adapts a capacity plan to a concrete
# problem (kernel grids need block edges that tile the padded problem).
# ---------------------------------------------------------------------------


def shrink_to_divisor(block: int, size: int) -> int:
    """Largest halving of ``block`` (clamped to ``size``) that divides ``size``."""
    b = max(min(block, size), 1)
    while size % b:
        b //= 2
    return max(b, 1)


def clamp_matmul_plan(plan: tiling.MatmulPlan, m: int, k: int,
                      n: int) -> tiling.MatmulPlan:
    """Blocks never exceed the problem dims (inputs are padded to block
    multiples by the caller)."""
    return tiling.MatmulPlan(min(plan.bm, m), min(plan.bk, k),
                             min(plan.bn, n), plan.n_buffers)


def clamp_attention_plan(plan: tiling.AttentionPlan, seq_q: int,
                         seq_kv: int) -> tiling.AttentionPlan:
    return tiling.AttentionPlan(
        shrink_to_divisor(plan.block_q, max(seq_q, 1)),
        shrink_to_divisor(plan.block_kv, max(seq_kv, 1)),
        plan.n_buffers)


def clamp_scan_plan(plan: tiling.ScanChunkPlan,
                    seq: int) -> tiling.ScanChunkPlan:
    return tiling.ScanChunkPlan(shrink_to_divisor(plan.chunk, max(seq, 1)),
                                plan.n_buffers)


# ---------------------------------------------------------------------------
# The LRU plan cache. Targets and plans are frozen dataclasses, so the
# (target, shapes, dtypes) key hashes directly and hits return the SAME plan
# object — jit caches keyed on the plan see one entry per distinct problem.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1024)
def _matmul_plan(target: HardwareTarget, m: int, k: int, n: int,
                 in_bytes: int, acc_bytes: int) -> tiling.MatmulPlan:
    plan = tiling.plan_matmul(m, k, n, partition=target.partition(
        fraction=0.75, n_buffers=2), in_bytes=in_bytes, acc_bytes=acc_bytes)
    return clamp_matmul_plan(plan, m, k, n)


@functools.lru_cache(maxsize=1024)
def _attention_plan(target: HardwareTarget, seq_q: int, seq_kv: int,
                    head_dim: int, in_bytes: int) -> tiling.AttentionPlan:
    return tiling.plan_attention(seq_q, seq_kv, head_dim,
                                 partition=target.partition(
                                     fraction=0.5, n_buffers=2),
                                 in_bytes=in_bytes)


@functools.lru_cache(maxsize=1024)
def _scan_plan(target: HardwareTarget, seq: int, d_inner: int,
               d_state: int) -> tiling.ScanChunkPlan:
    return tiling.plan_scan_chunk(seq, d_inner, d_state,
                                  partition=target.partition(
                                      fraction=0.5, n_buffers=1))


def matmul_kernel_plan(m: int, k: int, n: int, *,
                       in_bytes: Optional[int] = None,
                       acc_bytes: int = 4,
                       target: Optional[HardwareTarget] = None
                       ) -> tiling.MatmulPlan:
    """Cached, problem-clamped matmul plan for the current (or given) target."""
    target = target or get_target()
    in_bytes = target.word_bytes if in_bytes is None else in_bytes
    return _matmul_plan(target, m, k, n, in_bytes, acc_bytes)


def attention_plan(seq_q: int, seq_kv: int, head_dim: int, *,
                   in_bytes: Optional[int] = None,
                   target: Optional[HardwareTarget] = None
                   ) -> tiling.AttentionPlan:
    """Cached attention plan (capacity-sized, NOT clamped to divisors)."""
    target = target or get_target()
    in_bytes = target.word_bytes if in_bytes is None else in_bytes
    return _attention_plan(target, seq_q, seq_kv, head_dim, in_bytes)


def attention_kernel_plan(seq_q: int, seq_kv: int, head_dim: int, *,
                          in_bytes: Optional[int] = None,
                          target: Optional[HardwareTarget] = None
                          ) -> tiling.AttentionPlan:
    return clamp_attention_plan(
        attention_plan(seq_q, seq_kv, head_dim, in_bytes=in_bytes,
                       target=target), seq_q, seq_kv)


def scan_kernel_plan(seq: int, d_inner: int, d_state: int, *,
                     target: Optional[HardwareTarget] = None
                     ) -> tiling.ScanChunkPlan:
    return clamp_scan_plan(_scan_plan(target or get_target(), seq, d_inner,
                                      d_state), seq)


def plan_cache_info() -> Dict[str, Tuple]:
    return {"matmul": _matmul_plan.cache_info(),
            "attention": _attention_plan.cache_info(),
            "scan": _scan_plan.cache_info()}


def plan_cache_clear() -> None:
    _matmul_plan.cache_clear()
    _attention_plan.cache_clear()
    _scan_plan.cache_clear()


# ---------------------------------------------------------------------------
# KernelPlans / Mem3DPlanner
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelPlans:
    """Capacity-aware block plans for a model's hot ops.

    These are *capacity* plans for the shape cell they were planned at; the
    kernel entry points (:mod:`repro.kernels.ops`) clamp them to the concrete
    call shapes via the ``clamp_*_plan`` helpers, so threading one KernelPlans
    through layers with differing sequence lengths is safe.
    """

    matmul: tiling.MatmulPlan
    attention: Optional[tiling.AttentionPlan]
    scan_chunk: Optional[tiling.ScanChunkPlan]
    target_name: str = ""


class Mem3DPlanner:
    """Joint capacity/tiling/hierarchy planner, parametric in the target."""

    def __init__(self, target: Optional[HardwareTarget] = None):
        self._target = target

    @property
    def target(self) -> HardwareTarget:
        return self._target or get_target()

    @property
    def profile(self):
        return self.target.profile

    def plan_for(self, *, d_model: int, d_ff: int, seq_q: int, seq_kv: int,
                 head_dim: int, tokens_per_device: int,
                 ssm_d_inner: int = 0, ssm_d_state: int = 0) -> KernelPlans:
        target = self.target
        mm = matmul_kernel_plan(tokens_per_device, d_model, d_ff,
                                target=target)
        attn = None
        if head_dim:
            attn = attention_plan(seq_q, seq_kv, head_dim, target=target)
        scan = None
        if ssm_d_inner:
            scan = _scan_plan(target, seq_q, ssm_d_inner, ssm_d_state)
        return KernelPlans(matmul=mm, attention=attn, scan_chunk=scan,
                           target_name=target.name)
