"""Tile partitioning / die-utilization model (paper §IV, Table I).

The paper partitions each MemPool tile into a logic die (4 Snitch cores +
interconnect, 60 kGE/core) and a memory die (16 SPM banks + 2 KiB I$). We
rebuild that decision procedure:

  * SRAM area model ``bank_area(bytes) = a + b * bytes`` (periphery + bitcell
    array), calibrated by least squares against the *memory-die* utilization
    column of Table I (the only primitive area data the paper publishes).
  * Logic-die cell area ``L`` calibrated from the 3D-1MiB row (90 % util on a
    0.667-normalized footprint).
  * Partitioning rule: put every SPM bank + the I$ on the memory die; if the
    memory die would then be larger than the logic die at the flow's maximum
    utilization, migrate banks (I$ first) to the logic die until the dies
    balance — reproducing the paper's 15/16-bank arrangement for 8 MiB.

Predicted footprints and utilizations match Table I within ~6 % (validated in
``tests/test_area_model.py``; reported side-by-side in ``benchmarks.table1_tile``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.hw_profiles import KiB, MiB

# --- Calibration inputs (Table I, 3D rows; areas in units of the ------------
# --- 2D-1MiB tile footprint). ------------------------------------------------

#: Logic-die cell area: 90% utilization on a 0.667 footprint.
LOGIC_CELL_AREA = 0.90 * 0.667

#: Memory-die cell areas implied by Table I (util * footprint).
_MEM_CELL_AREA = {
    16 * KiB: 0.51 * 0.667,    # 1 MiB cluster -> 16 KiB / tile  (+ I$)
    32 * KiB: 0.65 * 0.667,    # 2 MiB                            (+ I$)
    64 * KiB: 0.89 * 0.767,    # 4 MiB                            (+ I$)
    128 * KiB: 0.933 * 15 / 15,  # 8 MiB: 15/16 banks, no I$ -> see below
}

BANKS_PER_TILE = 16
ICACHE_BYTES = 2 * KiB
TARGET_UTIL = 0.90            # the flow's standard-cell density target
MIXED_MEM_UTIL = 0.89         # SPM macros + I$ on one die (paper Fig. 3b)
PURE_MEM_UTIL = 1.00          # pure SPM-macro array (paper Fig. 3c, 5x3)

# Least-squares calibration of [A = 16 a (total periphery), b, icache_area]:
#   A + b*c + i = mem_cell_area(c)      for c in {16,32,64} KiB (I$ on mem die)
#   A + b*c * (15/16) + 0 = 0.933       for c = 128 KiB (15 banks, I$ on logic)
_rows = []
_rhs = []
for _c in (16 * KiB, 32 * KiB, 64 * KiB):
    _rows.append([1.0, float(_c), 1.0])
    _rhs.append(_MEM_CELL_AREA[_c])
_rows.append([15.0 / 16.0, 128 * KiB * 15.0 / 16.0, 0.0])
_rhs.append(0.933)
_sol, *_ = np.linalg.lstsq(np.asarray(_rows), np.asarray(_rhs), rcond=None)
SRAM_PERIPHERY_AREA, SRAM_AREA_PER_BYTE, ICACHE_AREA = (float(x) for x in _sol)


def sram_area(spm_bytes_per_tile: int, n_banks: int = BANKS_PER_TILE) -> float:
    """Area of ``n_banks`` banks holding ``spm_bytes_per_tile`` in total."""
    frac = n_banks / BANKS_PER_TILE
    return SRAM_PERIPHERY_AREA * frac + SRAM_AREA_PER_BYTE * spm_bytes_per_tile * frac


@dataclasses.dataclass(frozen=True)
class TilePartition:
    """A logic/memory-die assignment for one MemPool tile."""

    flow: str
    spm_bytes_per_tile: int
    banks_on_mem_die: int
    icache_on_mem_die: bool
    footprint: float              # normalized to the 2D-1MiB tile
    logic_util: float
    mem_util: float | None        # None for 2D flows

    @property
    def spm_cluster_mib(self) -> float:
        return self.spm_bytes_per_tile * 64 / MiB


def partition_tile(flow: str, spm_cluster_bytes: int) -> TilePartition:
    """The paper's partitioning procedure for one tile."""
    c = spm_cluster_bytes // 64   # per-tile SPM
    total_sram = sram_area(c) + ICACHE_AREA

    if flow == "2D":
        cell = LOGIC_CELL_AREA + total_sram
        fp = cell / TARGET_UTIL
        return TilePartition(flow, c, 0, False, fp, TARGET_UTIL, None)

    # 3D: exhaustive min-footprint search over bank/I$ assignments.  A mixed
    # memory die (SPM macros + I$) packs to at most MIXED_MEM_UTIL; a pure
    # SPM-macro array (the paper's 5x3 arrangement) packs to ~100 %.
    best = None
    for icache_mem in (True, False):
        for banks_mem in range(BANKS_PER_TILE, 0, -1):
            # SPM banks migrate only together with the I$: the logic die has a
            # single SRAM region (paper's 8 MiB floorplan: "one SPM bank and
            # all the tile's instruction cache banks").
            if banks_mem < BANKS_PER_TILE and icache_mem:
                continue
            mem_cell = sram_area(c, banks_mem) + (ICACHE_AREA if icache_mem else 0.0)
            logic_cell = (LOGIC_CELL_AREA +
                          sram_area(c, BANKS_PER_TILE - banks_mem) +
                          (0.0 if icache_mem else ICACHE_AREA))
            mem_cap = MIXED_MEM_UTIL if icache_mem else PURE_MEM_UTIL
            fp = max(logic_cell / TARGET_UTIL, mem_cell / mem_cap)
            cand = TilePartition(flow, c, banks_mem, icache_mem, fp,
                                 logic_cell / fp, mem_cell / fp)
            # strict improvement required, so the default partition wins ties
            if best is None or fp < best.footprint - 1e-9:
                best = cand
    assert best is not None
    return best


def table1(capacities_mib=(1, 2, 4, 8)) -> List[Dict]:
    """Model predictions laid out like the paper's Table I."""
    base = partition_tile("2D", 1 * MiB).footprint
    rows = []
    for flow in ("2D", "3D"):
        for mib in capacities_mib:
            p = partition_tile(flow, mib * MiB)
            rows.append(dict(
                flow=flow, spm_mib=mib,
                footprint=p.footprint / base,
                logic_util=p.logic_util,
                mem_util=p.mem_util,
                banks_on_mem_die=p.banks_on_mem_die,
                icache_on_mem_die=p.icache_on_mem_die,
            ))
    return rows


#: Paper's Table I, for validation (footprint normalized to 2D-1MiB).
PAPER_TABLE1 = {
    ("2D", 1): dict(footprint=1.000, logic_util=0.90, mem_util=None),
    ("2D", 2): dict(footprint=1.104, logic_util=0.90, mem_util=None),
    ("2D", 4): dict(footprint=1.420, logic_util=0.84, mem_util=None),
    ("2D", 8): dict(footprint=1.817, logic_util=0.86, mem_util=None),
    ("3D", 1): dict(footprint=0.667, logic_util=0.90, mem_util=0.51),
    ("3D", 2): dict(footprint=0.667, logic_util=0.90, mem_util=0.65),
    ("3D", 4): dict(footprint=0.767, logic_util=0.85, mem_util=0.89),
    ("3D", 8): dict(footprint=0.933, logic_util=0.84, mem_util=1.00),
}
