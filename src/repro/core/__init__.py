"""MemPool-3D core: hardware profiles, capacity-aware tiling, perf/energy models."""

from repro.core.hw_profiles import (MEMPOOL_PROFILES, TPU_PROFILES,
                                    MemPoolProfile, TpuProfile,
                                    get_tpu_profile, mempool_profile)
from repro.core.target import (CapacityPartition, HardwareTarget,
                               MemoryHierarchy, MemoryLevel,
                               available_targets, get_target, set_target,
                               use_target)
from repro.core.tiling import (AttentionPlan, MatmulPlan, ScanChunkPlan,
                               mempool_tile_size, plan_attention, plan_matmul,
                               plan_scan_chunk)
from repro.core.perf_model import matmul_cycles, fig6_table, speedup_vs_baseline
from repro.core.energy import derive, derive_all, pdp_table
from repro.core.area_model import partition_tile, table1
from repro.core.planner import (KernelPlans, Mem3DPlanner, RooflineReport,
                                attention_kernel_plan, attention_plan,
                                matmul_kernel_plan, scan_kernel_plan)
