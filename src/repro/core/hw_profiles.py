"""Hardware profiles: the paper's eight MemPool configurations plus TPU targets.

The MemPool profiles are calibrated with the *primitive* rows of Table II of the
paper (effective frequency, total power, footprint, combined die area, wire
length, buffers, F2F bumps), all normalized to the MemPool-2D(1 MiB) baseline.
Derived metrics (PDP, performance, energy efficiency, EDP) are NOT stored: they
are computed by :mod:`repro.core.energy` and validated against the paper's
derived rows in the benchmarks — that round trip is the reproduction.

TPU profiles carry the constants used for the roofline analysis
(:mod:`benchmarks.roofline`): peak bf16 FLOP/s, HBM bandwidth, ICI link
bandwidth, and the VMEM capacity that plays the role of MemPool's L1 SPM.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

KiB = 1024
MiB = 1024 * KiB
GiB = 1024 * MiB


@dataclasses.dataclass(frozen=True)
class MemPoolProfile:
    """One row of the paper's Table II (primitive metrics only)."""

    name: str
    flow: str                 # "2D" | "3D"
    spm_bytes: int            # shared-L1 scratchpad capacity of the cluster
    freq_norm: float          # effective frequency, normalized to 2D-1MiB
    power_norm: float         # total power, normalized to 2D-1MiB
    footprint_norm: float     # group footprint
    die_area_norm: float      # combined die area (cost proxy)
    wire_length_norm: float
    n_buffers: float
    n_f2f_bumps: float | None  # None for 2D flows
    tns_norm: float           # total negative slack (normalized)
    n_failing_paths: int

    # Architectural constants shared by every MemPool instance (paper §II).
    n_cores: int = 256
    n_tiles: int = 64
    n_groups: int = 4
    banks_per_tile: int = 16
    word_bytes: int = 4
    # Interconnect latency hierarchy (cycles): tile-local / group / cluster.
    latency_local: int = 1
    latency_group: int = 3
    latency_cluster: int = 5

    @property
    def spm_per_tile(self) -> int:
        return self.spm_bytes // self.n_tiles

    @property
    def key(self) -> Tuple[str, int]:
        return (self.flow, self.spm_bytes)


def _mp(flow: str, mib: int, freq: float, power: float, fp: float, area: float,
        wl: float, nbuf: float, bumps: float | None, tns: float,
        nfail: int) -> MemPoolProfile:
    return MemPoolProfile(
        name=f"MemPool-{flow}_{mib}MiB", flow=flow, spm_bytes=mib * MiB,
        freq_norm=freq, power_norm=power, footprint_norm=fp,
        die_area_norm=area, wire_length_norm=wl, n_buffers=nbuf,
        n_f2f_bumps=bumps, tns_norm=tns, n_failing_paths=nfail)


#: Table II of the paper, primitive rows, normalized to MemPool-2D(1 MiB).
MEMPOOL_PROFILES: Dict[str, MemPoolProfile] = {p.name: p for p in [
    _mp("2D", 1, 1.000, 1.000, 1.000, 1.000, 1.000, 182.9e3, None, -1.000, 1140),
    _mp("2D", 2, 0.930, 1.045, 1.074, 1.074, 1.036, 190.3e3, None, -2.080, 1636),
    _mp("2D", 4, 0.875, 1.129, 1.299, 1.299, 1.131, 212.5e3, None, -5.887, 4396),
    _mp("2D", 8, 0.885, 1.299, 1.572, 1.572, 1.294, 217.6e3, None, -5.212, 4352),
    _mp("3D", 1, 1.040, 0.913, 0.665, 1.330, 0.803, 151.5e3, 78.3e3, -0.184, 1046),
    _mp("3D", 2, 0.979, 0.958, 0.665, 1.330, 0.803, 151.2e3, 78.9e3, -0.458, 1332),
    _mp("3D", 4, 0.955, 1.041, 0.737, 1.474, 0.844, 166.5e3, 84.4e3, -0.604, 1747),
    _mp("3D", 8, 0.930, 1.173, 0.857, 1.714, 0.888, 156.1e3, 86.2e3, -0.962, 2403),
]}

SPM_CAPACITIES_MIB = (1, 2, 4, 8)


def mempool_profile(flow: str, mib: int) -> MemPoolProfile:
    return MEMPOOL_PROFILES[f"MemPool-{flow}_{mib}MiB"]


@dataclasses.dataclass(frozen=True)
class TpuProfile:
    """Roofline constants for a TPU target (per chip unless noted)."""

    name: str
    peak_flops_bf16: float     # FLOP/s
    hbm_bw: float              # bytes/s
    hbm_bytes: int             # capacity, bytes
    ici_link_bw: float         # bytes/s per link, per direction
    ici_links: int             # torus links per chip
    vmem_bytes: int            # the "shared-L1 SPM" of the TPU world
    mxu_dim: int = 128         # systolic array edge -> matmul tiling alignment
    sublanes: int = 8          # VREG sublane count -> second-minor alignment
    dci_bw: float = 25.0e9     # inter-pod (data-center) bytes/s per chip, est.

    @property
    def ici_bw_total(self) -> float:
        return self.ici_link_bw * self.ici_links


#: TPU v5e — the dry-run / roofline target (values from public spec sheets).
TPU_V5E = TpuProfile(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * GiB,
    ici_link_bw=50e9,
    ici_links=4,
    vmem_bytes=128 * MiB,
)

#: TPU v5p, kept for profile-sweep experiments (beyond-paper exploration).
TPU_V5P = TpuProfile(
    name="tpu-v5p",
    peak_flops_bf16=459e12,
    hbm_bw=2765e9,
    hbm_bytes=95 * GiB,
    ici_link_bw=100e9,
    ici_links=6,
    vmem_bytes=128 * MiB,
)

TPU_PROFILES = {p.name: p for p in (TPU_V5E, TPU_V5P)}


def get_tpu_profile(name: str = "tpu-v5e") -> TpuProfile:
    return TPU_PROFILES[name]
