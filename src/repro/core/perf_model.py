"""The paper's §VI-A cycle-count model (memory phase / compute phase).

The kernel multiplies two M x M matrices (M = 326400, the lcm of the four tile
sizes) that live in off-chip memory. Output tiles of size t x t are produced
one at a time; for each of the M/t K-steps the cores (1) run a *memory phase*
loading the next A and B tiles and synchronizing, then (2) a *compute phase*
on the loaded tiles. Each input element is hence loaded exactly M/t times.

Cycle model per K-step:
    memory  = 2 * t^2 * word_bytes / bw          (bw in bytes/cycle)
    compute = t^3 * cyc_per_mac                  (cluster-wide)
    static  = s                                  (loop setup + synchronization)
plus a store phase of t^2 * word_bytes / bw per finished output tile.

Two calibration constants — CYC_PER_MAC (the cluster's effective MAC
throughput, i.e. Snitch cores co-issuing loads with MACs) and STATIC_OVERHEAD
(cycles per phase pair) — are fitted to the three speedups the paper reports
in Fig. 6 (43 % @ 4 B/cyc, 16 % @ 16 B/cyc, 8 % @ 64 B/cyc for 8 MiB vs 1 MiB).
The fit lands at ~0.0112 cycles/MAC (~89.5 MACs/cycle cluster-wide, ~0.35 per
core — consistent with Snitch's load/MAC co-issue) and ~5000 cycles of static
overhead per phase pair. `tests/test_perf_model.py` asserts the round trip.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Sequence

from repro.core import tiling
from repro.core.hw_profiles import MiB

#: Matrix dimension used throughout the paper (lcm of 256, 384, 544, 800).
PAPER_M = 326400

#: Off-chip bandwidths analyzed in the paper (bytes/cycle). 16 B/cyc = 1 DDR ch.
PAPER_BANDWIDTHS = (4, 8, 16, 32, 64)
DDR_CHANNEL_BW = 16

#: Calibrated constants (see module docstring and tests/test_perf_model.py).
CYC_PER_MAC = 0.01115
STATIC_OVERHEAD = 9850.0


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    memory_cycles: float
    compute_cycles: float
    static_cycles: float
    store_cycles: float

    @property
    def total(self) -> float:
        return (self.memory_cycles + self.compute_cycles +
                self.static_cycles + self.store_cycles)


def matmul_cycles(m: int = PAPER_M, *, spm_bytes: int = 1 * MiB,
                  bw_bytes_per_cycle: float = DDR_CHANNEL_BW,
                  word_bytes: int = 4,
                  cyc_per_mac: float = CYC_PER_MAC,
                  static_overhead: float = STATIC_OVERHEAD,
                  tile: int | None = None) -> PhaseBreakdown:
    """Cycle count of the paper's tiled matmul for a given SPM capacity."""
    t = tile if tile is not None else tiling.mempool_tile_size(spm_bytes, word_bytes)
    k_steps = m // t
    n_out_tiles = k_steps * k_steps
    mem = n_out_tiles * k_steps * (2 * t * t * word_bytes / bw_bytes_per_cycle)
    comp = n_out_tiles * k_steps * (t ** 3) * cyc_per_mac
    stat = n_out_tiles * k_steps * static_overhead
    store = n_out_tiles * (t * t * word_bytes / bw_bytes_per_cycle)
    return PhaseBreakdown(mem, comp, stat, store)


def speedup_vs_baseline(spm_bytes: int, bw: float, *,
                        base_spm: int = 1 * MiB,
                        base_bw: float | None = None,
                        m: int = PAPER_M) -> float:
    """Fig. 6 ordinate: cycle-count speedup vs the 1 MiB configuration."""
    base_bw = bw if base_bw is None else base_bw
    base = matmul_cycles(m, spm_bytes=base_spm, bw_bytes_per_cycle=base_bw).total
    cur = matmul_cycles(m, spm_bytes=spm_bytes, bw_bytes_per_cycle=bw).total
    return base / cur


def fig6_table(capacities_mib: Sequence[int] = (1, 2, 4, 8),
               bandwidths: Iterable[float] = PAPER_BANDWIDTHS,
               m: int = PAPER_M) -> Dict[float, Dict[int, float]]:
    """Speedups relative to (1 MiB, 4 B/cycle) — the paper's Fig. 6 layout."""
    out: Dict[float, Dict[int, float]] = {}
    base = matmul_cycles(m, spm_bytes=1 * MiB, bw_bytes_per_cycle=4).total
    for bw in bandwidths:
        row = {}
        for cap in capacities_mib:
            cur = matmul_cycles(m, spm_bytes=cap * MiB, bw_bytes_per_cycle=bw).total
            row[cap] = base / cur
        out[bw] = row
    return out
