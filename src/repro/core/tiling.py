"""Capacity-aware tile planning — the paper's core algorithmic idea.

MemPool-3D's §VI picks the GEMM tile edge ``t`` as the largest tile whose
working set *fully utilizes* the shared-L1 SPM; each input element is then
loaded exactly ``M/t`` times from off-chip, so capacity buys reuse. This module
reproduces that selection exactly (:func:`mempool_tile_size` yields the paper's
t = 256/384/544/800 for 1/2/4/8 MiB) and generalizes it to TPU kernels: the
same "fill the scratchpad" rule sizes Pallas ``BlockSpec`` blocks for matmul,
blockwise attention, and SSM scan chunks, under MXU/VREG alignment instead of
bank-interleaving constraints.

Every planner below checks candidate working sets against a
:class:`repro.core.target.CapacityPartition` — the budget contract of the
current :class:`~repro.core.target.HardwareTarget`'s scratchpad level
(DESIGN.md §CapacityPartition). Callers normally go through the cached entry
points in :mod:`repro.core.planner`; the ``profile=`` escape hatch partitions
an explicit :class:`TpuProfile` for sweeps and tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.hw_profiles import TpuProfile
from repro.core.target import (CapacityPartition, MEMPOOL_DB_MARGIN,
                               MEMPOOL_TILE_ALIGN, get_target)

# ---------------------------------------------------------------------------
# The paper's tile-size rule (MemPool, §VI-A).
#
# Working set per tile step: the A, B and C tiles resident (3 t^2 words) plus
# a quarter-tile margin for the double-buffered fill of the next input tile
# and DMA metadata — 3.25 t^2 words total, i.e. 2 streamed tiles with the
# 0.125 double-buffer margin plus 1 resident accumulator tile. The largest t
# that is a multiple of 32 (MemPool: 4 banks/core * 8 rows interleave) and
# fits the SPM reproduces the paper's published tile sizes for every capacity:
#     1 MiB -> 256,  2 MiB -> 384,  4 MiB -> 544,  8 MiB -> 800.
# ---------------------------------------------------------------------------

#: effective resident-tile factor: 2 * (1 + db_margin) + 1 accumulator = 3.25
MEMPOOL_RESIDENT_TILES = 2.0 * (1.0 + MEMPOOL_DB_MARGIN) + 1.0


def mempool_partition(spm_bytes: int, word_bytes: int = 4) -> CapacityPartition:
    """The MemPool cluster-SPM partition: single-buffered streams with the
    paper's quarter-tile refill margin."""
    return CapacityPartition(capacity_bytes=spm_bytes, fraction=1.0,
                             n_buffers=1, db_margin=MEMPOOL_DB_MARGIN,
                             align=MEMPOOL_TILE_ALIGN, word_bytes=word_bytes)


def mempool_tile_size(spm_bytes: int, word_bytes: int = 4, *,
                      partition: Optional[CapacityPartition] = None) -> int:
    """Largest aligned tile edge t whose working set fits the partition.

    Streamed set: the A and B tiles (2 t^2 words, double-buffer margin
    applied by the partition); resident: the C accumulator tile (t^2 words).
    """
    part = partition or mempool_partition(spm_bytes, word_bytes)
    align = part.align
    factor = 2.0 * part.streamed_multiplier + 1.0
    t_max = math.sqrt(part.budget_bytes / (factor * word_bytes))
    t = int(t_max // align) * align
    if t <= 0:
        raise ValueError(
            f"SPM of {part.budget_bytes} B cannot hold a {align}-aligned tile")
    return t


def loads_per_element(m: int, t: int) -> float:
    """The paper's reuse law: each input element is loaded exactly M/t times."""
    return m / t


def offchip_traffic_bytes(m: int, t: int, word_bytes: int = 4) -> int:
    """Total off-chip traffic for an MxM * MxM GEMM with t-tiling.

    Inputs: 2 * M^2 elements, each loaded M/t times.  Output: M^2 stored once.
    """
    return (2 * m * m * (m // t) + m * m) * word_bytes


# ---------------------------------------------------------------------------
# TPU generalization: Pallas block plans.
# ---------------------------------------------------------------------------


def _round_down(x: int, align: int) -> int:
    return max(align, (x // align) * align)


def _fit_pow2_below(x: int, cap: int) -> int:
    """Largest power of two <= min(x, cap)."""
    v = 1
    while v * 2 <= min(x, cap):
        v *= 2
    return v


def _resolve_partition(partition: Optional[CapacityPartition],
                       profile: Optional[TpuProfile],
                       fraction: float, n_buffers: int) -> CapacityPartition:
    """Partition precedence: explicit partition > explicit profile > current
    target's scratchpad."""
    if partition is not None:
        return partition
    if profile is not None:
        return CapacityPartition(capacity_bytes=profile.vmem_bytes,
                                 fraction=fraction, n_buffers=n_buffers,
                                 align=profile.mxu_dim)
    return get_target().partition(fraction=fraction, n_buffers=n_buffers)


@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    """Block sizes for a (M,K) @ (K,N) matmul kernel.

    vmem model (the TPU analogue of the paper's 3.25-tile working set):
      n_buffers copies of the A and B blocks (double buffering of the HBM->VMEM
      DMA pipeline) + one f32 accumulator block resident across the K loop.
    """

    bm: int
    bk: int
    bn: int
    n_buffers: int = 2

    def streamed_bytes(self, in_bytes: int = 2) -> int:
        """One set of the streamed operand blocks (A + B)."""
        return (self.bm * self.bk + self.bk * self.bn) * in_bytes

    def resident_bytes(self, acc_bytes: int = 4) -> int:
        return self.bm * self.bn * acc_bytes

    def vmem_bytes(self, in_bytes: int = 2, acc_bytes: int = 4) -> int:
        return (self.n_buffers * self.streamed_bytes(in_bytes)
                + self.resident_bytes(acc_bytes))

    def grid(self, m: int, k: int, n: int) -> Tuple[int, int, int]:
        return (pl_cdiv(m, self.bm), pl_cdiv(n, self.bn), pl_cdiv(k, self.bk))

    def hbm_traffic_bytes(self, m: int, k: int, n: int, in_bytes: int = 2,
                          out_bytes: int = 2) -> int:
        """Generalized reuse law: A read n/bn times, B read m/bm times."""
        reads = (m * k * pl_cdiv(n, self.bn) + k * n * pl_cdiv(m, self.bm))
        return reads * in_bytes + m * n * out_bytes

    def arithmetic_intensity(self, m: int, k: int, n: int,
                             in_bytes: int = 2) -> float:
        return (2.0 * m * k * n) / self.hbm_traffic_bytes(m, k, n, in_bytes)


def pl_cdiv(a: int, b: int) -> int:
    return -(-a // b)


def plan_matmul(m: int, k: int, n: int, *,
                partition: Optional[CapacityPartition] = None,
                profile: Optional[TpuProfile] = None,
                in_bytes: Optional[int] = None,
                acc_bytes: int = 4,
                n_buffers: int = 2,
                vmem_fraction: float = 0.75) -> MatmulPlan:
    """Capacity-aware (bm, bk, bn) selection — the paper's t-rule on TPU.

    Strategy (mirrors the paper's square-tile argument): HBM traffic is
    ~ M*K*N*(1/bm + 1/bn), so grow bm ~= bn as large as the partition budget
    allows; bk only has to be deep enough to keep the MXU busy and amortize
    the accumulator writeback, so give it what is left.  All dims are aligned
    to the partition granularity (MXU 128); blocks never exceed the problem
    dims (rounded up to alignment so small problems still lower).
    """
    part = _resolve_partition(partition, profile, vmem_fraction, n_buffers)
    in_bytes = part.word_bytes if in_bytes is None else in_bytes
    a = part.align

    def fits(bm: int, bk: int, bn: int) -> bool:
        cand = MatmulPlan(bm, bk, bn, part.n_buffers)
        return part.fits(cand.streamed_bytes(in_bytes),
                         cand.resident_bytes(acc_bytes))

    # Upper bounds: nothing bigger than the (aligned) problem dims.
    m_cap = _round_down(max(m, a), a)
    n_cap = _round_down(max(n, a), a)
    k_cap = _round_down(max(k, a), a)

    # Square growth of the output block (the paper's t x t), then deepen bk.
    bm = bn = a
    while True:
        nbm, nbn = min(bm * 2, m_cap), min(bn * 2, n_cap)
        if (nbm, nbn) == (bm, bn) or not fits(nbm, a, nbn):
            # try growing just one side (rectangular problems)
            if nbm != bm and fits(nbm, a, bn):
                bm = nbm
                continue
            if nbn != bn and fits(bm, a, nbn):
                bn = nbn
                continue
            break
        bm, bn = nbm, nbn
    bk = a
    while bk * 2 <= k_cap and fits(bm, bk * 2, bn):
        bk *= 2
    return MatmulPlan(bm=bm, bk=bk, bn=bn, n_buffers=part.n_buffers)


@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    """Block sizes for blockwise (flash) attention."""

    block_q: int
    block_kv: int
    n_buffers: int = 2

    def streamed_bytes(self, head_dim: int, in_bytes: int = 2) -> int:
        """One set of the streamed K and V blocks."""
        return 2 * self.block_kv * head_dim * in_bytes

    def resident_bytes(self, head_dim: int, in_bytes: int = 2,
                       acc_bytes: int = 4) -> int:
        q = self.block_q * head_dim * in_bytes
        acc = self.block_q * head_dim * acc_bytes
        scores = self.block_q * self.block_kv * acc_bytes
        stats = 2 * self.block_q * acc_bytes
        return q + acc + scores + stats

    def vmem_bytes(self, head_dim: int, in_bytes: int = 2,
                   acc_bytes: int = 4) -> int:
        return (self.n_buffers * self.streamed_bytes(head_dim, in_bytes)
                + self.resident_bytes(head_dim, in_bytes, acc_bytes))


def plan_attention(seq_q: int, seq_kv: int, head_dim: int, *,
                   partition: Optional[CapacityPartition] = None,
                   profile: Optional[TpuProfile] = None,
                   in_bytes: Optional[int] = None,
                   n_buffers: int = 2,
                   vmem_fraction: float = 0.5,
                   max_block: int = 2048) -> AttentionPlan:
    part = _resolve_partition(partition, profile, vmem_fraction, n_buffers)
    in_bytes = part.word_bytes if in_bytes is None else in_bytes
    a = part.align

    def fits(bq: int, bkv: int) -> bool:
        cand = AttentionPlan(bq, bkv, part.n_buffers)
        return part.fits(cand.streamed_bytes(head_dim, in_bytes),
                         cand.resident_bytes(head_dim, in_bytes))

    bq = _fit_pow2_below(max(seq_q, a), max_block)
    bq = max(a, min(bq, _round_down(max(seq_q, a), a)))
    bkv = a
    while bkv * 2 <= min(seq_kv, max_block) and fits(bq, bkv * 2):
        bkv *= 2
    # shrink bq if even the minimal bkv does not fit
    while bq > a and not fits(bq, bkv):
        bq //= 2
    return AttentionPlan(block_q=bq, block_kv=bkv, n_buffers=part.n_buffers)


@dataclasses.dataclass(frozen=True)
class ScanChunkPlan:
    """Chunk length for the chunked selective scan (SSM) kernel.

    The paper's idea applied to state-space models: the chunk of inputs,
    gates and the (d_inner x d_state) running state must fit VMEM; a longer
    chunk amortizes the sequential inter-chunk dependency (the "static
    overhead" of the paper's phase model).
    """

    chunk: int
    n_buffers: int = 1

    def streamed_bytes(self, d_inner: int, d_state: int,
                       in_bytes: int = 2) -> int:
        seqs = 4 * self.chunk * d_inner * in_bytes      # x, dt, gate, out
        b_c = 2 * self.chunk * d_state * in_bytes       # B_t, C_t
        return seqs + b_c

    def resident_bytes(self, d_inner: int, d_state: int,
                       acc_bytes: int = 4) -> int:
        return d_inner * d_state * acc_bytes            # running state

    def vmem_bytes(self, d_inner: int, d_state: int, in_bytes: int = 2,
                   acc_bytes: int = 4) -> int:
        return (self.n_buffers * self.streamed_bytes(d_inner, d_state, in_bytes)
                + self.resident_bytes(d_inner, d_state, acc_bytes))


def plan_scan_chunk(seq: int, d_inner: int, d_state: int, *,
                    partition: Optional[CapacityPartition] = None,
                    profile: Optional[TpuProfile] = None,
                    in_bytes: Optional[int] = None,
                    n_buffers: int = 1,
                    vmem_fraction: float = 0.5,
                    min_chunk: int = 8,
                    max_chunk: int = 4096) -> ScanChunkPlan:
    part = _resolve_partition(partition, profile, vmem_fraction, n_buffers)
    in_bytes = part.word_bytes if in_bytes is None else in_bytes

    def fits(chunk: int) -> bool:
        cand = ScanChunkPlan(chunk, part.n_buffers)
        return part.fits(cand.streamed_bytes(d_inner, d_state, in_bytes),
                         cand.resident_bytes(d_inner, d_state))

    chunk = min_chunk
    while chunk * 2 <= min(seq, max_chunk) and fits(chunk * 2):
        chunk *= 2
    return ScanChunkPlan(chunk=chunk, n_buffers=part.n_buffers)
