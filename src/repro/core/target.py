"""HardwareTarget — one memory-hierarchy abstraction over MemPool and TPU.

The paper's thesis is that scratchpad capacity, tiling and interconnect
hierarchy must be chosen *together*. That co-design needs a single seam that
answers, for ANY backend: how much fast memory is there, who shares it, what
feeds it, and how is its capacity split among resident operands? This module
is that seam (see DESIGN.md §HardwareTarget):

  * :class:`MemoryHierarchy` — named levels with capacity / bandwidth /
    latency. MemPool's tile/group/cluster SPM view maps onto the TPU's
    VMEM / HBM / ICI / DCI ladder; both are instances of the same type.
  * :class:`CapacityPartition` — the planner's contract with a scratchpad
    level: a budget (capacity x fraction) split between *streamed* operands
    (multiplied by ``n_buffers`` for the DMA double-buffer pipeline, with a
    floor margin for partially-buffered flows — MemPool's quarter-tile
    slack) and *resident* state (accumulators, running SSM state).
  * :class:`TieredPartition` — a CapacityPartition stacked across two memory
    layers (the paper's logic-die / memory-die split): layer-0 and layer-1
    byte budgets under the same ``required_bytes`` contract. The serving
    pool partitions its paged KV cache with it (hot tier / spill tier).
  * a process-wide registry: :func:`get_target` / :func:`set_target` with an
    environment override (``REPRO_TARGET``, read via
    :mod:`repro.runtime_flags`) so launchers and benchmarks select targets
    by name instead of importing profile constants.

Every profile in :mod:`repro.core.hw_profiles` is registered at import time;
``TPU_V5E`` remains the process default so existing plans are unchanged
unless a target is selected.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Dict, Iterator, Optional, Tuple, Union

from repro import runtime_flags
from repro.core.hw_profiles import (MEMPOOL_PROFILES, TPU_PROFILES, TPU_V5E,
                                    MemPoolProfile, TpuProfile)

Profile = Union[TpuProfile, MemPoolProfile]


# ---------------------------------------------------------------------------
# Memory hierarchy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    """One level of a target's memory/interconnect hierarchy.

    ``capacity_bytes`` is ``None`` for pure transport levels (ICI/DCI links,
    MemPool's off-chip port). ``latency`` is in ``latency_unit`` — cycles for
    MemPool (the paper reports cycle counts), seconds for TPU estimates.
    """

    name: str
    capacity_bytes: Optional[int]
    bandwidth: Optional[float]          # bytes/s (TPU) or bytes/cycle (MemPool)
    latency: float
    latency_unit: str = "s"             # "s" | "cycles"
    shared_by: int = 1                  # compute units sharing this level


@dataclasses.dataclass(frozen=True)
class MemoryHierarchy:
    """Ordered levels, nearest (fastest) first."""

    levels: Tuple[MemoryLevel, ...]

    def level(self, name: str) -> MemoryLevel:
        for lv in self.levels:
            if lv.name == name:
                return lv
        raise KeyError(f"no memory level {name!r}; have "
                       f"{[lv.name for lv in self.levels]}")

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(lv.name for lv in self.levels)


# ---------------------------------------------------------------------------
# Capacity partitioning — the budget contract every tile plan is checked
# against (repro.core.tiling).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CapacityPartition:
    """Split of a scratchpad budget among streamed and resident operands.

    ``required = ceil(mult * streamed) + resident`` with
    ``mult = max(n_buffers, 1 + db_margin)``: full double-buffering keeps
    ``n_buffers`` copies of every streamed operand; a partially-buffered flow
    (MemPool's DMA refill) instead reserves ``db_margin`` of one streamed set
    — the paper's quarter-tile slack (2 tiles x 0.125 = 0.25 t^2 words).
    """

    capacity_bytes: int
    fraction: float = 1.0          # share of the level the planner may claim
    n_buffers: int = 2             # copies of each streamed operand
    db_margin: float = 0.0         # floor on streaming slack (see above)
    align: int = 128               # block-edge granularity (MXU / bank rows)
    word_bytes: int = 2            # native streamed-element width (bf16 / f32)

    def __post_init__(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")
        if self.n_buffers < 1:
            raise ValueError(f"n_buffers must be >= 1, got {self.n_buffers}")

    @property
    def budget_bytes(self) -> int:
        return int(self.capacity_bytes * self.fraction)

    @property
    def streamed_multiplier(self) -> float:
        return max(float(self.n_buffers), 1.0 + self.db_margin)

    def required_bytes(self, streamed_bytes: int, resident_bytes: int = 0) -> int:
        """Scratchpad footprint of a candidate working set."""
        return (int(math.ceil(self.streamed_multiplier * streamed_bytes))
                + resident_bytes)

    def fits(self, streamed_bytes: int, resident_bytes: int = 0) -> bool:
        return self.required_bytes(streamed_bytes, resident_bytes) <= self.budget_bytes

    def with_buffers(self, n_buffers: int) -> "CapacityPartition":
        return dataclasses.replace(self, n_buffers=n_buffers)

    def scaled(self, shards: int) -> "CapacityPartition":
        """The aggregate partition a ``shards``-way mesh exposes: each shard
        contributes its own copy of this level, so the pool the planner
        prices against grows linearly — the paper's more-dies-more-capacity
        argument applied across chips instead of across bonded layers.
        ``shards=1`` is the identity (single-device budgets unchanged)."""
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if shards == 1:
            return self
        return dataclasses.replace(
            self, capacity_bytes=self.capacity_bytes * shards)

    def stacked(self, layer1_fraction: float) -> "TieredPartition":
        """Stack a second memory layer on this partition (the paper's 3D
        move): layer 0 keeps this budget, layer 1 adds
        ``layer1_fraction x capacity`` of the same level — a second die
        bonded on top, holding capacity the 2D floorplan could not."""
        if layer1_fraction < 0.0:
            raise ValueError(
                f"layer1_fraction must be >= 0, got {layer1_fraction}")
        layer1 = dataclasses.replace(
            self, capacity_bytes=int(self.capacity_bytes * layer1_fraction))
        return TieredPartition(layer0=self, layer1=layer1)


@dataclasses.dataclass(frozen=True)
class TieredPartition:
    """A :class:`CapacityPartition` split across two stacked memory layers.

    MemPool-3D's headline move is partitioning one logical memory across two
    bonded dies: layer 0 (the logic die's fast share) and layer 1 (the
    stacked memory die). The serving pool reuses the shape: layer 0 is the
    hot tier resident sequences decode against; layer 1 is the spill tier
    preempted sequences park in — same budget formula, one more layer.
    """

    layer0: CapacityPartition
    layer1: CapacityPartition

    @property
    def tiers(self) -> Tuple[CapacityPartition, ...]:
        return (self.layer0, self.layer1)

    @property
    def budget_bytes(self) -> int:
        """Combined two-layer budget (the 3D capacity win)."""
        return self.layer0.budget_bytes + self.layer1.budget_bytes

    def tier_budgets(self) -> Tuple[int, int]:
        return (self.layer0.budget_bytes, self.layer1.budget_bytes)

    def scaled(self, shards: int) -> "TieredPartition":
        """Scale both stacked layers by the mesh shard count (see
        :meth:`CapacityPartition.scaled`)."""
        if shards == 1:
            return self
        return TieredPartition(layer0=self.layer0.scaled(shards),
                               layer1=self.layer1.scaled(shards))

    def units_per_tier(self, unit_bytes, resident_bytes: int = 0
                       ) -> Tuple[int, int]:
        """How many ``unit_bytes``-sized blocks each layer sustains, pricing
        one unit with the SAME ``required_bytes`` contract the tile planner
        uses. ``resident_bytes`` is charged against layer 0 only (resident
        state never spills a layer down by itself).

        ``unit_bytes`` is one int when both layers store a unit identically,
        or a per-tier ``(layer0_bytes, layer1_bytes)`` pair when the tiers
        encode differently — tier-aware KV compression prices a page per
        CODEC, so a quantized tier fits more pages in the same budget
        (DESIGN.md §Tiered KV compression)."""
        per_tier = (unit_bytes if isinstance(unit_bytes, (tuple, list))
                    else (unit_bytes, unit_bytes))
        out = []
        for i, tier in enumerate(self.tiers):
            budget = tier.budget_bytes - (resident_bytes if i == 0 else 0)
            per = tier.required_bytes(per_tier[i])
            out.append(max(0, budget // max(per, 1)))
        return (out[0], out[1])


# ---------------------------------------------------------------------------
# HardwareTarget
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareTarget:
    """A backend the planner can size plans for.

    ``scratchpad_level`` names the hierarchy level whose capacity the tile
    planner partitions: VMEM on TPU, the full shared-L1 cluster SPM on
    MemPool (the paper's t-rule fills the whole pool).
    """

    name: str
    kind: str                          # "tpu" | "mempool"
    hierarchy: MemoryHierarchy
    profile: Profile
    scratchpad_level: str
    tile_align: int                    # block-edge alignment for plans
    word_bytes: int                    # native word the capacity rule counts
    db_margin: float = 0.0             # default double-buffer floor margin

    @property
    def scratchpad_bytes(self) -> int:
        cap = self.hierarchy.level(self.scratchpad_level).capacity_bytes
        assert cap is not None, self.scratchpad_level
        return cap

    def partition(self, *, fraction: float = 1.0, n_buffers: int = 2,
                  db_margin: Optional[float] = None) -> CapacityPartition:
        """A :class:`CapacityPartition` of this target's scratchpad."""
        return CapacityPartition(
            capacity_bytes=self.scratchpad_bytes, fraction=fraction,
            n_buffers=n_buffers,
            db_margin=self.db_margin if db_margin is None else db_margin,
            align=self.tile_align, word_bytes=self.word_bytes)


def tpu_target(profile: TpuProfile) -> HardwareTarget:
    """VMEM / HBM / ICI / DCI — the TPU instance of the hierarchy.

    Latencies are public-order-of-magnitude estimates; planning uses only
    capacities and bandwidths.
    """
    hierarchy = MemoryHierarchy(levels=(
        MemoryLevel("vmem", profile.vmem_bytes, None, 30e-9, "s", 1),
        MemoryLevel("hbm", profile.hbm_bytes, profile.hbm_bw, 500e-9, "s", 1),
        MemoryLevel("ici", None, profile.ici_bw_total, 1e-6, "s",
                    shared_by=256),
        MemoryLevel("dci", None, profile.dci_bw, 10e-6, "s", shared_by=512),
    ))
    return HardwareTarget(
        name=profile.name, kind="tpu", hierarchy=hierarchy, profile=profile,
        scratchpad_level="vmem", tile_align=profile.mxu_dim, word_bytes=2)


#: MemPool bank-interleaving alignment: 4 banks/core x 8 interleave rows.
MEMPOOL_TILE_ALIGN = 32
#: The paper's quarter-tile double-buffer slack: 2 streamed tiles x 0.125
#: = 0.25 t^2 words on top of the 3 resident tiles (working set 3.25 t^2).
MEMPOOL_DB_MARGIN = 0.125


def mempool_target(profile: MemPoolProfile) -> HardwareTarget:
    """tile / group / cluster / off-chip — the MemPool instance."""
    hierarchy = MemoryHierarchy(levels=(
        MemoryLevel("tile", profile.spm_per_tile, None,
                    profile.latency_local, "cycles",
                    shared_by=profile.n_cores // profile.n_tiles),
        MemoryLevel("group", profile.spm_bytes // profile.n_groups, None,
                    profile.latency_group, "cycles",
                    shared_by=profile.n_cores // profile.n_groups),
        MemoryLevel("cluster", profile.spm_bytes, None,
                    profile.latency_cluster, "cycles",
                    shared_by=profile.n_cores),
        MemoryLevel("offchip", None, None, 100.0, "cycles",
                    shared_by=profile.n_cores),
    ))
    return HardwareTarget(
        name=profile.name.lower(), kind="mempool", hierarchy=hierarchy,
        profile=profile, scratchpad_level="cluster",
        tile_align=MEMPOOL_TILE_ALIGN, word_bytes=profile.word_bytes,
        db_margin=MEMPOOL_DB_MARGIN)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_LOCK = threading.Lock()
_REGISTRY: Dict[str, HardwareTarget] = {}
_CURRENT: Optional[HardwareTarget] = None


def _norm(name: str) -> str:
    return name.lower().replace("_", "-")


def register_target(target: HardwareTarget) -> HardwareTarget:
    with _LOCK:
        _REGISTRY[_norm(target.name)] = target
    return target


def available_targets(kind: Optional[str] = None) -> Tuple[str, ...]:
    with _LOCK:
        return tuple(sorted(n for n, t in _REGISTRY.items()
                            if kind is None or t.kind == kind))


def get_target(name: Optional[str] = None) -> HardwareTarget:
    """Resolve a target: explicit name > set_target() > $REPRO_TARGET > default."""
    if name is not None:
        return _lookup(name)
    if _CURRENT is not None:
        return _CURRENT
    env = runtime_flags.target_name()
    if env:
        return _lookup(env)
    return _lookup(TPU_V5E.name)


def _lookup(name: str) -> HardwareTarget:
    try:
        with _LOCK:
            return _REGISTRY[_norm(name)]
    except KeyError:
        raise KeyError(f"unknown hardware target {name!r}; available: "
                       f"{', '.join(available_targets())}") from None


def set_target(target: Union[HardwareTarget, str, None]) -> Optional[HardwareTarget]:
    """Set the process-wide current target (by name or instance).

    ``None`` clears the override (falls back to env/default). Returns the
    previously set target (``None`` if the default was in effect).
    """
    global _CURRENT
    if isinstance(target, str):
        target = _lookup(target)
    with _LOCK:
        prev, _CURRENT = _CURRENT, target
    return prev


@contextlib.contextmanager
def use_target(target: Union[HardwareTarget, str]) -> Iterator[HardwareTarget]:
    prev = set_target(target)
    try:
        yield get_target()
    finally:
        set_target(prev)


for _p in TPU_PROFILES.values():
    register_target(tpu_target(_p))
for _p in MEMPOOL_PROFILES.values():
    register_target(mempool_target(_p))
del _p
