"""Paper Table II: group PPA — primitive profile rows + derived PDP, with the
3D-vs-2D deltas the paper prints in parentheses."""

from __future__ import annotations

from repro.core import energy
from repro.core.hw_profiles import SPM_CAPACITIES_MIB
from repro.core.target import get_target

from benchmarks.common import fmt_table, pct, save_artifact

#: the paper's printed PDP deltas (3D vs 2D), for side-by-side validation
PAPER_PDP_DELTA = {1: -0.12, 2: -0.13, 4: -0.16, 8: -0.14}
PAPER_FREQ_DELTA = {1: +0.040, 2: +0.052, 4: +0.091, 8: +0.051}


def run() -> str:
    pdp = energy.pdp_table()
    rows = []
    arts = []
    for mib in SPM_CAPACITIES_MIB:
        # select the flow targets by name through the registry
        p2 = get_target(f"mempool-2d-{mib}mib").profile
        p3 = get_target(f"mempool-3d-{mib}mib").profile
        fp_delta = p3.footprint_norm / p2.footprint_norm - 1
        freq_delta = p3.freq_norm / p2.freq_norm - 1
        pdp_delta = pdp[p3.name] / pdp[p2.name] - 1
        rows.append([
            f"{mib} MiB",
            f"{p2.footprint_norm:.3f}/{p3.footprint_norm:.3f}", pct(fp_delta),
            f"{p2.freq_norm:.3f}/{p3.freq_norm:.3f}",
            f"{pct(freq_delta)} (paper {pct(PAPER_FREQ_DELTA[mib])})",
            f"{p2.power_norm:.3f}/{p3.power_norm:.3f}",
            f"{pdp[p2.name]:.3f}/{pdp[p3.name]:.3f}",
            f"{pct(pdp_delta)} (paper {pct(PAPER_PDP_DELTA[mib])})",
        ])
        arts.append(dict(mib=mib, fp_delta=fp_delta, freq_delta=freq_delta,
                         pdp_delta=pdp_delta,
                         paper_pdp_delta=PAPER_PDP_DELTA[mib]))
    save_artifact("table2.json", arts)
    return fmt_table(
        ["SPM", "footprint 2D/3D", "Δ", "freq 2D/3D", "Δ (vs paper)",
         "power 2D/3D", "PDP 2D/3D", "ΔPDP (vs paper)"],
        rows, title="Table II — group PPA (derived rows reproduce the paper)")


def main(argv=None) -> None:
    from benchmarks.common import run_cli
    run_cli(run, __doc__, argv)


if __name__ == "__main__":
    main()
