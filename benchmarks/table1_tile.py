"""Paper Table I: tile partitioning / die utilization — model vs published."""

from __future__ import annotations

from repro.core import area_model

from benchmarks.common import fmt_table, save_artifact


def run() -> str:
    rows = []
    arts = []
    for row in area_model.table1():
        paper = area_model.PAPER_TABLE1[(row["flow"], row["spm_mib"])]
        mem_m = "-" if row["mem_util"] is None else f"{row['mem_util']:.2f}"
        mem_p = "-" if paper["mem_util"] is None else f"{paper['mem_util']:.2f}"
        rows.append([
            row["flow"], f"{row['spm_mib']} MiB",
            f"{row['footprint']:.3f}", f"{paper['footprint']:.3f}",
            f"{row['logic_util']:.2f}", f"{paper['logic_util']:.2f}",
            mem_m, mem_p,
            row["banks_on_mem_die"], "yes" if row["icache_on_mem_die"] else "no",
        ])
        arts.append(dict(row, paper=paper))
    save_artifact("table1.json", arts)
    return fmt_table(
        ["flow", "SPM", "footprint(model)", "footprint(paper)",
         "logic util(m)", "(p)", "mem util(m)", "(p)", "banks@mem", "I$@mem"],
        rows, title="Table I — tile partitioning (model vs paper)")


def main(argv=None) -> None:
    from benchmarks.common import run_cli
    run_cli(run, __doc__, argv)


if __name__ == "__main__":
    main()
