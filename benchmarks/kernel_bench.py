"""Kernel micro-benchmarks: wall time of the XLA reference path on CPU plus
the planner's *predicted* TPU analytics (HBM traffic, arithmetic intensity,
roofline time) per capacity-planned block configuration, for a hardware
target selected by name through the registry (default: the current target).

Wall times on CPU are NOT the perf claim (this container has no TPU); they
verify the code runs end-to-end and give a relative sanity signal. The
planner analytics columns are the quantities §Perf iterates on.
"""

from __future__ import annotations

import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.core import planner
from repro.core.target import get_target
from repro.kernels import ops, ref

from benchmarks.common import fmt_table, save_artifact


def _time(fn: Callable, *args, reps: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(target_name: Optional[str] = None) -> str:
    target = get_target(target_name)
    assert target.kind == "tpu", \
        f"kernel bench needs a TPU target, got {target.name}"
    prof = target.profile
    key = jax.random.PRNGKey(0)
    rows: List[List] = []
    arts = []

    # --- matmul at the planner's blocks for a range of shapes
    for m, k, n in [(512, 512, 512), (1024, 2048, 1024), (2048, 2048, 2048)]:
        a = jax.random.normal(key, (m, k), jnp.float32)
        b = jax.random.normal(key, (k, n), jnp.float32)
        plan = planner.matmul_kernel_plan(m, k, n, target=target)
        us = _time(jax.jit(lambda a, b: ops.matmul(a, b, impl="ref")), a, b)
        traffic = plan.hbm_traffic_bytes(m, k, n)
        ai = plan.arithmetic_intensity(m, k, n)
        roof_s = max(2 * m * k * n / prof.peak_flops_bf16,
                     traffic / prof.hbm_bw)
        rows.append(["matmul", f"{m}x{k}x{n}",
                     f"({plan.bm},{plan.bk},{plan.bn})",
                     f"{us:.0f}", f"{traffic/2**20:.1f}", f"{ai:.0f}",
                     f"{roof_s*1e6:.1f}"])
        arts.append(dict(kind="matmul", shape=[m, k, n], cpu_us=us,
                         plan=[plan.bm, plan.bk, plan.bn],
                         hbm_bytes=traffic, intensity=ai,
                         v5e_roofline_us=roof_s * 1e6))

    # --- attention
    for b_, h, s, d in [(1, 8, 1024, 128), (1, 8, 4096, 128)]:
        q = jax.random.normal(key, (b_, h, s, d), jnp.bfloat16)
        kk = jax.random.normal(key, (b_, h, s, d), jnp.bfloat16)
        v = jax.random.normal(key, (b_, h, s, d), jnp.bfloat16)
        plan = planner.attention_plan(s, s, d, target=target)
        us = _time(jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="ref")),
                   q, kk, v)
        flops = 4.0 * b_ * h * s * s * d * 0.5          # causal half
        kv_bytes = b_ * h * s * d * 2 * 2 * (s // (2 * plan.block_q) + 1)
        roof_s = max(flops / prof.peak_flops_bf16,
                     kv_bytes / prof.hbm_bw)
        rows.append(["attention", f"b{b_} h{h} s{s} d{d}",
                     f"(q{plan.block_q},kv{plan.block_kv})",
                     f"{us:.0f}", f"{kv_bytes/2**20:.1f}",
                     f"{flops/kv_bytes:.0f}", f"{roof_s*1e6:.1f}"])
        arts.append(dict(kind="attention", shape=[b_, h, s, d], cpu_us=us,
                         plan=[plan.block_q, plan.block_kv],
                         v5e_roofline_us=roof_s * 1e6))

    # --- selective scan
    for b_, L, di, ds in [(1, 2048, 4096, 16), (1, 8192, 4096, 16)]:
        x = jax.random.normal(key, (b_, L, di), jnp.float32) * 0.1
        dt = jax.nn.softplus(jax.random.normal(key, (b_, L, di))) * 0.1
        a_ = -jnp.exp(jax.random.normal(key, (di, ds)) * 0.1)
        bb = jax.random.normal(key, (b_, L, ds)) * 0.1
        c = jax.random.normal(key, (b_, L, ds)) * 0.1
        dd = jnp.ones((di,))
        plan = planner.scan_kernel_plan(L, di, ds, target=target)
        us = _time(jax.jit(lambda *t: ops.selective_scan(*t, impl="ref")),
                   x, dt, a_, bb, c, dd)
        stream = b_ * L * (4 * di + 2 * ds) * 2
        roof_s = stream / prof.hbm_bw
        rows.append(["mamba_scan", f"b{b_} L{L} di{di}", f"chunk={plan.chunk}",
                     f"{us:.0f}", f"{stream/2**20:.1f}", "-",
                     f"{roof_s*1e6:.1f}"])
        arts.append(dict(kind="mamba_scan", shape=[b_, L, di, ds], cpu_us=us,
                         chunk=plan.chunk, v5e_roofline_us=roof_s * 1e6))

    save_artifact("kernel_bench.json", arts)
    return fmt_table(
        ["kernel", "shape", "planned blocks", "cpu µs (ref)",
         "HBM MiB (plan)", "arith.int.", f"{target.name} roofline µs"],
        rows,
        title=f"Kernel bench — capacity-planned blocks + {target.name} "
              "analytics")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default=None)
    print(run(ap.parse_args().target))


if __name__ == "__main__":
    main()
