"""Shared benchmark helpers: table formatting, artifact IO, target CLI."""

from __future__ import annotations

import contextlib
import glob
import json
import os
from typing import Any, Dict, Iterable, List, Sequence

ARTIFACT_ROOT = os.path.join(os.path.dirname(__file__), "artifacts")
DRYRUN_ROOT = os.path.join(ARTIFACT_ROOT, "dryrun")


def add_target_arg(ap) -> None:
    """Uniform ``--target <name>`` flag: every benchmark script accepts it
    (enforced by ``benchmarks/check_cli.py`` in CI) and resolves the name
    through the process-wide registry."""
    ap.add_argument("--target", default=None, metavar="NAME",
                    help="hardware target name (see repro.core.target; "
                         "default: current/REPRO_TARGET/tpu-v5e)")


def target_scope(name):
    """Context manager applying ``--target`` (no-op when None)."""
    if name is None:
        return contextlib.nullcontext()
    from repro.core.target import use_target
    return use_target(name)


def run_cli(run_fn, doc: str, argv=None) -> None:
    """Standard benchmark entry point: ``--target``-only CLI around a
    zero-argument ``run_fn``. New shared flags land here once, not in
    every script."""
    import argparse
    ap = argparse.ArgumentParser(description=doc)
    add_target_arg(ap)
    args = ap.parse_args(argv)
    with target_scope(args.target):
        print(run_fn())


def fmt_table(headers: Sequence[str], rows: Iterable[Sequence[Any]],
              title: str = "") -> str:
    rows = [[str(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    out = []
    if title:
        out.append(f"== {title} ==")
    out.append(" | ".join(h.ljust(w) for h, w in zip(headers, widths)))
    out.append("-+-".join("-" * w for w in widths))
    for r in rows:
        out.append(" | ".join(c.ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def pct(x: float) -> str:
    return f"{100.0 * x:+.1f}%"


def load_dryrun_artifacts(mesh: str = "16x16") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_ROOT, mesh, "*", "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def save_artifact(name: str, obj: Any) -> str:
    os.makedirs(ARTIFACT_ROOT, exist_ok=True)
    path = os.path.join(ARTIFACT_ROOT, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path
