"""Serving benchmark: static batching vs continuous batching tokens/s.

Drives the same synthetic mixed-length request stream through the same
Engine twice:

  * **static** — requests are grouped into fixed batches of ``n_slots``; a
    batch admits once and decodes until its SLOWEST request drains (empty
    slots idle — the classic straggler cost).
  * **continuous** — one scheduler over the whole stream; drained slots are
    refilled from the queue at every drain boundary.

Both modes share the jitted prefill/decode functions, so the measured delta
is scheduling, not compilation. Emits ``benchmarks/artifacts/
serve_bench.json`` — the serving datapoint of the perf trajectory.

    PYTHONPATH=src python -m benchmarks.serve_bench [--target NAME] [...]
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List

from benchmarks.common import add_target_arg, fmt_table, save_artifact, \
    target_scope


def _run_mode(engine, stream: List[Dict], n_slots: int, mode: str) -> Dict:
    from repro.serve.scheduler import Scheduler
    t0 = time.monotonic()
    reports = []
    if mode == "continuous":
        sch = Scheduler(n_slots=n_slots)
        for spec in stream:
            sch.submit(spec["prompt"], spec["max_new_tokens"])
        reports.append(engine.serve(scheduler=sch))
    else:                                   # static: one batch at a time
        for i in range(0, len(stream), n_slots):
            sch = Scheduler(n_slots=n_slots)
            for spec in stream[i:i + n_slots]:
                sch.submit(spec["prompt"], spec["max_new_tokens"])
            reports.append(engine.serve(scheduler=sch))
    dt = time.monotonic() - t0
    n_tokens = sum(len(r.tokens) for rep in reports for r in rep.requests)
    return {
        "mode": mode,
        "wall_s": dt,
        "n_tokens": n_tokens,
        "tok_per_s": n_tokens / dt if dt else 0.0,
        "decode_steps": sum(rep.stats["decode_steps"] for rep in reports),
        "host_syncs": sum(rep.stats["host_syncs"] for rep in reports),
        "max_slot_reuse": max(rep.stats["max_slot_reuse"]
                              for rep in reports),
        "completed": sum(rep.stats["drained"] for rep in reports),
    }


def run(target_name=None, arch: str = "qwen2.5-3b", n_requests: int = 32,
        prompt_len: int = 16, gen_len: int = 12, n_slots: int = None,
        seed: int = 0) -> str:
    import jax
    from repro.configs import get_reduced
    from repro.core.target import get_target
    from repro.models import build_model
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import derive_n_slots, synthetic_stream

    with target_scope(target_name):
        target = get_target()
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_len = prompt_len + gen_len
        n_slots = n_slots or derive_n_slots(cfg, max_len, max_slots=8)
        engine = Engine(model, params,
                        EngineConfig(max_len=max_len, sync_interval=4))
        stream = synthetic_stream(n_requests, prompt_len, gen_len,
                                  cfg.vocab_size, seed)
        # warmup: compile prefill (per distinct prompt length) + decode chunk
        _run_mode(engine, stream, n_slots, "continuous")
        recs = [_run_mode(engine, stream, n_slots, m)
                for m in ("static", "continuous")]

    stat, cont = recs
    speedup = (cont["tok_per_s"] / stat["tok_per_s"]
               if stat["tok_per_s"] else 0.0)
    artifact = {
        "arch": cfg.name, "target": target.name, "n_requests": n_requests,
        "prompt_len": prompt_len, "gen_len": gen_len, "n_slots": n_slots,
        "static": stat, "continuous": cont, "speedup_tok_per_s": speedup,
    }
    save_artifact("serve_bench.json", artifact)
    rows = [[r["mode"], f"{r['tok_per_s']:.1f}", r["n_tokens"],
             r["decode_steps"], r["host_syncs"], r["max_slot_reuse"],
             f"{r['wall_s']*1e3:.0f} ms"] for r in recs]
    table = fmt_table(
        ["mode", "tok/s", "tokens", "decode steps", "host syncs",
         "max slot reuse", "wall"],
        rows, title=f"Serve bench — {cfg.name}, {n_requests} requests, "
                    f"{n_slots} slots ({target.name})")
    return table + f"\ncontinuous/static speedup: {speedup:.2f}x"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    add_target_arg(ap)
    args = ap.parse_args(argv)
    print(run(args.target, args.arch, args.requests, args.prompt_len,
              args.gen_len, args.slots, args.seed))
    return 0


if __name__ == "__main__":
    sys.exit(main())
