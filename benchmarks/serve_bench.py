"""Serving benchmark: static vs continuous vs paged-two-tier vs
prefix-shared tokens/s AND pool footprint.

Drives the same synthetic mixed short/long request stream through the same
Engine in up to four modes:

  * **static** — requests are grouped into fixed batches of ``n_slots``; a
    batch admits once and decodes until its SLOWEST request drains (empty
    slots idle — the classic straggler cost).
  * **continuous** — one scheduler over the whole stream; drained slots are
    refilled from the queue at every drain boundary. Dense pool: every slot
    reserves a ``max_len``-deep KV slab.
  * **paged** (``--paged``) — the paged two-tier pool inside the SAME
    layer-0 byte budget the dense pool used: admission by pages, spill to
    the layer-1 tier under pressure. The interesting number is not just
    tok/s but *concurrent slots per byte* — the capacity win the paper gets
    from stacking a second memory layer.
  * **paged+share** (``--prefix-share``) — the stream becomes the
    shared-system-prompt workload (one common ``--system-len`` prefix per
    request) and the paged pool runs twice in the SAME layer-0 byte
    budget, sharing off vs on. Reported head-to-head: tok/s, TTFT
    percentiles, physical vs *mapped* pages (the concurrent-residency
    win), plus a bit-identical output check between the two runs.

A third head-to-head, ``--speculate``, measures self-drafting speculative
decoding (DESIGN.md §Speculative decoding): the repetitive (motif-tiled)
stream runs through the paged pool twice in the SAME layer-0 byte budget,
speculation off vs on. The decode win is reported as **tokens per decode
forward**: on the modeled memory-bound target every decode forward
streams the slot pool's entire resident KV through layer 0, so tokens per
full-pool sweep IS decode throughput — host wall-clock on the CPU test
backend is FLOP-bound (a width-(k+1) verify costs ~k× a single-token
step there) and is reported honestly alongside, not gated on.
``--require-speculate-win`` gates on >=1.5x tokens-per-forward and
bit-identical outputs vs the non-speculative run.

A separate head-to-head, ``--chunked-prefill``, measures the admission
stall chunked prefill exists to kill (DESIGN.md §Chunked prefill). Three
runs over the same short-request stream: **baseline** (no long prompt),
**unchunked** (+one ``--long-prompt-len`` prompt admitted whole — the
stall), **chunked** (+the same prompt admitted ``--chunk-prefill-tokens``
per boundary, interleaved with decode). The latency metric is the
token-weighted inter-token distribution: each token emitted at a drain
boundary contributes one sample of that boundary's wall / sync_interval.
``--require-flat-p99`` gates on chunked p99 staying within
``--flat-p99-tol`` of baseline WHILE the one-shot run degrades past it,
and the chunked outputs must be bit-identical to the one-shot outputs.
A phase-timed pass adds the prefill/insert/generate/drain breakdown.

``--mesh N`` (N > 1) runs the mesh-sharded head-to-head (DESIGN.md
§Sharded serving): the same paged stream served single-device and under
an N-way mesh with tensor-parallel weights, head-axis KV page placement
and per-shard pool budgets. The gated metric is **modeled decode
scaling**: emitted tokens per decode forward divided by the per-shard
resident-KV bytes that forward sweeps — on the modeled memory-bound
target the sweep IS the forward's cost, so the ratio is decode
throughput scaling. Host wall tok/s is reported alongside but not gated
(the CPU test backend is FLOP-bound and re-runs the full FLOPs on every
host device). ``--require-scaling`` gates on >=1.7x modeled scaling,
outputs bit-exact against the same engine's one-shot rollout, and one
host sync per drain boundary on BOTH sides — sharding must not add
sync points.

``--disaggregate`` runs the prefill/decode role-split head-to-head
(DESIGN.md §Disaggregated serving): mixed traffic — a short-request decode
stream plus several long prompts admitted in chunks — served twice in the
SAME layer-0 byte budget, combined engine vs disaggregated roles. The
gated metric is **decode-role tokens/s**: decode tokens over the wall the
decode clock actually spans. Combined, that clock is the full boundary
(prefill chunks ride the decode engine's dispatch stream, so every decode
consumer observes the prompt work); disaggregated, it is the decode
role's own dispatch + drain (both runs phase-timed so each phase blocks on
its device work — the role split is measured, not simulated). The same
split drives the token-weighted inter-token p99: ``--require-disagg-win``
gates on decode tok/s >= ``--disagg-win-min`` x combined AT a p99 no worse
than ``1 + --flat-p99-tol`` x combined, bit-identical outputs, and at most
one host sync per role per boundary.

``--kv-quant`` runs the tier-codec head-to-head (DESIGN.md §Tiered KV
compression & host parking): the same stream served twice in the SAME
layer-0 byte budget, fp16 pages vs the quantized codec (int8 or fp8 —
either spills at int8). A smaller page prices more pages into the budget,
so the gated metric is **concurrent resident sessions per layer-0 byte**:
``--require-residency-win`` gates on the quantized run holding >=1.8x the
fp16 run's resident high water at the same bytes, with every request
draining and the greedy FIRST token agreeing with the fp16 run on >=75%
of the stream (full-sequence identity is not gated — lossy codecs may
legitimately flip a late argmax). ``--park-idle N`` additionally runs the
layer-2 host tier inside the quantized serve: after N decode steps every
decoding resident parks to a host blob, resumes, and the stream completes
— park counters land in the record.

Every record carries pool bytes and pages-in-use next to throughput, so the
dense-vs-paged comparison shows capacity, not just speed. Emits
``benchmarks/artifacts/serve_bench.json``; ``--emit-bench`` additionally
writes the flat cross-PR metric file ``BENCH_10.json`` at the repo root
(diffed by ``tools/diff_bench.py``).

    PYTHONPATH=src python -m benchmarks.serve_bench [--target NAME] [--paged]
        [--page-tokens N] [--layer0-bytes B] [--layer1-bytes B]
        [--require-spill] [--prefix-share] [--system-len N]
        [--require-share-win] [--chunked-prefill] [--long-prompt-len N]
        [--chunk-prefill-tokens N] [--sync-interval N] [--require-flat-p99]
        [--flat-p99-tol F] [--speculate] [--speculate-tokens K]
        [--require-speculate-win] [--mesh SPEC] [--mesh-axes NAMES]
        [--require-scaling] [--disaggregate] [--require-disagg-win]
        [--disagg-win-min F] [--kv-quant CODEC] [--park-idle N]
        [--require-residency-win] [--emit-bench] [...]
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional

from benchmarks.common import add_target_arg, fmt_table, save_artifact, \
    target_scope

BENCH_ID = 10


def _emit_bench_json(meta: Dict, metrics: Dict) -> str:
    """Write the flat cross-PR metric file ``BENCH_<id>.json`` at the repo
    root. Values are plain numbers only, keyed ``<run>.<metric>``, so
    ``tools/diff_bench.py`` can diff any two PRs' files key by key."""
    import json
    import pathlib
    path = pathlib.Path(__file__).resolve().parents[1] / \
        f"BENCH_{BENCH_ID}.json"
    clean = {k: v for k, v in metrics.items()
             if isinstance(v, (int, float)) and not isinstance(v, bool)}
    payload = {"bench_id": BENCH_ID, "schema": 1, "meta": meta,
               "metrics": clean}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return str(path)


def _run_mode(engine, stream: List[Dict], n_slots: int, mode: str,
              geom=None) -> Dict:
    from repro.serve.scheduler import Scheduler, percentile

    paged = mode in ("paged", "paged+share")

    def make_sched():
        return Scheduler(n_slots=n_slots, pages=geom if paged else None,
                         prefix_share=(mode == "paged+share"))

    t0 = time.monotonic()
    reports = []
    if mode == "static":                    # one batch at a time
        for i in range(0, len(stream), n_slots):
            sch = make_sched()
            for spec in stream[i:i + n_slots]:
                sch.submit(spec["prompt"], spec["max_new_tokens"])
            reports.append(engine.serve(scheduler=sch))
    else:                                   # continuous / paged [+share]
        sch = make_sched()
        for spec in stream:
            sch.submit(spec["prompt"], spec["max_new_tokens"])
        reports.append(engine.serve(scheduler=sch))
    dt = time.monotonic() - t0
    n_tokens = sum(len(r.tokens) for rep in reports for r in rep.requests)
    ttft = [t for rep in reports for t in rep.stats["ttft_steps"]]
    ttft_emit = [t for rep in reports
                 for t in rep.stats["ttft_emit_steps"]]
    last = reports[-1].stats
    rec = {
        "mode": mode,
        "wall_s": dt,
        "n_tokens": n_tokens,
        "tok_per_s": n_tokens / dt if dt else 0.0,
        "decode_steps": sum(rep.stats["decode_steps"] for rep in reports),
        "host_syncs": sum(rep.stats["host_syncs"] for rep in reports),
        "max_slot_reuse": max(rep.stats["max_slot_reuse"]
                              for rep in reports),
        "completed": sum(rep.stats["drained"] for rep in reports),
        "n_slots": n_slots,
        "preemptions": sum(rep.stats["preemptions"] for rep in reports),
        "spilled_pages": sum(rep.stats["spilled_pages"] for rep in reports),
        "restores": sum(rep.stats["restores"] for rep in reports),
        # admission wait in decode-step clock units (scheduler TTFT).
        # Meaningless for static mode: each per-batch serve() restarts the
        # step clock, so cross-batch queueing is invisible — reported as
        # None and rendered "-" in the table.
        "ttft_steps_p50": (None if mode == "static"
                           else percentile(ttft, 50)),
        "ttft_steps_p95": (None if mode == "static"
                           else percentile(ttft, 95)),
        # first-token EMISSION boundary in step-clock units — the real
        # TTFT (admission-wait alone reads 0 whenever the stream admits
        # at the first boundary, which is what BENCH_7 reported)
        "ttft_emit_p50": (None if mode == "static"
                          else percentile(ttft_emit, 50)),
        "ttft_emit_p95": (None if mode == "static"
                          else percentile(ttft_emit, 95)),
        # rid -> tokens, for cross-mode bit-identity checks (single-report
        # modes only: static restarts rids per batch)
        "outputs": ({r.rid: list(r.tokens) for r in reports[0].requests}
                    if len(reports) == 1 else {}),
    }
    if paged:
        rec.update({
            "pool_bytes": last["pool_bytes"],
            "spill_bytes": last["spill_bytes"],
            "page_tokens": last["page_tokens"],
            "n_pages": last["n_pages"],
            "pages_high_water": max(rep.stats["pages_high_water"]
                                    for rep in reports),
            "spill_high_water": max(rep.stats["spill_high_water"]
                                    for rep in reports),
            "mapped_high_water": max(rep.stats["mapped_high_water"]
                                     for rep in reports),
        })
    if mode == "paged+share":
        rec.update({k: last[k] for k in (
            "prefix_hits", "prefix_misses", "shared_prefix_tokens",
            "cow_copies")})
        rec["residency_ratio"] = (rec["mapped_high_water"]
                                  / max(rec["pages_high_water"], 1))
    return rec


def run(target_name=None, arch: str = "qwen2.5-3b", n_requests: int = 32,
        prompt_len: int = 16, gen_len: int = 12, n_slots: int = None,
        seed: int = 0, paged: bool = False, page_tokens: int = 8,
        layer0_bytes: Optional[int] = None,
        layer1_bytes: Optional[int] = None, max_slots: int = 32,
        require_spill: bool = False, prefix_share: bool = False,
        system_len: Optional[int] = None,
        require_share_win: bool = False,
        sync_interval: Optional[int] = None,
        emit_bench: bool = False) -> str:
    import jax
    from repro.configs import get_reduced
    from repro.core.target import get_target
    from repro.models import build_model
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import (derive_n_slots, derive_page_geometry,
                                       kv_bytes_per_token,
                                       shared_prefix_stream, synthetic_stream)

    paged = paged or prefix_share
    with target_scope(target_name):
        target = get_target()
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if prefix_share:
            # shared-system-prompt workload: one common prefix (3 full
            # pages by default) + unique tails up to one page
            system_len = system_len or 3 * page_tokens
            tail_len = page_tokens
            prompt_len = system_len + tail_len
            stream = shared_prefix_stream(n_requests, system_len, tail_len,
                                          gen_len, cfg.vocab_size, seed)
        else:
            stream = synthetic_stream(n_requests, prompt_len, gen_len,
                                      cfg.vocab_size, seed)
        max_len = prompt_len + gen_len
        n_slots = n_slots or derive_n_slots(cfg, max_len, max_slots=8)
        engine = Engine(model, params,
                        EngineConfig(max_len=max_len,
                                     sync_interval=sync_interval or 4))
        # the dense pool's layer-0 footprint is the shared byte budget:
        # the paged pool must beat it on concurrency INSIDE the same bytes
        dense_bytes = n_slots * kv_bytes_per_token(cfg) * max_len
        modes = [("static", n_slots, None), ("continuous", n_slots, None)]
        geom = None
        if paged:
            geom = derive_page_geometry(
                cfg, max_len, page_tokens=page_tokens, max_slots=max_slots,
                layer0_bytes=(layer0_bytes if layer0_bytes is not None
                              else dense_bytes),
                layer1_bytes=layer1_bytes)
            paged_slots = derive_n_slots(cfg, max_len, pages=geom,
                                         max_slots=max_slots)
            modes.append(("paged", paged_slots, geom))
            if prefix_share:
                # sharing on vs off, SAME geometry and layer-0 bytes
                modes.append(("paged+share", paged_slots, geom))
        # warmup: compile prefill (per distinct prompt length) + decode chunk
        for mode, slots, g in modes[1:]:
            _run_mode(engine, stream, slots, mode, g)
        recs = [_run_mode(engine, stream, slots, mode, g)
                for mode, slots, g in modes]

    by_mode = {r["mode"]: r for r in recs}
    outputs = {r["mode"]: r.pop("outputs") for r in recs}   # not in artifact
    stat, cont = by_mode["static"], by_mode["continuous"]
    for r in recs:
        r["pool_bytes"] = r.get("pool_bytes", dense_bytes)
    speedup = (cont["tok_per_s"] / stat["tok_per_s"]
               if stat["tok_per_s"] else 0.0)
    artifact = {
        "arch": cfg.name, "target": target.name, "n_requests": n_requests,
        "prompt_len": prompt_len, "gen_len": gen_len, "n_slots": n_slots,
        "dense_pool_bytes": dense_bytes,
        "static": stat, "continuous": cont, "speedup_tok_per_s": speedup,
    }
    lines = []
    if paged:
        pg = by_mode["paged"]
        slots_ratio = pg["n_slots"] / max(cont["n_slots"], 1)
        artifact.update({
            "paged": pg,
            "slots_ratio_paged_vs_dense": slots_ratio,
            "layer0_bytes": pg["pool_bytes"],
            "layer1_bytes": pg["spill_bytes"],
        })
        lines.append(
            f"paged vs dense concurrency: {pg['n_slots']} vs "
            f"{cont['n_slots']} slots in {pg['pool_bytes']} layer-0 bytes "
            f"({slots_ratio:.2f}x), spill tier: {pg['preemptions']} "
            f"preemptions / {pg['spilled_pages']} pages")
        if require_spill and pg["preemptions"] < 1:
            raise SystemExit(
                "serve_bench --require-spill: the layer-1 spill tier was "
                "never exercised — shrink --layer0-bytes")
    if prefix_share:
        pg, sh = by_mode["paged"], by_mode["paged+share"]
        if outputs["paged"] != outputs["paged+share"]:
            raise SystemExit(
                "serve_bench --prefix-share: sharing-on outputs differ "
                "from sharing-off — prefix sharing must be bit-exact")
        artifact.update({
            "prefix_share": sh, "system_len": system_len,
            "residency_ratio": sh["residency_ratio"],
            "share_outputs_bit_identical": True,
        })
        lines.append(
            f"prefix sharing (system prompt {system_len} tok, same "
            f"{sh['pool_bytes']} layer-0 bytes): residency "
            f"{sh['mapped_high_water']} mapped vs {sh['pages_high_water']} "
            f"physical pages ({sh['residency_ratio']:.2f}x), ttft p50/p95 "
            f"{sh['ttft_steps_p50']:.0f}/{sh['ttft_steps_p95']:.0f} vs "
            f"{pg['ttft_steps_p50']:.0f}/{pg['ttft_steps_p95']:.0f} steps "
            f"sharing-off, {sh['prefix_hits']} hits "
            f"({sh['shared_prefix_tokens']} prompt tokens from cache, "
            f"{sh['cow_copies']} COW), outputs bit-identical")
        if require_share_win and (
                sh["residency_ratio"] < 1.5
                or sh["ttft_steps_p95"] > pg["ttft_steps_p95"]):
            raise SystemExit(
                "serve_bench --require-share-win: expected >=1.5x mapped/"
                "physical residency and no-worse TTFT p95 with sharing on; "
                f"got {sh['residency_ratio']:.2f}x, p95 "
                f"{sh['ttft_steps_p95']:.0f} vs {pg['ttft_steps_p95']:.0f}")
    save_artifact("serve_bench.json", artifact)
    if emit_bench:
        metrics = {"speedup_tok_per_s": speedup}
        for r in recs:
            metrics.update({f"{r['mode']}.{k}": v for k, v in r.items()})
        path = _emit_bench_json(
            {"mode": "serve", "arch": cfg.name, "target": target.name,
             "n_requests": n_requests}, metrics)
        lines.append(f"bench metrics -> {path}")
    rows = [[r["mode"], f"{r['tok_per_s']:.1f}", r["n_tokens"], r["n_slots"],
             r["pool_bytes"], r.get("pages_high_water", "-"),
             ("-" if r["ttft_steps_p50"] is None else
              f"{r['ttft_steps_p50']:.0f}/{r['ttft_steps_p95']:.0f}"),
             r["preemptions"], r["max_slot_reuse"],
             f"{r['wall_s']*1e3:.0f} ms"] for r in recs]
    table = fmt_table(
        ["mode", "tok/s", "tokens", "slots", "pool bytes", "pages hw",
         "ttft p50/95", "preempt", "max reuse", "wall"],
        rows, title=f"Serve bench — {cfg.name}, {n_requests} requests "
                    f"({target.name})")
    return "\n".join([table,
                      f"continuous/static speedup: {speedup:.2f}x"] + lines)


def _stream_metrics(rep, sync_interval: int) -> Dict:
    """Flatten one ServeReport into the latency/counter record the
    chunked-prefill head-to-head compares across runs.

    The inter-token distribution is token-weighted: every token emitted at
    a drain boundary contributes one sample of that boundary's
    ``wall / sync_interval``, so a slow boundary counts once per consumer
    that observed the gap. Boundaries that emit nothing (all decode slots
    drained, only prefill chunks ran) add no samples — no stream observed
    an inter-token gap there.
    """
    from repro.serve.scheduler import percentile

    st = rep.stats
    samples: List[float] = []
    for w, t in zip(st["boundary_wall_s"], st["boundary_tokens"]):
        samples.extend([w / sync_interval] * t)
    return {
        "n_tokens": sum(len(r.tokens) for r in rep.requests),
        "intertoken_p50_ms": percentile(samples, 50) * 1e3,
        "intertoken_p95_ms": percentile(samples, 95) * 1e3,
        "intertoken_p99_ms": percentile(samples, 99) * 1e3,
        "ttft_steps_p50": percentile(st["ttft_steps"], 50),
        "ttft_steps_p95": percentile(st["ttft_steps"], 95),
        "ttft_emit_p50": percentile(st["ttft_emit_steps"], 50),
        "ttft_emit_p95": percentile(st["ttft_emit_steps"], 95),
        "e2e_steps_p50": percentile(st["e2e_steps"], 50),
        "e2e_steps_p95": percentile(st["e2e_steps"], 95),
        "boundaries": len(st["boundary_wall_s"]),
        "decode_steps": st["decode_steps"],
        "host_syncs": st["host_syncs"],
        "preemptions": st["preemptions"],
        "spilled_pages": st["spilled_pages"],
        "restores": st["restores"],
        "prefill_chunks": st["prefill_chunks"],
        "max_boundary_prefill_tokens": st["max_boundary_prefill_tokens"],
        "pages_high_water": st.get("pages_high_water", 0),
        "mapped_high_water": st.get("mapped_high_water", 0),
        "prefix_hits": st.get("prefix_hits", 0),
        "cow_copies": st.get("cow_copies", 0),
    }


def run_chunked(target_name=None, arch: str = "qwen2.5-3b",
                n_requests: int = 32, prompt_len: int = 16,
                gen_len: int = 12, n_slots: Optional[int] = None,
                seed: int = 0, page_tokens: int = 8,
                layer0_bytes: Optional[int] = None,
                layer1_bytes: Optional[int] = None, max_slots: int = 32,
                prefix_share: bool = False,
                system_len: Optional[int] = None,
                long_prompt_len: int = 4096, long_gen_len: int = 4,
                chunk_prefill_tokens: int = 0, sync_interval: int = 8,
                flat_p99_tol: float = 0.10, require_flat_p99: bool = False,
                require_spill: bool = False, repeats: int = 3,
                emit_bench: bool = False) -> str:
    """The chunked-prefill admission-stall head-to-head (see module doc)."""
    import jax
    import numpy as np
    from repro.configs import get_reduced
    from repro.core.target import get_target
    from repro.models import build_model
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import (Scheduler, derive_page_geometry,
                                       derive_prefill_chunk,
                                       kv_bytes_per_token,
                                       shared_prefix_stream, synthetic_stream)

    with target_scope(target_name):
        target = get_target()
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if prefix_share:
            system_len = system_len or 3 * page_tokens
            prompt_len = system_len + page_tokens
            shorts = shared_prefix_stream(n_requests, system_len,
                                          page_tokens, gen_len,
                                          cfg.vocab_size, seed)
        else:
            shorts = synthetic_stream(n_requests, prompt_len, gen_len,
                                      cfg.vocab_size, seed)
        rng = np.random.RandomState(seed + 1)
        long_prompt = rng.randint(2, cfg.vocab_size,
                                  size=long_prompt_len).astype(np.int32)
        chunk = chunk_prefill_tokens or derive_prefill_chunk(cfg)
        max_len = long_prompt_len + max(gen_len, long_gen_len)
        n_slots = n_slots or 8
        if layer0_bytes is None:
            # fully resident by default: the head-to-head isolates the
            # admission stall. --layer0-bytes shrinks the pool to compose
            # chunking with spill/preemption (CI runs both).
            resident = (n_slots * (prompt_len + gen_len + page_tokens)
                        + long_prompt_len + long_gen_len + page_tokens)
            layer0_bytes = kv_bytes_per_token(cfg) * resident
        geom = derive_page_geometry(cfg, max_len, page_tokens=page_tokens,
                                    max_slots=max_slots,
                                    layer0_bytes=layer0_bytes,
                                    layer1_bytes=layer1_bytes)
        engine = Engine(model, params,
                        EngineConfig(max_len=max_len,
                                     sync_interval=sync_interval))

        def serve(with_long, chunk_setting):
            sch = Scheduler(n_slots=n_slots, pages=geom,
                            prefix_share=prefix_share,
                            chunk_prefill_tokens=chunk_setting)
            stream = list(shorts)
            if with_long:
                # lands mid-stream: the pool decodes at full concurrency
                # when the long prompt admits
                stream.insert(min(n_slots, len(stream)),
                              {"prompt": long_prompt,
                               "max_new_tokens": long_gen_len})
            for spec in stream:
                sch.submit(spec["prompt"], spec["max_new_tokens"])
            t0 = time.monotonic()
            rep = engine.serve(scheduler=sch)
            return rep, time.monotonic() - t0

        runs = [("baseline", False, chunk),   # no long prompt
                ("unchunked", True, None),    # one-shot 4k admission: stall
                ("chunked", True, chunk)]     # chunked 4k admission
        for _, with_long, c in runs:          # warmup: compile everything
            serve(with_long, c)
        recs, outputs = [], {}
        for name, with_long, c in runs:
            # wall-clock p99 on a shared host is noisy: measure `repeats`
            # passes and keep the median-p99 one
            passes = []
            for _ in range(max(1, repeats)):
                rep, dt = serve(with_long, c)
                m = {"run": name, "wall_s": dt,
                     **_stream_metrics(rep, sync_interval)}
                m["tok_per_s"] = m["n_tokens"] / dt if dt else 0.0
                passes.append((m, rep))
            passes.sort(key=lambda p: p[0]["intertoken_p99_ms"])
            rec, rep = passes[len(passes) // 2]
            recs.append(rec)
            outputs[name] = {r.rid: list(r.tokens) for r in rep.requests}
        # phase breakdown runs separately: phase_timing blocks on device
        # completion per phase, which would skew the latency numbers above
        phases = {}
        engine.ecfg.phase_timing = True
        try:
            for name, with_long, c in (("unchunked", True, None),
                                       ("chunked", True, chunk)):
                rep, _ = serve(with_long, c)
                phases[name] = dict(rep.stats.get("phase_s", {}))
        finally:
            engine.ecfg.phase_timing = False

    by = {r["run"]: r for r in recs}
    if outputs["unchunked"] != outputs["chunked"]:
        raise SystemExit(
            "serve_bench --chunked-prefill: chunked outputs differ from "
            "one-shot prefill — chunked prefill must be bit-exact")
    base_p99 = by["baseline"]["intertoken_p99_ms"] or 1e-9
    ratio_chunked = by["chunked"]["intertoken_p99_ms"] / base_p99
    ratio_unchunked = by["unchunked"]["intertoken_p99_ms"] / base_p99
    artifact = {
        "arch": cfg.name, "target": target.name, "n_requests": n_requests,
        "long_prompt_len": long_prompt_len,
        "chunk_prefill_tokens": chunk, "sync_interval": sync_interval,
        "n_slots": n_slots, "layer0_bytes": layer0_bytes,
        "prefix_share": prefix_share,
        "p99_ratio_chunked": ratio_chunked,
        "p99_ratio_unchunked": ratio_unchunked,
        "flat_p99_tol": flat_p99_tol,
        "outputs_bit_identical": True,
        "phase_s": phases,
        "runs": {r["run"]: r for r in recs},
    }
    save_artifact("serve_chunked_bench.json", artifact)
    rows = [[r["run"], f"{r['tok_per_s']:.1f}", r["n_tokens"],
             f"{r['intertoken_p50_ms']:.1f}",
             f"{r['intertoken_p99_ms']:.1f}",
             f"{r['ttft_emit_p50']:.0f}/{r['ttft_emit_p95']:.0f}",
             f"{r['e2e_steps_p95']:.0f}", r["preemptions"],
             r["prefill_chunks"], f"{r['wall_s']*1e3:.0f} ms"]
            for r in recs]
    table = fmt_table(
        ["run", "tok/s", "tokens", "it p50 ms", "it p99 ms",
         "ttft emit 50/95", "e2e p95", "preempt", "chunks", "wall"],
        rows, title=f"Chunked prefill head-to-head — {cfg.name}, "
                    f"{n_requests}+1 requests, {long_prompt_len}-token "
                    f"admission, chunk={chunk} ({target.name})")
    phase_keys = ("prefill", "insert", "generate", "drain")
    phase_rows = [[name] + [f"{phases[name].get(k, 0.0)*1e3:.0f}"
                            for k in phase_keys]
                  for name in phases]
    phase_table = fmt_table(
        ["run"] + [f"{k} ms" for k in phase_keys], phase_rows,
        title="Phase breakdown (separate phase-timed pass)")
    lines = [
        table, phase_table,
        f"p99 inter-token vs baseline: chunked x{ratio_chunked:.2f}, "
        f"one-shot x{ratio_unchunked:.2f} (tol {flat_p99_tol:.0%}); "
        f"outputs bit-identical"]
    if require_spill and by["chunked"]["preemptions"] < 1:
        raise SystemExit(
            "serve_bench --require-spill: the chunked run never preempted "
            "— shrink --layer0-bytes")
    if emit_bench:
        metrics = {"p99_ratio_chunked": ratio_chunked,
                   "p99_ratio_unchunked": ratio_unchunked}
        for r in recs:
            metrics.update({f"{r['run']}.{k}": v for k, v in r.items()})
        for name, ph in phases.items():
            metrics.update({f"{name}.phase_{k}_s": v
                            for k, v in ph.items()})
        path = _emit_bench_json(
            {"mode": "chunked-prefill", "arch": cfg.name,
             "target": target.name, "n_requests": n_requests,
             "long_prompt_len": long_prompt_len,
             "chunk_prefill_tokens": chunk,
             "sync_interval": sync_interval}, metrics)
        lines.append(f"bench metrics -> {path}")
    if require_flat_p99:
        if ratio_chunked > 1 + flat_p99_tol:
            raise SystemExit(
                "serve_bench --require-flat-p99: chunked admission moved "
                f"p99 inter-token x{ratio_chunked:.2f} vs baseline "
                f"(tolerance {flat_p99_tol:.0%}) — the chunk budget is not "
                "hiding under decode; shrink --chunk-prefill-tokens or "
                "raise --sync-interval")
        if ratio_unchunked <= 1 + flat_p99_tol:
            raise SystemExit(
                "serve_bench --require-flat-p99: the one-shot admission "
                f"stall never materialized (x{ratio_unchunked:.2f}) — the "
                "head-to-head is not measuring anything; lengthen "
                "--long-prompt-len")
    return "\n".join(lines)


def run_speculate(target_name=None, arch: str = "qwen2.5-3b",
                  n_requests: int = 24, prompt_len: int = 48,
                  gen_len: int = 32, n_slots: int = None, seed: int = 0,
                  page_tokens: int = 8,
                  layer0_bytes: Optional[int] = None,
                  layer1_bytes: Optional[int] = None, max_slots: int = 32,
                  speculate_tokens: int = 0,
                  sync_interval: Optional[int] = None,
                  require_speculate_win: bool = False,
                  emit_bench: bool = False) -> str:
    """Speculative-decoding head-to-head: the repetitive stream through
    the paged pool in the SAME layer-0 byte budget, speculation off vs on.

    The gated metric is decode **tokens per forward**: each decode forward
    sweeps the pool's entire resident KV through layer 0 — the dominant
    cost on the modeled memory-bound target — so emitted tokens per sweep
    IS decode throughput there. Host wall tok/s is reported alongside but
    NOT gated: the CPU test backend is FLOP-bound, where a width-(k+1)
    verify forward genuinely costs ~k× a single-token step.
    """
    import jax
    from repro.configs import get_reduced
    from repro.core.target import get_target
    from repro.models import build_model
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import (Scheduler, derive_n_slots,
                                       derive_page_geometry,
                                       derive_speculate_tokens,
                                       kv_bytes_per_token, percentile,
                                       repetitive_stream)

    with target_scope(target_name):
        target = get_target()
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        k = speculate_tokens or derive_speculate_tokens(cfg) or 4
        stream = repetitive_stream(n_requests, prompt_len, gen_len,
                                   cfg.vocab_size, seed)
        max_len = prompt_len + gen_len
        n_slots = n_slots or derive_n_slots(cfg, max_len, max_slots=8)
        dense_bytes = n_slots * kv_bytes_per_token(cfg) * max_len
        geom = derive_page_geometry(
            cfg, max_len, page_tokens=page_tokens, max_slots=max_slots,
            layer0_bytes=(layer0_bytes if layer0_bytes is not None
                          else dense_bytes),
            layer1_bytes=layer1_bytes)
        slots = derive_n_slots(cfg, max_len, pages=geom,
                               max_slots=max_slots)
        engine = Engine(model, params,
                        EngineConfig(max_len=max_len,
                                     sync_interval=sync_interval or 4,
                                     speculate_tokens=k))

        def one(spec_k: int) -> Dict:
            engine.ecfg.speculate_tokens = spec_k
            sch = Scheduler(n_slots=slots, pages=geom)
            for spec in stream:
                sch.submit(spec["prompt"], spec["max_new_tokens"])
            t0 = time.monotonic()
            rep = engine.serve(scheduler=sch)
            dt = time.monotonic() - t0
            st = rep.stats
            n_tokens = sum(len(r.tokens) for r in rep.requests)
            rec = {
                "mode": "speculate" if spec_k else "baseline",
                "speculate_tokens": spec_k,
                "wall_s": dt,
                "n_tokens": n_tokens,
                "tok_per_s": n_tokens / dt if dt else 0.0,
                "decode_steps": st["decode_steps"],
                "host_syncs": st["host_syncs"],
                "completed": st["drained"],
                "n_slots": slots,
                "pool_bytes": st["pool_bytes"],
                "preemptions": st["preemptions"],
                # the gated metric: emitted tokens per full-pool KV sweep
                "tok_per_forward": (n_tokens / st["decode_steps"]
                                    if st["decode_steps"] else 0.0),
                "ttft_steps_p50": percentile(st["ttft_steps"], 50),
                "ttft_steps_p95": percentile(st["ttft_steps"], 95),
                # emission-boundary TTFT: non-zero even when every slot
                # admits at the first boundary (see _run_mode)
                "ttft_emit_p50": percentile(st["ttft_emit_steps"], 50),
                "ttft_emit_p95": percentile(st["ttft_emit_steps"], 95),
                "outputs": {r.rid: list(r.tokens) for r in rep.requests},
            }
            if spec_k:
                rec.update({key: st[key] for key in (
                    "spec_proposed", "spec_accepted", "spec_rejected",
                    "spec_acceptance_rate")})
            return rec

        for s in (0, k):        # warmup: compile both variants' chunks
            one(s)
        off, on = one(0), one(k)

    outputs = (off.pop("outputs"), on.pop("outputs"))
    identical = outputs[0] == outputs[1]
    ratio = (on["tok_per_forward"] / off["tok_per_forward"]
             if off["tok_per_forward"] else 0.0)
    artifact = {
        "arch": cfg.name, "target": target.name, "n_requests": n_requests,
        "prompt_len": prompt_len, "gen_len": gen_len,
        "speculate_tokens": k, "layer0_bytes": off["pool_bytes"],
        "baseline": off, "speculate": on,
        "tok_per_forward_ratio": ratio,
        "speculate_outputs_bit_identical": identical,
    }
    save_artifact("serve_speculate.json", artifact)
    lines = [
        f"speculative decoding (k={k}, {on['pool_bytes']} layer-0 bytes, "
        f"acceptance {on['spec_acceptance_rate']:.2f}: "
        f"{on['spec_accepted']}/{on['spec_proposed']} drafts): "
        f"{on['tok_per_forward']:.2f} vs {off['tok_per_forward']:.2f} "
        f"decode tokens/forward ({ratio:.2f}x), wall "
        f"{on['tok_per_s']:.1f} vs {off['tok_per_s']:.1f} tok/s, outputs "
        f"{'bit-identical' if identical else 'DIFFER'}"]
    if emit_bench:
        metrics = {"tok_per_forward_ratio": ratio,
                   "acceptance_rate": on["spec_acceptance_rate"]}
        for r in (off, on):
            metrics.update({f"{r['mode']}.{key}": v
                            for key, v in r.items()})
        path = _emit_bench_json(
            {"mode": "speculate", "arch": cfg.name, "target": target.name,
             "n_requests": n_requests, "speculate_tokens": k}, metrics)
        lines.append(f"bench metrics -> {path}")
    if not identical:
        raise SystemExit(
            "serve_bench --speculate: speculative outputs differ from the "
            "non-speculative run — greedy speculation must be bit-exact")
    if require_speculate_win and ratio < 1.5:
        raise SystemExit(
            "serve_bench --require-speculate-win: expected >=1.5x decode "
            f"tokens-per-forward with speculation on; got {ratio:.2f}x "
            f"(acceptance {on['spec_acceptance_rate']:.2f}) — lengthen the "
            "stream's repetition or raise --speculate-tokens")
    rows = [[r["mode"], f"{r['tok_per_forward']:.2f}",
             f"{r['tok_per_s']:.1f}", r["n_tokens"], r["decode_steps"],
             r["host_syncs"],
             f"{r['ttft_steps_p50']:.0f}/{r['ttft_steps_p95']:.0f}",
             f"{r.get('spec_acceptance_rate', 0.0):.2f}",
             f"{r['wall_s']*1e3:.0f} ms"] for r in (off, on)]
    table = fmt_table(
        ["mode", "tok/fwd", "tok/s", "tokens", "forwards", "syncs",
         "ttft p50/95", "accept", "wall"],
        rows, title=f"Speculative decode bench — {cfg.name}, "
                    f"{n_requests} requests, k={k} ({target.name})")
    return "\n".join([table] + lines)


def run_disagg(target_name=None, arch: str = "qwen2.5-3b",
               n_requests: int = 24, prompt_len: int = 16,
               gen_len: int = 16, n_slots: Optional[int] = None,
               seed: int = 0, page_tokens: int = 8,
               layer0_bytes: Optional[int] = None,
               layer1_bytes: Optional[int] = None, max_slots: int = 32,
               long_prompt_len: int = 512, n_long: int = 3,
               long_gen_len: int = 8, chunk_prefill_tokens: int = 0,
               sync_interval: int = 8, disagg_win_min: float = 1.15,
               flat_p99_tol: float = 0.10,
               require_disagg_win: bool = False,
               emit_bench: bool = False) -> str:
    """Disaggregated-roles head-to-head (see module doc): mixed
    long-prompt + decode traffic through the SAME paged pool geometry,
    combined engine vs prefill/decode role split.

    Both runs are phase-timed (every phase blocks on its device work), so
    the decode clock is measured, not simulated: combined, each boundary's
    prefill chunks execute inside the decode engine's dispatch stream and
    the full boundary wall is the inter-token gap every decode consumer
    observes; disaggregated, the prompt chunks run on the prefill role and
    the decode consumer's clock spans only the decode dispatch + the
    decode role's drain fetch (``boundary_decode_wall_s``).
    """
    import jax
    import numpy as np
    from repro.configs import get_reduced
    from repro.core.target import get_target
    from repro.models import build_model
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import (Scheduler, derive_page_geometry,
                                       derive_prefill_chunk,
                                       kv_bytes_per_token, percentile,
                                       synthetic_stream)

    with target_scope(target_name):
        target = get_target()
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shorts = synthetic_stream(n_requests, prompt_len, gen_len,
                                  cfg.vocab_size, seed)
        rng = np.random.RandomState(seed + 1)
        longs = [{"prompt": rng.randint(2, cfg.vocab_size,
                                        size=long_prompt_len
                                        ).astype(np.int32),
                  "max_new_tokens": long_gen_len}
                 for _ in range(max(1, n_long))]
        # interleave: every batch of short requests is followed by a long
        # prompt, so prompt chunks keep landing while the pool decodes
        stream = []
        per = max(1, len(shorts) // (len(longs) + 1))
        li = 0
        for i, spec in enumerate(shorts):
            stream.append(spec)
            if (i + 1) % per == 0 and li < len(longs):
                stream.append(longs[li])
                li += 1
        stream.extend(longs[li:])
        chunk = chunk_prefill_tokens or derive_prefill_chunk(cfg)
        max_len = long_prompt_len + max(gen_len, long_gen_len)
        n_slots = n_slots or 8
        if layer0_bytes is None:
            resident = (n_slots * (prompt_len + gen_len + page_tokens)
                        + n_long * (long_prompt_len + long_gen_len
                                    + page_tokens))
            layer0_bytes = kv_bytes_per_token(cfg) * resident
        geom = derive_page_geometry(cfg, max_len, page_tokens=page_tokens,
                                    max_slots=max_slots,
                                    layer0_bytes=layer0_bytes,
                                    layer1_bytes=layer1_bytes)

        def one(disagg: bool) -> Dict:
            # a fresh engine per mode keeps phase/role accounting separate;
            # phase_timing on BOTH sides so each phase blocks identically
            engine = Engine(model, params,
                            EngineConfig(max_len=max_len,
                                         sync_interval=sync_interval,
                                         phase_timing=True,
                                         disaggregate=disagg))

            def serve_once():
                sch = Scheduler(n_slots=n_slots, pages=geom,
                                chunk_prefill_tokens=chunk,
                                disaggregate=disagg)
                for spec in stream:
                    sch.submit(spec["prompt"], spec["max_new_tokens"])
                t0 = time.monotonic()
                rep = engine.serve(scheduler=sch)
                return rep, time.monotonic() - t0

            serve_once()                      # warmup: compile
            rep, dt = serve_once()
            st = rep.stats
            # the decode consumer's clock: full boundary combined, the
            # decode role's own span disaggregated
            walls = (st["boundary_decode_wall_s"] if disagg
                     else st["boundary_wall_s"])
            decode_wall = sum(walls)
            samples: List[float] = []
            for w, t in zip(walls, st["boundary_tokens"]):
                samples.extend([w / sync_interval] * t)
            n_tokens = sum(len(r.tokens) for r in rep.requests)
            decode_tokens = st.get(
                "decode_tokens",
                n_tokens - sum(1 for r in rep.requests if r.tokens))
            rec = {
                "mode": "disaggregated" if disagg else "combined",
                "wall_s": dt,
                "n_tokens": n_tokens,
                "decode_tokens": decode_tokens,
                "decode_wall_s": decode_wall,
                # the gated metric: decode tokens over the decode clock
                "decode_tok_per_s": (decode_tokens / decode_wall
                                     if decode_wall else 0.0),
                "intertoken_p50_ms": percentile(samples, 50) * 1e3,
                "intertoken_p99_ms": percentile(samples, 99) * 1e3,
                "tok_per_s": n_tokens / dt if dt else 0.0,
                "boundaries": len(st["boundary_wall_s"]),
                "decode_steps": st["decode_steps"],
                "host_syncs": st["host_syncs"],
                "completed": st["drained"],
                "n_slots": n_slots,
                "pool_bytes": st["pool_bytes"],
                "pages_high_water": st["pages_high_water"],
                "preemptions": st["preemptions"],
                "prefill_chunks": st["prefill_chunks"],
                "handovers": st["handovers"],
                "handover_pages": st["handover_pages"],
                "phase_s": dict(st.get("phase_s", {})),
                "outputs": {r.rid: list(r.tokens) for r in rep.requests},
            }
            if disagg:
                rec["host_syncs_by_role"] = dict(st["host_syncs_by_role"])
                rec["role_s"] = dict(st.get("role_s", {}))
                for role, n in rec["host_syncs_by_role"].items():
                    if n > rec["boundaries"]:
                        raise SystemExit(
                            f"serve_bench --disaggregate: {role} role made "
                            f"{n} host syncs over {rec['boundaries']} "
                            "boundaries — at most one per role per boundary")
            return rec

        comb = one(False)
        dis = one(True)

    outputs = (comb.pop("outputs"), dis.pop("outputs"))
    identical = outputs[0] == outputs[1]
    if not identical:
        raise SystemExit(
            "serve_bench --disaggregate: disaggregated outputs differ from "
            "the combined engine — the role split must be bit-exact")
    ratio = (dis["decode_tok_per_s"] / comb["decode_tok_per_s"]
             if comb["decode_tok_per_s"] else 0.0)
    p99_ratio = (dis["intertoken_p99_ms"]
                 / max(comb["intertoken_p99_ms"], 1e-9))
    artifact = {
        "arch": cfg.name, "target": target.name,
        "n_requests": len(stream), "long_prompt_len": long_prompt_len,
        "n_long": n_long, "chunk_prefill_tokens": chunk,
        "sync_interval": sync_interval, "layer0_bytes": layer0_bytes,
        "decode_tok_per_s_ratio": ratio, "p99_ratio": p99_ratio,
        "disagg_win_min": disagg_win_min, "flat_p99_tol": flat_p99_tol,
        "outputs_bit_identical": True,
        "combined": comb, "disaggregated": dis,
    }
    save_artifact("serve_disagg_bench.json", artifact)
    lines = [
        f"disaggregated roles ({dis['handovers']} handovers, "
        f"{dis['handover_pages']} pages moved zero-copy, same "
        f"{dis['pool_bytes']} layer-0 bytes): decode "
        f"{dis['decode_tok_per_s']:.1f} vs {comb['decode_tok_per_s']:.1f} "
        f"tok/s (x{ratio:.2f}), inter-token p99 "
        f"{dis['intertoken_p99_ms']:.2f} vs "
        f"{comb['intertoken_p99_ms']:.2f} ms (x{p99_ratio:.2f}, tol "
        f"{flat_p99_tol:.0%}), role syncs "
        f"{dis['host_syncs_by_role']}, outputs bit-identical"]
    if emit_bench:
        metrics = {"decode_tok_per_s_ratio": ratio,
                   "p99_ratio": p99_ratio}
        for r in (comb, dis):
            metrics.update({f"{r['mode']}.{k}": v for k, v in r.items()})
            metrics.update({f"{r['mode']}.phase_{k}_s": v
                            for k, v in r["phase_s"].items()})
        path = _emit_bench_json(
            {"mode": "disaggregate", "arch": cfg.name,
             "target": target.name, "n_requests": len(stream),
             "long_prompt_len": long_prompt_len,
             "chunk_prefill_tokens": chunk,
             "sync_interval": sync_interval}, metrics)
        lines.append(f"bench metrics -> {path}")
    if require_disagg_win:
        if ratio < disagg_win_min:
            raise SystemExit(
                "serve_bench --require-disagg-win: expected >="
                f"{disagg_win_min:.2f}x decode tok/s from the role split; "
                f"got x{ratio:.2f} — the stream's prompt work is too thin "
                "to matter (lengthen --long-prompt-len or add --n-long)")
        if p99_ratio > 1 + flat_p99_tol:
            raise SystemExit(
                "serve_bench --require-disagg-win: disaggregated p99 "
                f"inter-token moved x{p99_ratio:.2f} vs combined "
                f"(tolerance {flat_p99_tol:.0%}) — the decode role is not "
                "isolated from prompt work")
    phase_keys = ("prefill", "insert", "generate", "drain", "handover")
    rows = [[r["mode"], f"{r['decode_tok_per_s']:.1f}",
             f"{r['intertoken_p50_ms']:.2f}/{r['intertoken_p99_ms']:.2f}",
             r["n_tokens"], r["decode_tokens"], r["handovers"],
             r["prefill_chunks"], r["preemptions"],
             f"{r['host_syncs']}/{r['boundaries']}",
             f"{r['wall_s']*1e3:.0f} ms"] for r in (comb, dis)]
    table = fmt_table(
        ["mode", "dec tok/s", "it p50/p99 ms", "tokens", "dec toks",
         "handover", "chunks", "preempt", "syncs/bnd", "wall"],
        rows, title=f"Disaggregated serve bench — {cfg.name}, "
                    f"{len(stream)} requests ({n_long} x "
                    f"{long_prompt_len}-token prompts), chunk={chunk} "
                    f"({target.name})")
    phase_rows = [[r["mode"]] + [f"{r['phase_s'].get(k, 0.0)*1e3:.0f}"
                                 for k in phase_keys]
                  for r in (comb, dis)]
    phase_table = fmt_table(
        ["mode"] + [f"{k} ms" for k in phase_keys], phase_rows,
        title="Phase breakdown (both runs phase-timed)")
    return "\n".join([table, phase_table] + lines)


def run_mesh(target_name=None, arch: str = "qwen2.5-3b",
             n_requests: int = 32, prompt_len: int = 16,
             gen_len: int = 12, seed: int = 0, page_tokens: int = 8,
             layer0_bytes: Optional[int] = None,
             layer1_bytes: Optional[int] = None, max_slots: int = 32,
             mesh_spec: str = "2", mesh_axes: str = "data,model",
             sync_interval: Optional[int] = None,
             require_scaling: bool = False,
             emit_bench: bool = False) -> str:
    """Mesh-sharded serving head-to-head: the same paged stream served
    single-device and under the ``--mesh`` mesh, same per-shard layer-0
    byte budget (the mesh exposes ``kv_shards`` x the aggregate pool).

    The gated metric is **modeled decode scaling**: tokens per decode
    forward over the per-shard resident-KV bytes that forward sweeps.
    Head-axis page placement keeps per-shard sweep bytes flat while the
    scaled budget admits ``kv_shards`` x the slots, so tokens per sweep
    — decode throughput on the modeled memory-bound target — scales with
    the mesh. Host wall tok/s is reported but NOT gated: every forced
    host-platform device re-runs the full FLOPs, so wall time cannot
    show the memory-side win. Sync discipline is asserted, not gated:
    one host sync per drain boundary on both sides.

    Bit-exactness is asserted per mesh size, against the SAME engine's
    one-shot rollout: tensor-parallel row-sharded matmuls reassociate
    the contraction sum across shards (the all-reduce adds partials the
    single device accumulated inside one dot), so a near-tie greedy
    argmax may legitimately flip ACROSS mesh sizes — but within one mesh
    size, continuous batching, paging and head-axis placement must not
    move a single bit.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_reduced
    from repro.core.target import get_target
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_cli_mesh
    from repro.models import build_model
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import (Scheduler, derive_n_slots,
                                       derive_page_geometry,
                                       kv_bytes_per_token, kv_shards,
                                       percentile, synthetic_stream)

    with target_scope(target_name):
        target = get_target()
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        stream = synthetic_stream(n_requests, prompt_len, gen_len,
                                  cfg.vocab_size, seed)
        max_len = prompt_len + gen_len
        base_slots = derive_n_slots(cfg, max_len, max_slots=8)
        l0 = (layer0_bytes if layer0_bytes is not None
              else base_slots * kv_bytes_per_token(cfg) * max_len)
        mesh = make_cli_mesh(mesh_spec, mesh_axes)
        model_shards = shd.axis_size(mesh, shd.MODEL_AXIS)
        data_shards = shd.axis_size(mesh, shd.DATA_AXIS)
        shards = kv_shards(cfg, model_shards) * max(1, data_shards)

        def one(meshed: bool) -> Dict:
            ms = model_shards if meshed else 1
            ds = data_shards if meshed else 1
            ks = shards if meshed else 1
            geom = derive_page_geometry(
                cfg, max_len, page_tokens=page_tokens,
                max_slots=max_slots, layer0_bytes=l0,
                layer1_bytes=layer1_bytes, model_shards=ms)
            slots = derive_n_slots(cfg, max_len, pages=geom,
                                   max_slots=max_slots, model_shards=ms,
                                   data_shards=ds)
            engine = Engine(model, params,
                            EngineConfig(max_len=max_len,
                                         sync_interval=sync_interval or 4,
                                         mesh=mesh if meshed else None))
            # this engine's own ground truth: one-shot greedy rollouts
            refs = []
            for spec in stream:
                toks, _ = engine.generate(
                    {"tokens": jnp.asarray(spec["prompt"])[None]},
                    n_steps=spec["max_new_tokens"])
                refs.append([int(t) for t in np.asarray(toks)[0]])

            def serve_once():
                sch = Scheduler(n_slots=slots, pages=geom)
                rids = [sch.submit(s["prompt"], s["max_new_tokens"]).rid
                        for s in stream]
                t0 = time.monotonic()
                rep = engine.serve(scheduler=sch)
                return rids, rep, time.monotonic() - t0

            serve_once()                      # warmup: compile
            rids, rep, dt = serve_once()
            for rid, ref in zip(rids, refs):
                got = rep.outputs[rid]
                if not got or got != ref[:len(got)]:
                    raise SystemExit(
                        f"serve_bench --mesh: {'mesh' if meshed else 'base'}"
                        " continuous outputs are not a prefix of the same "
                        "engine's one-shot rollout — sharded serving must "
                        "be bit-exact against its own reference")
            st = rep.stats
            n_tokens = sum(len(r.tokens) for r in rep.requests)
            page_bytes = st["pool_bytes"] // max(st["n_pages"], 1)
            return {
                "mode": f"mesh={ms * ds}" if meshed else "mesh=1",
                "wall_s": dt,
                "n_tokens": n_tokens,
                "tok_per_s": n_tokens / dt if dt else 0.0,
                "decode_steps": st["decode_steps"],
                "host_syncs": st["host_syncs"],
                "boundaries": len(st["boundary_wall_s"]),
                "completed": st["drained"],
                "n_slots": slots,
                "kv_shards": ks,
                "pool_bytes": st["pool_bytes"],
                "per_shard_pool_bytes": st["pool_bytes"] // ks,
                "n_pages": st["n_pages"],
                "pages_high_water": st["pages_high_water"],
                "per_shard_pages_high_water":
                    -(-st["pages_high_water"] // ks),
                # per-shard resident-KV bytes one decode forward sweeps:
                # the forward's modeled cost on the memory-bound target
                "per_shard_sweep_bytes":
                    st["pages_high_water"] * page_bytes // ks,
                "tok_per_forward": (n_tokens / st["decode_steps"]
                                    if st["decode_steps"] else 0.0),
                "ttft_emit_p50": percentile(st["ttft_emit_steps"], 50),
                "ttft_emit_p95": percentile(st["ttft_emit_steps"], 95),
            }

        base = one(False)
        on_mesh = one(True)

    for rec in (base, on_mesh):
        if rec["host_syncs"] != rec["boundaries"]:
            raise SystemExit(
                f"serve_bench --mesh: {rec['mode']} made "
                f"{rec['host_syncs']} host syncs over {rec['boundaries']} "
                "drain boundaries — sharding must not add sync points")

    def modeled(rec):
        return rec["tok_per_forward"] / max(rec["per_shard_sweep_bytes"], 1)

    scaling = modeled(on_mesh) / modeled(base) if modeled(base) else 0.0
    wall_scaling = (on_mesh["tok_per_s"] / base["tok_per_s"]
                    if base["tok_per_s"] else 0.0)
    artifact = {
        "arch": cfg.name, "target": target.name, "n_requests": n_requests,
        "prompt_len": prompt_len, "gen_len": gen_len,
        "mesh": mesh_spec, "mesh_axes": mesh_axes,
        "model_shards": model_shards, "data_shards": data_shards,
        "layer0_bytes": l0,
        "scaling_modeled": scaling, "scaling_wall": wall_scaling,
        "outputs_prefix_of_one_shot": True,
        "base": base, "mesh_run": on_mesh,
    }
    save_artifact("serve_mesh_bench.json", artifact)
    lines = [
        f"mesh scaling ({data_shards}x{model_shards} data x model, "
        f"{on_mesh['kv_shards']}x kv pool, per-shard "
        f"{on_mesh['per_shard_pool_bytes']} layer-0 bytes): modeled decode "
        f"x{scaling:.2f} ({on_mesh['tok_per_forward']:.2f} vs "
        f"{base['tok_per_forward']:.2f} tok/fwd at flat per-shard sweep), "
        f"wall x{wall_scaling:.2f} (not gated: host devices re-run full "
        f"FLOPs), syncs/boundary {on_mesh['host_syncs']}/"
        f"{on_mesh['boundaries']} vs {base['host_syncs']}/"
        f"{base['boundaries']}, outputs one-shot-exact"]
    if emit_bench:
        metrics = {"scaling_modeled": scaling,
                   "scaling_wall": wall_scaling}
        for key, rec in (("base", base), ("mesh", on_mesh)):
            metrics.update({f"{key}.{k}": v for k, v in rec.items()})
        path = _emit_bench_json(
            {"mode": "mesh", "arch": cfg.name, "target": target.name,
             "n_requests": n_requests, "mesh": mesh_spec,
             "mesh_axes": mesh_axes}, metrics)
        lines.append(f"bench metrics -> {path}")
    if require_scaling and scaling < 1.7:
        raise SystemExit(
            "serve_bench --require-scaling: expected >=1.7x modeled decode "
            f"scaling at mesh {mesh_spec}; got x{scaling:.2f} — the pool "
            "budget did not scale (check kv_shards: MLA-latent and SSM "
            "caches replicate) or slots were capped by --max-slots")
    rows = [[r["mode"], f"{r['tok_per_forward']:.2f}",
             f"{r['tok_per_s']:.1f}", r["n_tokens"], r["n_slots"],
             r["kv_shards"], r["per_shard_pool_bytes"],
             r["pages_high_water"], r["per_shard_pages_high_water"],
             f"{r['host_syncs']}/{r['boundaries']}",
             f"{r['ttft_emit_p50']:.0f}/{r['ttft_emit_p95']:.0f}",
             f"{r['wall_s']*1e3:.0f} ms"] for r in (base, on_mesh)]
    table = fmt_table(
        ["mode", "tok/fwd", "tok/s", "tokens", "slots", "kv shards",
         "shard bytes", "pages hw", "shard hw", "syncs/bnd",
         "ttft emit 50/95", "wall"],
        rows, title=f"Mesh-sharded serve bench — {cfg.name}, "
                    f"{n_requests} requests, mesh {mesh_spec} "
                    f"({target.name})")
    return "\n".join([table] + lines)


def run_quant(target_name=None, arch: str = "qwen2.5-3b",
              n_requests: int = 32, prompt_len: int = 16,
              gen_len: int = 12, seed: int = 0, *, page_tokens: int = 8,
              layer0_bytes: Optional[int] = None,
              layer1_bytes: Optional[int] = None, max_slots: int = 32,
              kv_quant: str = "int8", park_idle: int = 0,
              sync_interval: Optional[int] = None,
              residency_win_min: float = 1.8,
              require_residency_win: bool = False,
              emit_bench: bool = False) -> str:
    """Tier-codec head-to-head: fp16 vs quantized pages, SAME layer-0
    bytes. The quantized page is smaller, so the same budget holds more
    pages and the pool keeps more sessions concurrently resident — the
    capacity win, gated as residents-per-byte. Greedy first-token
    agreement against the fp16 run bounds the quantization cost."""
    import jax
    from repro.configs import get_reduced
    from repro.core.target import get_target
    from repro.models import build_model
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import (DECODING, PREFILLING, Scheduler,
                                       derive_n_slots, derive_page_geometry,
                                       kv_bytes_per_token, percentile,
                                       synthetic_stream)

    with target_scope(target_name):
        target = get_target()
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        stream = synthetic_stream(n_requests, prompt_len, gen_len,
                                  cfg.vocab_size, seed)
        max_len = prompt_len + gen_len
        engine = Engine(model, params,
                        EngineConfig(max_len=max_len,
                                     sync_interval=sync_interval or 4))
        # default budget: four full-depth fp16 residents — tight enough
        # that fp16 concurrency is page-capped, so the codec's smaller
        # page shows up as MORE residents, not just slack
        l0 = (layer0_bytes if layer0_bytes is not None
              else 4 * kv_bytes_per_token(cfg) * max_len)

        def one(qq: str) -> Dict:
            geom = derive_page_geometry(
                cfg, max_len, page_tokens=page_tokens, max_slots=max_slots,
                layer0_bytes=l0, layer1_bytes=layer1_bytes, kv_quant=qq)
            slots = derive_n_slots(cfg, max_len, pages=geom,
                                   max_slots=max_slots)

            def serve_once():
                sch = Scheduler(n_slots=slots, pages=geom)
                rids = [sch.submit(s["prompt"], s["max_new_tokens"]).rid
                        for s in stream]
                rid_map = {r: r for r in rids}
                t0 = time.monotonic()
                if park_idle:
                    engine.serve(scheduler=sch, max_steps=park_idle)
                    blobs = []
                    for slot in sorted(list(sch.active)):
                        req = sch.active[slot]
                        if req.status == DECODING:
                            blobs.append(
                                (req.rid,
                                 engine.park_request(sch, req.rid)))
                        elif req.status == PREFILLING:
                            sch.requeue(slot)
                    for old_rid, blob in blobs:
                        rid_map[old_rid] = \
                            engine.resume_parked(sch, blob).rid
                rep = engine.serve(scheduler=sch)
                return rids, rid_map, rep, time.monotonic() - t0

            serve_once()                          # warmup: compile
            rids, rid_map, rep, dt = serve_once()
            st = rep.stats
            n_tokens = sum(len(r.tokens) for r in rep.requests)
            return {
                "mode": f"kv-quant={qq}",
                "codec": qq,
                "wall_s": dt,
                "n_tokens": n_tokens,
                "tok_per_s": n_tokens / dt if dt else 0.0,
                "completed": st["drained"],
                "n_slots": slots,
                "n_pages": st["n_pages"],
                "pool_bytes": st["pool_bytes"],
                "page_bytes": geom.page_bytes,
                "resident_high_water": st["resident_high_water"],
                "residents_per_mb":
                    st["resident_high_water"] * 2**20 / max(l0, 1),
                "pages_high_water": st["pages_high_water"],
                "preemptions": st["preemptions"],
                "spilled_pages": st["spilled_pages"],
                "parks": st["parks"],
                "park_resumes": st["park_resumes"],
                "ttft_emit_p50": percentile(st["ttft_emit_steps"], 50),
                "ttft_emit_p95": percentile(st["ttft_emit_steps"], 95),
                "outputs": [rep.outputs[rid_map[r]] for r in rids],
            }

        base = one("fp16")
        quant = one(kv_quant)

    for rec in (base, quant):
        if rec["completed"] != n_requests:
            raise SystemExit(
                f"serve_bench --kv-quant: {rec['mode']} drained "
                f"{rec['completed']}/{n_requests} requests")
    outs_base = base.pop("outputs")
    outs_quant = quant.pop("outputs")
    if any(not o for o in outs_base) or any(not o for o in outs_quant):
        raise SystemExit(
            "serve_bench --kv-quant: a drained request emitted no tokens")
    agreement = sum(a[0] == b[0]
                    for a, b in zip(outs_base, outs_quant)) / n_requests
    ratio = (quant["resident_high_water"]
             / max(base["resident_high_water"], 1))
    pages_ratio = (quant["n_pages"] - 1) / max(base["n_pages"] - 1, 1)
    artifact = {
        "arch": cfg.name, "target": target.name, "n_requests": n_requests,
        "prompt_len": prompt_len, "gen_len": gen_len,
        "kv_quant": kv_quant, "layer0_bytes": l0, "park_idle": park_idle,
        "residency_ratio": ratio, "pages_ratio": pages_ratio,
        "first_token_agreement": agreement,
        "base": base, "quant": quant,
    }
    save_artifact("serve_quant_bench.json", artifact)
    lines = [
        f"tier codecs ({kv_quant} vs fp16, same {l0} layer-0 bytes): "
        f"residency {quant['resident_high_water']} vs "
        f"{base['resident_high_water']} concurrent residents "
        f"({ratio:.2f}x), {quant['n_pages'] - 1} vs {base['n_pages'] - 1} "
        f"data pages ({pages_ratio:.2f}x), greedy first-token agreement "
        f"{agreement:.2f}"]
    if park_idle:
        lines.append(
            f"host parking: {quant['parks']} parked at step {park_idle}, "
            f"{quant['park_resumes']} resumed, stream completed")
    if emit_bench:
        metrics = {"residency_ratio": ratio, "pages_ratio": pages_ratio,
                   "first_token_agreement": agreement}
        for key, rec in (("base", base), ("quant", quant)):
            metrics.update({f"{key}.{k}": v for k, v in rec.items()})
        path = _emit_bench_json(
            {"mode": "kv-quant", "arch": cfg.name, "target": target.name,
             "n_requests": n_requests, "kv_quant": kv_quant,
             "layer0_bytes": l0, "park_idle": park_idle}, metrics)
        lines.append(f"bench metrics -> {path}")
    if require_residency_win and (ratio < residency_win_min
                                  or agreement < 0.75):
        raise SystemExit(
            "serve_bench --require-residency-win: expected >="
            f"{residency_win_min}x concurrent residents at >=0.75 "
            f"first-token agreement; got x{ratio:.2f} at {agreement:.2f} "
            "— either the budget is slack (fp16 was not page-capped) or "
            "the codec drifted")
    rows = [[r["mode"], r["n_slots"], r["resident_high_water"],
             r["n_pages"] - 1, r["page_bytes"], r["pool_bytes"],
             r["preemptions"], r["parks"],
             f"{r['ttft_emit_p50']:.0f}/{r['ttft_emit_p95']:.0f}",
             f"{r['tok_per_s']:.1f}"] for r in (base, quant)]
    table = fmt_table(
        ["mode", "slots", "res hw", "pages", "page B", "pool B",
         "preempt", "parks", "ttft 50/95", "tok/s"],
        rows, title=f"Tier-codec serve bench — {cfg.name}, "
                    f"{n_requests} requests, {l0} layer-0 bytes "
                    f"({target.name})")
    return "\n".join([table] + lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="also run the paged two-tier pool inside the dense "
                         "pool's layer-0 byte budget")
    ap.add_argument("--page-tokens", type=int, default=8,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--layer0-bytes", type=int, default=None,
                    help="layer-0 (hot tier) budget; default: the dense "
                         "pool's footprint")
    ap.add_argument("--layer1-bytes", type=int, default=None,
                    help="layer-1 (spill tier) budget; default: derived "
                         "from the target's TieredPartition")
    ap.add_argument("--max-slots", type=int, default=32,
                    help="cap on paged-mode concurrent slots")
    ap.add_argument("--require-spill", action="store_true",
                    help="fail unless the layer-1 spill tier was exercised")
    ap.add_argument("--prefix-share", action="store_true",
                    help="replay a shared-system-prompt stream through the "
                         "paged pool with prefix sharing off vs on (same "
                         "layer-0 bytes; outputs must be bit-identical)")
    ap.add_argument("--system-len", type=int, default=None,
                    help="shared system-prompt length for --prefix-share "
                         "(default: 3 full pages)")
    ap.add_argument("--require-share-win", action="store_true",
                    help="fail unless sharing shows >=1.5x mapped/physical "
                         "residency and no-worse TTFT p95")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="run the chunked-prefill admission-stall "
                         "head-to-head instead of the mode comparison: "
                         "baseline stream vs one-shot vs chunked admission "
                         "of one --long-prompt-len prompt")
    ap.add_argument("--long-prompt-len", type=int, default=4096,
                    help="length of the admission-stall prompt "
                         "(--chunked-prefill)")
    ap.add_argument("--chunk-prefill-tokens", type=int, default=0,
                    metavar="N",
                    help="per-boundary prefill-token budget for the "
                         "chunked run (0: derive from the target's "
                         "CapacityPartition)")
    ap.add_argument("--sync-interval", type=int, default=None,
                    help="decode steps per drain boundary (default: 4, or "
                         "8 in --chunked-prefill mode)")
    ap.add_argument("--require-flat-p99", action="store_true",
                    help="fail unless chunked p99 inter-token latency "
                         "stays within --flat-p99-tol of baseline while "
                         "the one-shot admission degrades past it")
    ap.add_argument("--flat-p99-tol", type=float, default=0.10,
                    help="relative p99 tolerance for --require-flat-p99")
    ap.add_argument("--repeats", type=int, default=3,
                    help="measured passes per run in --chunked-prefill "
                         "mode; the median-p99 pass is reported")
    ap.add_argument("--speculate", action="store_true",
                    help="run the speculative-decoding head-to-head "
                         "instead of the mode comparison: the repetitive "
                         "stream through the paged pool, speculation off "
                         "vs on in the same layer-0 bytes")
    ap.add_argument("--speculate-tokens", type=int, default=0, metavar="K",
                    help="draft tokens per slot per boundary for "
                         "--speculate (0: derive from the target's "
                         "CapacityPartition)")
    ap.add_argument("--require-speculate-win", action="store_true",
                    help="fail unless speculation shows >=1.5x decode "
                         "tokens-per-forward with bit-identical outputs")
    ap.add_argument("--mesh", default="1",
                    help="device mesh sizes, 'DxM' (matching --mesh-axes) "
                         "or one int (model-parallel shorthand: '2' = "
                         "1x2); any size > 1 runs the mesh-sharded "
                         "head-to-head instead of the mode comparison")
    ap.add_argument("--mesh-axes", default="data,model",
                    help="comma-separated axis names for --mesh")
    ap.add_argument("--require-scaling", action="store_true",
                    help="fail unless the --mesh run shows >=1.7x modeled "
                         "decode scaling with one-shot-exact outputs and "
                         "one host sync per drain boundary")
    ap.add_argument("--disaggregate", action="store_true",
                    help="run the prefill/decode role-split head-to-head "
                         "instead of the mode comparison: mixed long-"
                         "prompt + decode traffic through the same paged "
                         "pool, combined engine vs disaggregated roles")
    ap.add_argument("--n-long", type=int, default=3,
                    help="long prompts interleaved into the --disaggregate "
                         "stream")
    ap.add_argument("--require-disagg-win", action="store_true",
                    help="fail unless the role split shows >= "
                         "--disagg-win-min x decode tok/s at inter-token "
                         "p99 within --flat-p99-tol of combined, with "
                         "bit-identical outputs")
    ap.add_argument("--disagg-win-min", type=float, default=1.15,
                    help="decode tok/s ratio --require-disagg-win gates on")
    ap.add_argument("--kv-quant", choices=("fp16", "fp8", "int8"),
                    default=None,
                    help="run the tier-codec head-to-head instead of the "
                         "mode comparison: the same stream in the same "
                         "layer-0 bytes, fp16 pages vs this codec")
    ap.add_argument("--park-idle", type=int, default=0, metavar="N",
                    help="inside the --kv-quant runs: after N decode "
                         "steps park every decoding resident to the "
                         "layer-2 host tier, resume, and finish")
    ap.add_argument("--require-residency-win", action="store_true",
                    help="fail unless the quantized run holds >=1.8x the "
                         "fp16 run's concurrent residents in the same "
                         "layer-0 bytes at >=0.75 greedy first-token "
                         "agreement")
    ap.add_argument("--emit-bench", action="store_true",
                    help="write the flat cross-PR metric file "
                         "BENCH_%d.json at the repo root" % BENCH_ID)
    add_target_arg(ap)
    args = ap.parse_args(argv)
    try:
        mesh_n = 1
        for part in args.mesh.split("x"):
            mesh_n *= int(part)
    except ValueError:
        mesh_n = 0      # malformed: let parse_mesh raise the real error
    if mesh_n != 1 or args.require_scaling:
        print(run_mesh(
            args.target, args.arch, args.requests,
            args.prompt_len, args.gen_len, args.seed,
            page_tokens=args.page_tokens, layer0_bytes=args.layer0_bytes,
            layer1_bytes=args.layer1_bytes, max_slots=args.max_slots,
            mesh_spec=args.mesh, mesh_axes=args.mesh_axes,
            sync_interval=args.sync_interval,
            require_scaling=args.require_scaling,
            emit_bench=args.emit_bench))
        return 0
    if args.disaggregate or args.require_disagg_win:
        print(run_disagg(
            args.target, args.arch, args.requests, args.prompt_len,
            args.gen_len, args.slots, args.seed,
            page_tokens=args.page_tokens, layer0_bytes=args.layer0_bytes,
            layer1_bytes=args.layer1_bytes, max_slots=args.max_slots,
            long_prompt_len=args.long_prompt_len, n_long=args.n_long,
            chunk_prefill_tokens=args.chunk_prefill_tokens,
            sync_interval=args.sync_interval or 8,
            disagg_win_min=args.disagg_win_min,
            flat_p99_tol=args.flat_p99_tol,
            require_disagg_win=args.require_disagg_win,
            emit_bench=args.emit_bench))
        return 0
    if args.kv_quant or args.require_residency_win:
        print(run_quant(
            args.target, args.arch, args.requests, args.prompt_len,
            args.gen_len, args.seed, page_tokens=args.page_tokens,
            layer0_bytes=args.layer0_bytes,
            layer1_bytes=args.layer1_bytes, max_slots=args.max_slots,
            kv_quant=args.kv_quant or "int8", park_idle=args.park_idle,
            sync_interval=args.sync_interval,
            require_residency_win=args.require_residency_win,
            emit_bench=args.emit_bench))
        return 0
    if args.speculate:
        print(run_speculate(
            args.target, args.arch, args.requests,
            args.prompt_len, args.gen_len,
            args.slots, args.seed, page_tokens=args.page_tokens,
            layer0_bytes=args.layer0_bytes,
            layer1_bytes=args.layer1_bytes, max_slots=args.max_slots,
            speculate_tokens=args.speculate_tokens,
            sync_interval=args.sync_interval,
            require_speculate_win=args.require_speculate_win,
            emit_bench=args.emit_bench))
        return 0
    if args.chunked_prefill:
        print(run_chunked(
            args.target, args.arch, args.requests, args.prompt_len,
            args.gen_len, args.slots or 16, args.seed,
            page_tokens=args.page_tokens, layer0_bytes=args.layer0_bytes,
            layer1_bytes=args.layer1_bytes, max_slots=args.max_slots,
            prefix_share=args.prefix_share, system_len=args.system_len,
            long_prompt_len=args.long_prompt_len,
            chunk_prefill_tokens=args.chunk_prefill_tokens,
            sync_interval=args.sync_interval or 32,
            flat_p99_tol=args.flat_p99_tol,
            require_flat_p99=args.require_flat_p99,
            require_spill=args.require_spill, repeats=args.repeats,
            emit_bench=args.emit_bench))
        return 0
    print(run(args.target, args.arch, args.requests, args.prompt_len,
              args.gen_len, args.slots, args.seed, paged=args.paged,
              page_tokens=args.page_tokens, layer0_bytes=args.layer0_bytes,
              layer1_bytes=args.layer1_bytes, max_slots=args.max_slots,
              require_spill=args.require_spill,
              prefix_share=args.prefix_share, system_len=args.system_len,
              require_share_win=args.require_share_win,
              sync_interval=args.sync_interval,
              emit_bench=args.emit_bench))
    return 0


if __name__ == "__main__":
    sys.exit(main())
