"""Serving benchmark: static vs continuous vs paged-two-tier vs
prefix-shared tokens/s AND pool footprint.

Drives the same synthetic mixed short/long request stream through the same
Engine in up to four modes:

  * **static** — requests are grouped into fixed batches of ``n_slots``; a
    batch admits once and decodes until its SLOWEST request drains (empty
    slots idle — the classic straggler cost).
  * **continuous** — one scheduler over the whole stream; drained slots are
    refilled from the queue at every drain boundary. Dense pool: every slot
    reserves a ``max_len``-deep KV slab.
  * **paged** (``--paged``) — the paged two-tier pool inside the SAME
    layer-0 byte budget the dense pool used: admission by pages, spill to
    the layer-1 tier under pressure. The interesting number is not just
    tok/s but *concurrent slots per byte* — the capacity win the paper gets
    from stacking a second memory layer.
  * **paged+share** (``--prefix-share``) — the stream becomes the
    shared-system-prompt workload (one common ``--system-len`` prefix per
    request) and the paged pool runs twice in the SAME layer-0 byte
    budget, sharing off vs on. Reported head-to-head: tok/s, TTFT
    percentiles, physical vs *mapped* pages (the concurrent-residency
    win), plus a bit-identical output check between the two runs.

Every record carries pool bytes and pages-in-use next to throughput, so the
dense-vs-paged comparison shows capacity, not just speed. Emits
``benchmarks/artifacts/serve_bench.json``.

    PYTHONPATH=src python -m benchmarks.serve_bench [--target NAME] [--paged]
        [--page-tokens N] [--layer0-bytes B] [--layer1-bytes B]
        [--require-spill] [--prefix-share] [--system-len N]
        [--require-share-win] [...]
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional

from benchmarks.common import add_target_arg, fmt_table, save_artifact, \
    target_scope


def _run_mode(engine, stream: List[Dict], n_slots: int, mode: str,
              geom=None) -> Dict:
    from repro.serve.scheduler import Scheduler, percentile

    paged = mode in ("paged", "paged+share")

    def make_sched():
        return Scheduler(n_slots=n_slots, pages=geom if paged else None,
                         prefix_share=(mode == "paged+share"))

    t0 = time.monotonic()
    reports = []
    if mode == "static":                    # one batch at a time
        for i in range(0, len(stream), n_slots):
            sch = make_sched()
            for spec in stream[i:i + n_slots]:
                sch.submit(spec["prompt"], spec["max_new_tokens"])
            reports.append(engine.serve(scheduler=sch))
    else:                                   # continuous / paged [+share]
        sch = make_sched()
        for spec in stream:
            sch.submit(spec["prompt"], spec["max_new_tokens"])
        reports.append(engine.serve(scheduler=sch))
    dt = time.monotonic() - t0
    n_tokens = sum(len(r.tokens) for rep in reports for r in rep.requests)
    ttft = [t for rep in reports for t in rep.stats["ttft_steps"]]
    last = reports[-1].stats
    rec = {
        "mode": mode,
        "wall_s": dt,
        "n_tokens": n_tokens,
        "tok_per_s": n_tokens / dt if dt else 0.0,
        "decode_steps": sum(rep.stats["decode_steps"] for rep in reports),
        "host_syncs": sum(rep.stats["host_syncs"] for rep in reports),
        "max_slot_reuse": max(rep.stats["max_slot_reuse"]
                              for rep in reports),
        "completed": sum(rep.stats["drained"] for rep in reports),
        "n_slots": n_slots,
        "preemptions": sum(rep.stats["preemptions"] for rep in reports),
        "spilled_pages": sum(rep.stats["spilled_pages"] for rep in reports),
        "restores": sum(rep.stats["restores"] for rep in reports),
        # admission wait in decode-step clock units (scheduler TTFT).
        # Meaningless for static mode: each per-batch serve() restarts the
        # step clock, so cross-batch queueing is invisible — reported as
        # None and rendered "-" in the table.
        "ttft_steps_p50": (None if mode == "static"
                           else percentile(ttft, 50)),
        "ttft_steps_p95": (None if mode == "static"
                           else percentile(ttft, 95)),
        # rid -> tokens, for cross-mode bit-identity checks (single-report
        # modes only: static restarts rids per batch)
        "outputs": ({r.rid: list(r.tokens) for r in reports[0].requests}
                    if len(reports) == 1 else {}),
    }
    if paged:
        rec.update({
            "pool_bytes": last["pool_bytes"],
            "spill_bytes": last["spill_bytes"],
            "page_tokens": last["page_tokens"],
            "n_pages": last["n_pages"],
            "pages_high_water": max(rep.stats["pages_high_water"]
                                    for rep in reports),
            "spill_high_water": max(rep.stats["spill_high_water"]
                                    for rep in reports),
            "mapped_high_water": max(rep.stats["mapped_high_water"]
                                     for rep in reports),
        })
    if mode == "paged+share":
        rec.update({k: last[k] for k in (
            "prefix_hits", "prefix_misses", "shared_prefix_tokens",
            "cow_copies")})
        rec["residency_ratio"] = (rec["mapped_high_water"]
                                  / max(rec["pages_high_water"], 1))
    return rec


def run(target_name=None, arch: str = "qwen2.5-3b", n_requests: int = 32,
        prompt_len: int = 16, gen_len: int = 12, n_slots: int = None,
        seed: int = 0, paged: bool = False, page_tokens: int = 8,
        layer0_bytes: Optional[int] = None,
        layer1_bytes: Optional[int] = None, max_slots: int = 32,
        require_spill: bool = False, prefix_share: bool = False,
        system_len: Optional[int] = None,
        require_share_win: bool = False) -> str:
    import jax
    from repro.configs import get_reduced
    from repro.core.target import get_target
    from repro.models import build_model
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import (derive_n_slots, derive_page_geometry,
                                       kv_bytes_per_token,
                                       shared_prefix_stream, synthetic_stream)

    paged = paged or prefix_share
    with target_scope(target_name):
        target = get_target()
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if prefix_share:
            # shared-system-prompt workload: one common prefix (3 full
            # pages by default) + unique tails up to one page
            system_len = system_len or 3 * page_tokens
            tail_len = page_tokens
            prompt_len = system_len + tail_len
            stream = shared_prefix_stream(n_requests, system_len, tail_len,
                                          gen_len, cfg.vocab_size, seed)
        else:
            stream = synthetic_stream(n_requests, prompt_len, gen_len,
                                      cfg.vocab_size, seed)
        max_len = prompt_len + gen_len
        n_slots = n_slots or derive_n_slots(cfg, max_len, max_slots=8)
        engine = Engine(model, params,
                        EngineConfig(max_len=max_len, sync_interval=4))
        # the dense pool's layer-0 footprint is the shared byte budget:
        # the paged pool must beat it on concurrency INSIDE the same bytes
        dense_bytes = n_slots * kv_bytes_per_token(cfg) * max_len
        modes = [("static", n_slots, None), ("continuous", n_slots, None)]
        geom = None
        if paged:
            geom = derive_page_geometry(
                cfg, max_len, page_tokens=page_tokens, max_slots=max_slots,
                layer0_bytes=(layer0_bytes if layer0_bytes is not None
                              else dense_bytes),
                layer1_bytes=layer1_bytes)
            paged_slots = derive_n_slots(cfg, max_len, pages=geom,
                                         max_slots=max_slots)
            modes.append(("paged", paged_slots, geom))
            if prefix_share:
                # sharing on vs off, SAME geometry and layer-0 bytes
                modes.append(("paged+share", paged_slots, geom))
        # warmup: compile prefill (per distinct prompt length) + decode chunk
        for mode, slots, g in modes[1:]:
            _run_mode(engine, stream, slots, mode, g)
        recs = [_run_mode(engine, stream, slots, mode, g)
                for mode, slots, g in modes]

    by_mode = {r["mode"]: r for r in recs}
    outputs = {r["mode"]: r.pop("outputs") for r in recs}   # not in artifact
    stat, cont = by_mode["static"], by_mode["continuous"]
    for r in recs:
        r["pool_bytes"] = r.get("pool_bytes", dense_bytes)
    speedup = (cont["tok_per_s"] / stat["tok_per_s"]
               if stat["tok_per_s"] else 0.0)
    artifact = {
        "arch": cfg.name, "target": target.name, "n_requests": n_requests,
        "prompt_len": prompt_len, "gen_len": gen_len, "n_slots": n_slots,
        "dense_pool_bytes": dense_bytes,
        "static": stat, "continuous": cont, "speedup_tok_per_s": speedup,
    }
    lines = []
    if paged:
        pg = by_mode["paged"]
        slots_ratio = pg["n_slots"] / max(cont["n_slots"], 1)
        artifact.update({
            "paged": pg,
            "slots_ratio_paged_vs_dense": slots_ratio,
            "layer0_bytes": pg["pool_bytes"],
            "layer1_bytes": pg["spill_bytes"],
        })
        lines.append(
            f"paged vs dense concurrency: {pg['n_slots']} vs "
            f"{cont['n_slots']} slots in {pg['pool_bytes']} layer-0 bytes "
            f"({slots_ratio:.2f}x), spill tier: {pg['preemptions']} "
            f"preemptions / {pg['spilled_pages']} pages")
        if require_spill and pg["preemptions"] < 1:
            raise SystemExit(
                "serve_bench --require-spill: the layer-1 spill tier was "
                "never exercised — shrink --layer0-bytes")
    if prefix_share:
        pg, sh = by_mode["paged"], by_mode["paged+share"]
        if outputs["paged"] != outputs["paged+share"]:
            raise SystemExit(
                "serve_bench --prefix-share: sharing-on outputs differ "
                "from sharing-off — prefix sharing must be bit-exact")
        artifact.update({
            "prefix_share": sh, "system_len": system_len,
            "residency_ratio": sh["residency_ratio"],
            "share_outputs_bit_identical": True,
        })
        lines.append(
            f"prefix sharing (system prompt {system_len} tok, same "
            f"{sh['pool_bytes']} layer-0 bytes): residency "
            f"{sh['mapped_high_water']} mapped vs {sh['pages_high_water']} "
            f"physical pages ({sh['residency_ratio']:.2f}x), ttft p50/p95 "
            f"{sh['ttft_steps_p50']:.0f}/{sh['ttft_steps_p95']:.0f} vs "
            f"{pg['ttft_steps_p50']:.0f}/{pg['ttft_steps_p95']:.0f} steps "
            f"sharing-off, {sh['prefix_hits']} hits "
            f"({sh['shared_prefix_tokens']} prompt tokens from cache, "
            f"{sh['cow_copies']} COW), outputs bit-identical")
        if require_share_win and (
                sh["residency_ratio"] < 1.5
                or sh["ttft_steps_p95"] > pg["ttft_steps_p95"]):
            raise SystemExit(
                "serve_bench --require-share-win: expected >=1.5x mapped/"
                "physical residency and no-worse TTFT p95 with sharing on; "
                f"got {sh['residency_ratio']:.2f}x, p95 "
                f"{sh['ttft_steps_p95']:.0f} vs {pg['ttft_steps_p95']:.0f}")
    save_artifact("serve_bench.json", artifact)
    rows = [[r["mode"], f"{r['tok_per_s']:.1f}", r["n_tokens"], r["n_slots"],
             r["pool_bytes"], r.get("pages_high_water", "-"),
             ("-" if r["ttft_steps_p50"] is None else
              f"{r['ttft_steps_p50']:.0f}/{r['ttft_steps_p95']:.0f}"),
             r["preemptions"], r["max_slot_reuse"],
             f"{r['wall_s']*1e3:.0f} ms"] for r in recs]
    table = fmt_table(
        ["mode", "tok/s", "tokens", "slots", "pool bytes", "pages hw",
         "ttft p50/95", "preempt", "max reuse", "wall"],
        rows, title=f"Serve bench — {cfg.name}, {n_requests} requests "
                    f"({target.name})")
    return "\n".join([table,
                      f"continuous/static speedup: {speedup:.2f}x"] + lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="also run the paged two-tier pool inside the dense "
                         "pool's layer-0 byte budget")
    ap.add_argument("--page-tokens", type=int, default=8,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--layer0-bytes", type=int, default=None,
                    help="layer-0 (hot tier) budget; default: the dense "
                         "pool's footprint")
    ap.add_argument("--layer1-bytes", type=int, default=None,
                    help="layer-1 (spill tier) budget; default: derived "
                         "from the target's TieredPartition")
    ap.add_argument("--max-slots", type=int, default=32,
                    help="cap on paged-mode concurrent slots")
    ap.add_argument("--require-spill", action="store_true",
                    help="fail unless the layer-1 spill tier was exercised")
    ap.add_argument("--prefix-share", action="store_true",
                    help="replay a shared-system-prompt stream through the "
                         "paged pool with prefix sharing off vs on (same "
                         "layer-0 bytes; outputs must be bit-identical)")
    ap.add_argument("--system-len", type=int, default=None,
                    help="shared system-prompt length for --prefix-share "
                         "(default: 3 full pages)")
    ap.add_argument("--require-share-win", action="store_true",
                    help="fail unless sharing shows >=1.5x mapped/physical "
                         "residency and no-worse TTFT p95")
    add_target_arg(ap)
    args = ap.parse_args(argv)
    print(run(args.target, args.arch, args.requests, args.prompt_len,
              args.gen_len, args.slots, args.seed, paged=args.paged,
              page_tokens=args.page_tokens, layer0_bytes=args.layer0_bytes,
              layer1_bytes=args.layer1_bytes, max_slots=args.max_slots,
              require_spill=args.require_spill,
              prefix_share=args.prefix_share, system_len=args.system_len,
              require_share_win=args.require_share_win))
    return 0


if __name__ == "__main__":
    sys.exit(main())
