"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
three-term roofline table (single-pod, per the assignment)."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import fmt_table, load_dryrun_artifacts, save_artifact


def _fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def rows_for(mesh: str) -> List[Dict]:
    out = []
    for rec in load_dryrun_artifacts(mesh):
        if rec.get("status") != "ok":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "status": rec.get("status", "?"),
                        "reason": rec.get("reason", rec.get("error", ""))})
            continue
        r = rec["roofline"]
        out.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bound": r["bound"],
            "useful": r["useful_flops_ratio"],
            "roofline_fraction": r["roofline_fraction"],
            "temp_gib": (rec["memory"]["temp_size_in_bytes"] or 0) / 2**30,
            "args_gib": (rec["memory"]["argument_size_in_bytes"] or 0) / 2**30,
        })
    return out


def run(mesh: str = "16x16") -> str:
    data = rows_for(mesh)
    rows = []
    for d in sorted(data, key=lambda d: (d["arch"], d["shape"])):
        if d["status"] != "ok":
            rows.append([d["arch"], d["shape"], d["status"],
                         "-", "-", "-", "-", "-", "-", d["reason"][:44]])
            continue
        rows.append([
            d["arch"], d["shape"], "ok",
            f"{d['compute_s']*1e3:.1f}", f"{d['memory_s']*1e3:.1f}",
            f"{d['collective_s']*1e3:.1f}", d["bound"],
            f"{d['useful']:.2f}", f"{d['roofline_fraction']:.3f}",
            f"temp {d['temp_gib']:.1f} GiB",
        ])
    save_artifact(f"roofline_{mesh}.json", data)
    return fmt_table(
        ["arch", "shape", "status", "compute ms", "memory ms",
         "collective ms", "bound", "useful", "roofline", "mem/device"],
        rows, title=f"Roofline — {mesh} mesh (per step, per-chip terms)")


def _run_both() -> str:
    out = [run("16x16"), ""]
    try:
        out.append(run("2x16x16"))
    except Exception:
        out.append("(multi-pod artifacts not yet complete)")
    return "\n".join(out)


def main(argv=None) -> None:
    from benchmarks.common import run_cli
    run_cli(_run_both, __doc__, argv)


if __name__ == "__main__":
    main()
