"""Paper Figs. 7, 8, 9: performance / energy efficiency / EDP across the
eight MemPool configurations at 16 B/cycle, with the paper's headline claims
validated inline."""

from __future__ import annotations

from repro.core import energy
from repro.core.hw_profiles import SPM_CAPACITIES_MIB

from benchmarks.common import fmt_table, pct, save_artifact


def run() -> str:
    derived = energy.derive_all(bw_bytes_per_cycle=16)
    rows = []
    for mib in SPM_CAPACITIES_MIB:
        d2 = derived[f"MemPool-2D_{mib}MiB"]
        d3 = derived[f"MemPool-3D_{mib}MiB"]
        rows.append([
            f"{mib} MiB",
            f"{d2.performance:.3f}", f"{d3.performance:.3f}",
            pct(d3.performance / d2.performance - 1),
            f"{d2.efficiency:.3f}", f"{d3.efficiency:.3f}",
            pct(d3.efficiency / d2.efficiency - 1),
            f"{d2.edp:.3f}", f"{d3.edp:.3f}",
            pct(d3.edp / d2.edp - 1),
        ])
    save_artifact("fig789.json", {k: v.to_dict() if hasattr(v, "to_dict")
                                  else v.__dict__ for k, v in derived.items()})

    checks = [
        ("Fig7: 3D@4MiB perf vs 2D@4MiB (paper +9.1%)",
         derived["MemPool-3D_4MiB"].performance
         / derived["MemPool-2D_4MiB"].performance - 1, 0.091),
        ("Fig7: 3D@8MiB perf vs baseline (paper +8.4%)",
         derived["MemPool-3D_8MiB"].performance - 1, 0.084),
        ("Fig8: 3D@1MiB efficiency vs baseline (paper +14%)",
         derived["MemPool-3D_1MiB"].efficiency - 1, 0.14),
        ("Fig8: 3D@4MiB efficiency vs 2D@4MiB (paper +18.4%)",
         derived["MemPool-3D_4MiB"].efficiency
         / derived["MemPool-2D_4MiB"].efficiency - 1, 0.184),
        ("Fig8: 3D@4MiB energy vs 2D@1MiB (paper -3.7%)",
         derived["MemPool-3D_4MiB"].energy - 1, -0.037),
        ("Fig9: 3D@1MiB EDP vs baseline (paper -15.6%)",
         derived["MemPool-3D_1MiB"].edp - 1, -0.156),
    ]
    lines = [fmt_table(
        ["SPM", "perf 2D", "perf 3D", "Δ", "eff 2D", "eff 3D", "Δ",
         "EDP 2D", "EDP 3D", "Δ"],
        rows, title="Figs. 7-9 — performance / efficiency / EDP @ 16 B/cyc")]
    lines.append("")
    for name, got, want in checks:
        ok = "OK " if abs(got - want) < 0.015 else "DIFF"
        lines.append(f"  [{ok}] {name}: got {pct(got)}")
    return "\n".join(lines)


def main(argv=None) -> None:
    from benchmarks.common import run_cli
    run_cli(run, __doc__, argv)


if __name__ == "__main__":
    main()
