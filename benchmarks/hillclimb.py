"""§Perf hillclimb driver: run one (arch x shape) cell with experimental
overrides and record the roofline delta vs the baseline artifact.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen2.5-3b \
        --shape train_4k --tag dp_layout --set layout=dp --set n_microbatches=1

Results land in benchmarks/artifacts/perf/<arch>__<shape>__<tag>.json with
the baseline terms embedded for the before/after table in EXPERIMENTS.md.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="override key=value (value parsed as json if possible)")
    ap.add_argument("--multi-pod", action="store_true")
    from benchmarks.common import add_target_arg
    add_target_arg(ap)
    args = ap.parse_args()
    if args.target:        # process-wide: the dry-run below plans against it
        from repro.core.target import set_target
        set_target(args.target)

    from repro.launch import dryrun

    ov = dict(dryrun.TRAIN_OVERRIDES.get(args.arch, {}))
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        ov[k] = v
    dryrun.TRAIN_OVERRIDES[args.arch] = ov
    rec = dryrun.dryrun_cell(args.arch, args.shape, multi_pod=args.multi_pod)

    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    base_path = dryrun.artifact_path(mesh_tag, args.arch, args.shape)
    baseline = None
    if os.path.exists(base_path):
        with open(base_path) as f:
            baseline = json.load(f)

    out = {"tag": args.tag, "overrides": {k: v for k, v in ov.items()},
           "result": rec,
           "baseline_roofline": (baseline or {}).get("roofline"),
           "baseline_memory": (baseline or {}).get("memory")}
    d = os.path.join(os.path.dirname(__file__), "artifacts", "perf")
    os.makedirs(d, exist_ok=True)
    path = os.path.join(
        d, f"{args.arch}__{args.shape}__{args.tag}.json".replace("/", "_"))
    with open(path, "w") as f:
        json.dump(out, f, indent=1)

    if baseline and rec.get("status") == "ok":
        b, n = baseline["roofline"], rec["roofline"]
        print("\n--- before/after ---")
        for k in ("compute_s", "memory_s", "collective_s",
                  "roofline_fraction"):
            print(f"{k:20s} {b[k]:10.4f} -> {n[k]:10.4f} "
                  f"({(n[k]/b[k]-1)*100 if b[k] else 0:+.1f}%)")
        print(f"bound: {b['bound']} -> {n['bound']}")
        bt = (baseline["memory"]["temp_size_in_bytes"] or 0) / 2**30
        nt = (rec["memory"]["temp_size_in_bytes"] or 0) / 2**30
        print(f"temp GiB: {bt:.2f} -> {nt:.2f}")
    print(f"saved {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
