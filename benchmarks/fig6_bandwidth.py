"""Paper Fig. 6: matmul cycle-count speedup vs off-chip bandwidth x SPM
capacity, relative to (1 MiB, 4 B/cycle). Validates the paper's three
published points (43 % / 16 % / 8 % for 8 MiB vs 1 MiB)."""

from __future__ import annotations

from repro.core import perf_model
from repro.core.hw_profiles import MiB, SPM_CAPACITIES_MIB
from repro.core.target import get_target

from benchmarks.common import fmt_table, save_artifact

#: the three speedups (8 MiB over 1 MiB at equal bandwidth) §VI-A publishes
PAPER_POINTS = {4: 1.43, 16: 1.16, 64: 1.08}


def run() -> str:
    # capacities come from the registered MemPool targets' scratchpad level
    caps = [get_target(f"mempool-2d-{mib}mib").scratchpad_bytes // MiB
            for mib in SPM_CAPACITIES_MIB]
    table = perf_model.fig6_table(capacities_mib=caps)
    rows = []
    for bw, caps in table.items():
        marks = []
        for cap, v in caps.items():
            marks.append(f"{v:.3f}")
        rel8 = caps[8] / caps[1]
        check = ""
        if bw in PAPER_POINTS:
            check = f"8v1={rel8:.2f} (paper {PAPER_POINTS[bw]:.2f})"
        rows.append([f"{bw:g} B/cyc"] + marks + [check])
    save_artifact("fig6.json", {str(k): v for k, v in table.items()})
    return fmt_table(
        ["off-chip BW", "1 MiB", "2 MiB", "4 MiB", "8 MiB", "validation"],
        rows, title="Fig. 6 — cycle-count speedup vs (1 MiB, 4 B/cyc)")


def main(argv=None) -> None:
    from benchmarks.common import run_cli
    run_cli(run, __doc__, argv)


if __name__ == "__main__":
    main()
