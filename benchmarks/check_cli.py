"""CI tooling check: every runnable benchmark script accepts ``--target``.

Target selection by name is the registry contract (DESIGN.md
§HardwareTarget); this check keeps new benchmark scripts honest. Runs each
script's ``--help`` in-process and greps the usage text.

    PYTHONPATH=src python -m benchmarks.check_cli
"""

from __future__ import annotations

import contextlib
import glob
import io
import os
import runpy
import sys

#: library modules, not CLI entry points
NON_CLI = {"common.py", "check_cli.py", "__init__.py"}


def check(path: str) -> str:
    """Returns '' if ok, else a failure reason."""
    argv, sys.argv = sys.argv, [path, "--help"]
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf), contextlib.redirect_stderr(buf):
            runpy.run_path(path, run_name="__main__")
        return "no argparse --help (script ran to completion)"
    except SystemExit as e:
        if e.code not in (0, None):
            return f"--help exited {e.code}: {buf.getvalue()[-200:]}"
    except Exception as e:   # noqa: BLE001 — report, don't crash the sweep
        return f"{type(e).__name__}: {e}"
    finally:
        sys.argv = argv
    if "--target" not in buf.getvalue():
        return "--help does not mention --target"
    return ""


def main() -> int:
    root = os.path.dirname(os.path.abspath(__file__))
    failures = []
    for path in sorted(glob.glob(os.path.join(root, "*.py"))):
        name = os.path.basename(path)
        if name in NON_CLI:
            continue
        reason = check(path)
        status = "FAIL" if reason else "ok"
        print(f"[{status:4s}] {name}" + (f" — {reason}" if reason else ""))
        if reason:
            failures.append(name)
    if failures:
        print(f"\n{len(failures)} benchmark script(s) missing --target: "
              f"{', '.join(failures)}")
        return 1
    print("\nall benchmark scripts accept --target")
    return 0


if __name__ == "__main__":
    sys.exit(main())
