"""CI tooling check: every runnable benchmark script accepts ``--target``,
and the serving CLIs expose their contracted flags.

Target selection by name is the registry contract (DESIGN.md
§HardwareTarget); the serve benchmark's ``--paged`` / tier-budget flags are
the contract for the dense-vs-paged capacity comparison (DESIGN.md §Paged
two-tier pool), and its ``--chunked-prefill`` family is the contract for
the admission-stall head-to-head (DESIGN.md §Chunked prefill), and its
``--speculate`` family is the contract for the speculative-decoding
head-to-head (DESIGN.md §Speculative decoding), and its ``--mesh``
family is the contract for the mesh-sharded scaling head-to-head
(DESIGN.md §Sharded serving), and its ``--disaggregate`` family is the
contract for the prefill/decode role-split head-to-head (DESIGN.md
§Disaggregated serving), and its ``--kv-quant`` family is the contract
for the tier-codec residency head-to-head (DESIGN.md §Tiered KV
compression & host parking). The stream driver ``repro.launch.serve``
is checked too: it must expose ``--chunk-prefill-tokens``,
``--speculate-tokens``, ``--mesh``, ``--disaggregate``, ``--kv-quant``
and ``--park-idle`` so the serving knobs documented in docs/SERVING.md
stay wired. Runs each script's
``--help`` in-process and greps the usage text.

    PYTHONPATH=src python -m benchmarks.check_cli
"""

from __future__ import annotations

import contextlib
import glob
import io
import os
import runpy
import sys

#: library modules, not CLI entry points
NON_CLI = {"common.py", "check_cli.py", "__init__.py"}

#: per-script extra required flags, beyond the universal --target
EXTRA_FLAGS = {
    "serve_bench.py": ("--paged", "--page-tokens", "--layer0-bytes",
                       "--layer1-bytes", "--require-spill", "--prefix-share",
                       "--system-len", "--require-share-win",
                       "--chunked-prefill", "--chunk-prefill-tokens",
                       "--long-prompt-len", "--sync-interval",
                       "--require-flat-p99", "--flat-p99-tol", "--repeats",
                       "--speculate", "--speculate-tokens",
                       "--require-speculate-win", "--mesh", "--mesh-axes",
                       "--require-scaling", "--disaggregate",
                       "--require-disagg-win", "--disagg-win-min",
                       "--kv-quant", "--park-idle",
                       "--require-residency-win", "--emit-bench"),
}

#: non-benchmark CLI entry points checked for specific flags only (no
#: --target requirement): (path relative to repo root, required flags)
EXTRA_CLIS = (
    (os.path.join("src", "repro", "launch", "serve.py"),
     ("--chunk-prefill-tokens", "--paged", "--prefix-share",
      "--speculate-tokens", "--mesh", "--mesh-axes", "--disaggregate",
      "--kv-quant", "--park-idle")),
)


def check(path: str, flags=("--target",)) -> str:
    """Returns '' if ok, else a failure reason."""
    argv, sys.argv = sys.argv, [path, "--help"]
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf), contextlib.redirect_stderr(buf):
            runpy.run_path(path, run_name="__main__")
        return "no argparse --help (script ran to completion)"
    except SystemExit as e:
        if e.code not in (0, None):
            return f"--help exited {e.code}: {buf.getvalue()[-200:]}"
    except Exception as e:   # noqa: BLE001 — report, don't crash the sweep
        return f"{type(e).__name__}: {e}"
    finally:
        sys.argv = argv
    missing = [flag for flag in flags if flag not in buf.getvalue()]
    if missing:
        return f"--help does not mention {', '.join(missing)}"
    return ""


def main() -> int:
    root = os.path.dirname(os.path.abspath(__file__))
    failures = []

    def run_check(path, label, flags):
        reason = check(path, flags)
        status = "FAIL" if reason else "ok"
        print(f"[{status:4s}] {label}" + (f" — {reason}" if reason else ""))
        if reason:
            failures.append(label)

    for path in sorted(glob.glob(os.path.join(root, "*.py"))):
        name = os.path.basename(path)
        if name in NON_CLI:
            continue
        run_check(path, name,
                  ("--target",) + EXTRA_FLAGS.get(name, ()))
    repo = os.path.dirname(root)
    for rel, flags in EXTRA_CLIS:
        run_check(os.path.join(repo, rel), rel, flags)
    if failures:
        print(f"\n{len(failures)} CLI(s) missing contracted flags: "
              f"{', '.join(failures)}")
        return 1
    print("\nall CLIs expose their contracted flags")
    return 0


if __name__ == "__main__":
    sys.exit(main())
