"""CI tooling check: every runnable benchmark script accepts ``--target``,
and the serving benchmark exposes the paged two-tier pool flags.

Target selection by name is the registry contract (DESIGN.md
§HardwareTarget); the serve benchmark's ``--paged`` / tier-budget flags are
the contract for the dense-vs-paged capacity comparison (DESIGN.md §Paged
two-tier pool). This check keeps new benchmark scripts honest. Runs each
script's ``--help`` in-process and greps the usage text.

    PYTHONPATH=src python -m benchmarks.check_cli
"""

from __future__ import annotations

import contextlib
import glob
import io
import os
import runpy
import sys

#: library modules, not CLI entry points
NON_CLI = {"common.py", "check_cli.py", "__init__.py"}

#: per-script extra required flags, beyond the universal --target
EXTRA_FLAGS = {
    "serve_bench.py": ("--paged", "--page-tokens", "--layer0-bytes",
                       "--layer1-bytes", "--require-spill", "--prefix-share",
                       "--system-len", "--require-share-win"),
}


def check(path: str) -> str:
    """Returns '' if ok, else a failure reason."""
    argv, sys.argv = sys.argv, [path, "--help"]
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf), contextlib.redirect_stderr(buf):
            runpy.run_path(path, run_name="__main__")
        return "no argparse --help (script ran to completion)"
    except SystemExit as e:
        if e.code not in (0, None):
            return f"--help exited {e.code}: {buf.getvalue()[-200:]}"
    except Exception as e:   # noqa: BLE001 — report, don't crash the sweep
        return f"{type(e).__name__}: {e}"
    finally:
        sys.argv = argv
    missing = [flag for flag in
               ("--target",) + EXTRA_FLAGS.get(os.path.basename(path), ())
               if flag not in buf.getvalue()]
    if missing:
        return f"--help does not mention {', '.join(missing)}"
    return ""


def main() -> int:
    root = os.path.dirname(os.path.abspath(__file__))
    failures = []
    for path in sorted(glob.glob(os.path.join(root, "*.py"))):
        name = os.path.basename(path)
        if name in NON_CLI:
            continue
        reason = check(path)
        status = "FAIL" if reason else "ok"
        print(f"[{status:4s}] {name}" + (f" — {reason}" if reason else ""))
        if reason:
            failures.append(name)
    if failures:
        print(f"\n{len(failures)} benchmark script(s) missing --target: "
              f"{', '.join(failures)}")
        return 1
    print("\nall benchmark scripts accept --target")
    return 0


if __name__ == "__main__":
    sys.exit(main())
