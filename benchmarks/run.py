"""Benchmark entry point: one section per paper table/figure + the roofline
aggregation. ``PYTHONPATH=src python -m benchmarks.run``"""

from __future__ import annotations

import sys
import time


def main(argv=None) -> int:
    import argparse

    from benchmarks import (fig6_bandwidth, fig789_energy, kernel_bench,
                            roofline, serve_bench, table1_tile, table2_group)
    from benchmarks.common import add_target_arg, target_scope
    ap = argparse.ArgumentParser(description=__doc__)
    add_target_arg(ap)
    args = ap.parse_args(argv)
    sections = [
        ("Table I (tile partitioning)", table1_tile.run),
        ("Table II (group PPA)", table2_group.run),
        ("Fig. 6 (bandwidth sweep)", fig6_bandwidth.run),
        ("Figs. 7-9 (perf/efficiency/EDP)", fig789_energy.run),
        ("Kernel bench", kernel_bench.run),
        ("Serve bench (continuous batching)", serve_bench.run),
        ("Roofline (single-pod)", lambda: roofline.run("16x16")),
        ("Roofline (multi-pod)", lambda: roofline.run("2x16x16")),
    ]
    failures = 0
    with target_scope(args.target):
        for name, fn in sections:
            t0 = time.time()
            print(f"\n{'=' * 72}\n{name}\n{'=' * 72}")
            try:
                print(fn())
            except Exception as e:  # keep reporting the rest
                failures += 1
                print(f"SECTION FAILED: {type(e).__name__}: {e}")
            print(f"[{time.time() - t0:.1f}s]")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
