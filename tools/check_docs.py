"""Docs-vs-code consistency check: references in the markdown docs must
resolve against the actual code.

Two rules, applied to README.md, DESIGN.md and docs/*.md (or any files
passed on the command line):

  1. **Symbol references.** Every backticked dotted name rooted at a
     project package — `repro.serve.scheduler.PagePool`,
     `benchmarks.serve_bench`, ... — must resolve: the longest importable
     module prefix is imported and the remaining attributes are looked up
     with getattr. Docs that name a symbol that was renamed or removed
     fail CI instead of quietly rotting.
  2. **CLI flags.** Every ``--flag`` on a documented ``python -m
     <module>`` invocation (line continuations included) must appear in
     that module's ``--help``. Additionally, a table of knobs can be
     bound to one or more modules with a directive comment on the line
     before it::

         <!-- check-docs: flags-for repro.launch.serve benchmarks.serve_bench -->

     Every backticked ``--flag`` in the table below the directive must
     then exist in EVERY listed module's ``--help``.

Runs in CI next to ``benchmarks/check_cli.py`` (which checks the inverse
direction: that benchmark CLIs expose the contracted flags at all).

    PYTHONPATH=src python tools/check_docs.py [files...]
"""

from __future__ import annotations

import contextlib
import glob
import importlib
import io
import os
import re
import runpy
import sys
from typing import Dict, List, Optional, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(_ROOT, "src"), _ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

#: packages whose dotted references the docs are held accountable for
PROJECT_ROOTS = ("repro", "benchmarks", "tools")

_REF_RE = re.compile(
    r"`((?:%s)(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`" % "|".join(PROJECT_ROOTS))
_CMD_RE = re.compile(r"python\s+-m\s+((?:%s)[A-Za-z0-9_.]*)"
                     % "|".join(PROJECT_ROOTS))
_FLAG_RE = re.compile(r"(--[A-Za-z][A-Za-z0-9-]*)")
_DIRECTIVE_RE = re.compile(r"<!--\s*check-docs:\s*flags-for\s+([^>]+?)\s*-->")


def resolve_symbol(ref: str) -> str:
    """'' if ``ref`` imports/getattrs cleanly, else the failure reason."""
    parts = ref.split(".")
    mod, err = None, None
    for i in range(len(parts), 0, -1):
        try:
            mod = importlib.import_module(".".join(parts[:i]))
            break
        except ImportError as e:
            err = e
            continue
    if mod is None:
        return f"no importable module prefix ({err})"
    obj = mod
    for attr in parts[i:]:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return f"{'.'.join(parts[:i])} has no attribute {attr!r}"
    return ""


def _module_help(module: str, cache: Dict[str, Optional[str]]) -> Optional[str]:
    """The module's ``--help`` text (cached), or None if it has no CLI."""
    if module in cache:
        return cache[module]
    argv, sys.argv = sys.argv, [module, "--help"]
    buf = io.StringIO()
    text: Optional[str] = None
    try:
        with contextlib.redirect_stdout(buf), contextlib.redirect_stderr(buf):
            runpy.run_module(module, run_name="__main__")
    except SystemExit as e:
        if e.code in (0, None):
            text = buf.getvalue()
    except Exception:    # noqa: BLE001 — a non-CLI module is not an error here
        text = None
    finally:
        sys.argv = argv
    cache[module] = text
    return text


def _continued_lines(lines: List[str]) -> List[Tuple[int, str]]:
    """Join shell line continuations; yields (first_lineno, full_line)."""
    out, i = [], 0
    while i < len(lines):
        start, buf = i, lines[i]
        while buf.rstrip().endswith("\\") and i + 1 < len(lines):
            buf = buf.rstrip()[:-1] + " " + lines[i + 1]
            i += 1
        out.append((start + 1, buf))
        i += 1
    return out


def check_file(path: str, help_cache: Dict[str, Optional[str]]) -> List[str]:
    """All failures in one markdown file, as 'path:line: reason' strings."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    lines = text.splitlines()
    failures: List[str] = []

    for lineno, line in enumerate(lines, 1):
        for ref in _REF_RE.findall(line):
            reason = resolve_symbol(ref)
            if reason:
                failures.append(f"{path}:{lineno}: `{ref}` does not "
                                f"resolve — {reason}")

    def check_flags(module: str, flags: List[str], lineno: int) -> None:
        help_text = _module_help(module, help_cache)
        if help_text is None:
            failures.append(f"{path}:{lineno}: documented module "
                            f"{module} has no --help")
            return
        for flag in flags:
            if flag not in help_text:
                failures.append(f"{path}:{lineno}: {module} --help does "
                                f"not mention documented flag {flag}")

    for lineno, line in _continued_lines(lines):
        for m in _CMD_RE.finditer(line):
            flags = _FLAG_RE.findall(line[m.end():])
            if flags:
                check_flags(m.group(1), flags, lineno)

    for i, line in enumerate(lines):
        m = _DIRECTIVE_RE.search(line)
        if not m:
            continue
        modules = m.group(1).split()
        # the table: contiguous block of |-rows after the directive
        flags: List[str] = []
        for row in lines[i + 1:]:
            if row.strip() and not row.lstrip().startswith("|"):
                break
            flags.extend(_FLAG_RE.findall(row))
        for module in modules:
            check_flags(module, sorted(set(flags)), i + 1)
    return failures


def default_files() -> List[str]:
    files = [os.path.join(_ROOT, "README.md"),
             os.path.join(_ROOT, "DESIGN.md")]
    files += sorted(glob.glob(os.path.join(_ROOT, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def main(argv: Optional[List[str]] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    files = args or default_files()
    help_cache: Dict[str, Optional[str]] = {}
    failures: List[str] = []
    for path in files:
        fails = check_file(path, help_cache)
        rel = os.path.relpath(path, _ROOT)
        print(f"[{'FAIL' if fails else 'ok':4s}] {rel}")
        failures.extend(fails)
    if failures:
        print(f"\n{len(failures)} stale doc reference(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nall doc references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
