"""Cross-PR benchmark diff: compare the flat metric files
``BENCH_<n>.json`` that ``benchmarks/serve_bench.py --emit-bench`` writes
at the repo root.

Each file carries a ``metrics`` dict of plain numbers keyed
``<run>.<metric>`` (plus top-level ratios). With no arguments the tool
first prints the full bench TRAJECTORY — one row per ``bench_id``, its
``meta.mode`` and the headline metrics (gated ratios first, then
throughput) — then diffs the two most recent files key by key: the
current PR's against the previous PR's. It is informational by design:
CI runs it on every push, and the FIRST PR to emit a bench file has
nothing to diff against, so a missing counterpart exits 0 with a note
instead of failing the build.

    python tools/diff_bench.py [old.json new.json]
"""

from __future__ import annotations

import glob
import json
import os
import re
import sys
from typing import Dict, List, Optional, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_BENCH_RE = re.compile(r"BENCH_(\d+)\.json$")


def find_bench_files(root: str = _ROOT) -> List[Tuple[int, str]]:
    """All root-level bench files as (bench_id, path), oldest first."""
    out = []
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = _BENCH_RE.search(os.path.basename(path))
        if m:
            out.append((int(m.group(1)), path))
    return sorted(out)


def load_metrics(path: str) -> Dict[str, float]:
    with open(path, encoding="utf-8") as f:
        payload = json.load(f)
    metrics = payload.get("metrics", {})
    return {k: v for k, v in metrics.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)}


#: Headline pick order for the trajectory table: each bench's GATED
#: metric is a ratio/scaling/speedup/agreement top-level key; throughput
#: and latency keys fill the remaining columns.
_HEADLINE_PATTERNS = (
    re.compile(r"^(?!.*\.)(.*ratio.*|.*scaling.*|.*speedup.*|"
               r".*agreement.*|.*acceptance.*|.*win.*)$"),
    re.compile(r"\.(decode_)?tok_per_s$"),
    re.compile(r"\.(ttft_emit_p95|inter_token_p99_ms|e2e_steps_p95)$"),
)


def headline_metrics(metrics: Dict[str, float],
                     limit: int = 3) -> List[Tuple[str, float]]:
    """Up to ``limit`` headline (key, value) pairs, gated ratios first."""
    picked: List[Tuple[str, float]] = []
    for pat in _HEADLINE_PATTERNS:
        for key in sorted(metrics):
            if len(picked) >= limit:
                return picked
            if pat.search(key) and all(k != key for k, _ in picked):
                picked.append((key, metrics[key]))
    return picked


def trajectory(found: List[Tuple[int, str]]) -> List[str]:
    """One line per bench file: id, meta mode, headline metrics."""
    lines = [f"bench trajectory ({len(found)} files):"]
    for bench_id, path in found:
        try:
            with open(path, encoding="utf-8") as f:
                payload = json.load(f)
        except (OSError, ValueError) as exc:
            lines.append(f"  BENCH_{bench_id:<3d} <unreadable: {exc}>")
            continue
        mode = payload.get("meta", {}).get("mode", "?")
        picks = headline_metrics(load_metrics(path))
        shown = "  ".join(f"{k}={v:.4g}" for k, v in picks) or "(no metrics)"
        lines.append(f"  BENCH_{bench_id:<3d} {mode:<16s} {shown}")
    return lines


def diff(old: Dict[str, float], new: Dict[str, float]) -> List[str]:
    lines = []
    for key in sorted(set(old) | set(new)):
        if key not in old:
            lines.append(f"  + {key:44s} {new[key]:>12.4g}  (new metric)")
        elif key not in new:
            lines.append(f"  - {key:44s} {old[key]:>12.4g}  (dropped)")
        else:
            o, n = old[key], new[key]
            rel = f"{(n - o) / o:+.1%}" if o else "   n/a"
            lines.append(f"    {key:44s} {o:>12.4g} -> {n:>12.4g}  {rel}")
    return lines


def main(argv: Optional[List[str]] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    if len(args) == 2:
        old_path, new_path = args
        if not os.path.exists(old_path):
            print(f"diff_bench: no previous bench file at {old_path} — "
                  "nothing to diff (first bench of this sequence)")
            return 0
    elif args:
        print(__doc__)
        return 2
    else:
        found = find_bench_files()
        if not found:
            print("diff_bench: no BENCH_*.json at the repo root — "
                  "nothing to diff")
            return 0
        for line in trajectory(found):
            print(line)
        if len(found) == 1:
            bench_id, path = found[0]
            print(f"diff_bench: only BENCH_{bench_id}.json exists — "
                  "nothing to diff against (first bench of this sequence)")
            return 0
        (_, old_path), (_, new_path) = found[-2], found[-1]
    old, new = load_metrics(old_path), load_metrics(new_path)
    print(f"bench diff: {os.path.basename(old_path)} -> "
          f"{os.path.basename(new_path)} ({len(new)} metrics)")
    for line in diff(old, new):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
