"""The paper's own experiment (§VI), end to end:

1. reproduce the SPM-capacity sweep — tile sizes, cycle counts, Fig. 6/7/8/9
   numbers — from the calibrated models;
2. actually RUN the capacity-aware tiled matmul kernel (Pallas, interpret
   mode on CPU) at each planned tile size, verifying numerics against the
   oracle — the "memory phase / compute phase" structure executing for real;
3. print the TPU-v5e translation: what the same capacity sweep means for
   VMEM-planned block sizes and HBM traffic (the hardware-adaptation story).

    PYTHONPATH=src python examples/mempool_matmul.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, perf_model, tiling
from repro.core.hw_profiles import MiB
from repro.core.target import get_target
from repro.kernels import ops, ref


def section(title):
    print(f"\n{'=' * 64}\n{title}\n{'=' * 64}")


def main() -> int:
    section("1. The paper's capacity sweep (calibrated reproduction)")
    print(f"{'SPM':>6} {'tile t':>7} {'loads/elem':>11} "
          f"{'cycles @16B/c':>14} {'perf 2D':>8} {'perf 3D':>8} "
          f"{'eff 3D':>7} {'EDP 3D':>7}")
    for mib in (1, 2, 4, 8):
        # the registered 3D target's cluster-SPM capacity drives the t-rule
        target = get_target(f"mempool-3d-{mib}mib")
        t = tiling.mempool_tile_size(target.scratchpad_bytes)
        cyc = perf_model.matmul_cycles(spm_bytes=mib * MiB,
                                       bw_bytes_per_cycle=16).total
        d2, d3 = energy.derive("2D", mib), energy.derive("3D", mib)
        print(f"{mib:>4}Mi {t:>7} {perf_model.PAPER_M // t:>11} "
              f"{cyc:>14.3e} {d2.performance:>8.3f} {d3.performance:>8.3f} "
              f"{d3.efficiency:>7.3f} {d3.edp:>7.3f}")
    print("\npaper checkpoints: t=256/384/544/800; 3D@4MiB perf +9.1% vs 2D;"
          "\n3D@1MiB best EDP (-15.6%); speedups 43%/16%/8% at 4/16/64 B/c:")
    for bw in (4, 16, 64):
        s = perf_model.speedup_vs_baseline(8 * MiB, bw)
        print(f"  8MiB vs 1MiB @ {bw:>2} B/cyc: {(s - 1) * 100:+.1f}%")

    section("2. The kernel itself (Pallas interpret mode, scaled-down M)")
    # The paper's M=326400 is too big for CPU; run a proportional M with the
    # real planned tile structure: M = 4 tiles of the 1 MiB tile edge.
    m = 512
    a = jax.random.normal(jax.random.PRNGKey(0), (m, m), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (m, m), jnp.float32)
    want = ref.matmul_ref(a, b)
    scaled = {1: 64, 2: 128, 4: 256, 8: 512}   # CPU-sized stand-ins, 1:8 span
    for mib in (1, 2, 4, 8):
        t_full = tiling.mempool_tile_size(mib * MiB)
        t = scaled[mib]
        plan = tiling.MatmulPlan(bm=t, bk=t, bn=t)
        got = ops.matmul(a, b, plan=plan, impl="pallas")
        err = float(jnp.abs(got - want).max())
        traffic = tiling.offchip_traffic_bytes(m, plan.bm)
        print(f"  SPM {mib} MiB -> paper tile {t_full}, run blocks "
              f"({plan.bm},{plan.bk},{plan.bn}): max|err|={err:.2e}, "
              f"off-chip traffic {traffic / 2**20:.1f} MiB "
              f"({m // t} loads/element)")
        assert err < 1e-3

    section("3. The TPU translation (same law, VMEM instead of SPM)")
    print(f"{'VMEM budget':>12} {'blocks (bm,bk,bn)':>20} "
          f"{'HBM traffic':>12} {'arith.int.':>10}")
    m3 = 8192
    tpu = get_target("tpu-v5e")
    for frac in (0.125, 0.25, 0.5, 0.75):
        plan = tiling.plan_matmul(m3, m3, m3,
                                  partition=tpu.partition(fraction=frac))
        tr = plan.hbm_traffic_bytes(m3, m3, m3)
        ai = plan.arithmetic_intensity(m3, m3, m3)
        print(f"{frac * 128:>9.0f}Mi {str((plan.bm, plan.bk, plan.bn)):>20} "
              f"{tr / 2**30:>9.2f}Gi {ai:>10.0f}")
    print("\nbigger scratchpad -> bigger tiles -> less off-chip traffic:"
          "\nthe paper's insight, verbatim, on the TPU memory hierarchy.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
