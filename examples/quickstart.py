"""Quickstart: train a ~10M-param decoder LM for 300 steps on the synthetic
Markov pipeline, with checkpointing — the end-to-end driver in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]

The same public API scales to the assigned production configs: swap
`ModelConfig(...)` for `repro.configs.get_config("yi-6b")` and run under a
real mesh (see src/repro/launch/train.py).
"""

import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import TrainConfig, make_train_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cfg = ModelConfig(                      # ~10M params
        name="quickstart-10m", family="dense",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=1024, vocab_size=2048)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    tcfg = TrainConfig(opt=opt_mod.OptConfig(
        peak_lr=1e-3, warmup_steps=20, decay_steps=args.steps))
    state = opt_mod.init_opt_state(params, tcfg.opt)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    data = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=256, global_batch=8, seed=0))

    ckpt_dir = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep=2)

    it = data.iterator(depth=2)
    t0 = time.time()
    for step in range(args.steps):
        batch = jax.tree.map(jnp.asarray, next(it))
        params, state, metrics = step_fn(params, state, batch)
        if step % 25 == 0 or step == args.steps - 1:
            tput = 8 * 256 * (step + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {float(metrics['total_loss']):.4f}"
                  f"  ({tput:.0f} tok/s)", flush=True)
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, {"params": params, "opt": state})
    mgr.wait()
    print(f"checkpoints: {mgr.all_steps()} in {ckpt_dir}")
    final = float(metrics["total_loss"])
    uniform = float(jnp.log(jnp.asarray(float(cfg.vocab_size))))
    print(f"final loss {final:.3f} (uniform would be {uniform:.2f}; "
          f"markov optimum ~{jnp.log(4.0):.2f})")
    return 0 if final < 0.8 * uniform else 1


if __name__ == "__main__":
    sys.exit(main())
