"""Fault-tolerance drill: train, crash (injected), restart from the atomic
checkpoint on a DIFFERENT mesh shape, and verify the loss trajectory
continues — the elastic-restart contract at example scale.

    PYTHONPATH=src python examples/elastic_restart.py

On a real cluster the same flow is driven by launch/train.py --fail-at /
--resume with the RestartPolicy deciding restart-vs-reslice.
"""

import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import RestartPolicy, elastic_mesh_shape
from repro.train.loop import TrainConfig, make_train_step

CFG = ModelConfig(name="elastic-demo", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                  d_ff=128, vocab_size=256)


def run_segment(mesh, params, state, data, start, stop, step_fn):
    losses = []
    for i in range(start, stop):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        params, state, m = step_fn(params, state, batch)
        losses.append(float(m["total_loss"]))
    return params, state, losses


def main() -> int:
    model = build_model(CFG)
    tcfg = TrainConfig(opt=opt_mod.OptConfig(peak_lr=3e-3, warmup_steps=5,
                                             decay_steps=100,
                                             weight_decay=0.0))
    data = SyntheticPipeline(DataConfig(vocab_size=CFG.vocab_size, seq_len=64,
                                        global_batch=8, seed=7, branching=2))
    ckpt = tempfile.mkdtemp(prefix="elastic_ckpt_")
    mgr = CheckpointManager(ckpt, keep=2)

    # ---- phase 1: "16 hosts" (here: 1x1 mesh stands in) -------------------
    mesh_a = make_host_mesh(1, 1)
    with shd.use_mesh(mesh_a):
        params = model.init(jax.random.PRNGKey(0))
        state = opt_mod.init_opt_state(params, tcfg.opt)
        step_fn = jax.jit(make_train_step(model, tcfg))
        params, state, l1 = run_segment(mesh_a, params, state, data, 0, 30,
                                        step_fn)
        mgr.save(30, {"params": params, "opt": state}, blocking=True)
    print(f"phase 1 (mesh {dict(mesh_a.shape)}): loss "
          f"{l1[0]:.3f} -> {l1[-1]:.3f}; checkpoint @30 saved")
    print("=== simulated hard failure: 1 of 16 hosts lost ===")

    # ---- recovery decision -------------------------------------------------
    policy = RestartPolicy()
    action, backoff = policy.next_action(0, dead_hosts=[5], n_hosts=16)
    new_shape = elastic_mesh_shape(n_devices=240, model_parallel=16)
    print(f"RestartPolicy -> {action} (backoff {backoff:.0f}s); "
          f"elastic mesh for 240 surviving chips: {new_shape}")

    # ---- phase 2: restart on the new mesh ---------------------------------
    mesh_b = make_host_mesh(1, 1)   # stands in for the re-sliced (15,16)
    with shd.use_mesh(mesh_b):
        tmpl = jax.eval_shape(
            lambda: {"params": model.init(jax.random.PRNGKey(0)),
                     "opt": opt_mod.init_opt_state(
                         jax.eval_shape(lambda: model.init(
                             jax.random.PRNGKey(0))), tcfg.opt)})
        step0, restored = mgr.restore(tmpl)
        params, state = restored["params"], restored["opt"]
        params = jax.device_put(params, shd.named_shardings(params, mesh_b))
        step_fn = jax.jit(make_train_step(model, tcfg))
        params, state, l2 = run_segment(mesh_b, params, state, data, step0,
                                        step0 + 30, step_fn)
    print(f"phase 2 (restored @ step {step0}, new mesh): loss "
          f"{l2[0]:.3f} -> {l2[-1]:.3f}")
    ok = l2[0] < l1[0] and l2[-1] <= l2[0] + 0.05
    print("continuity check:", "OK — trajectory resumed, no loss spike"
          if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
