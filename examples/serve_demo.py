"""Serving demo: the serving surfaces of the Engine over the pooled KV
cache — one-shot batched decode across three architecture families (dense
GQA, MLA+MoE, pure SSM), continuous batching over the dense slot pool,
the paged two-tier pool (same stream, same layer-0 bytes, more concurrent
slots, preempt-and-spill to the stacked layer-1 tier), and ref-counted
prefix sharing over a shared-system-prompt stream. Walkthrough:
docs/SERVING.md.

    PYTHONPATH=src python examples/serve_demo.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve.engine import Engine, EngineConfig
from repro.serve.scheduler import (Scheduler, derive_n_slots,
                                   derive_page_geometry, kv_bytes_per_token,
                                   shared_prefix_stream, synthetic_stream)


def demo(arch: str, prompt_len: int = 16, gen: int = 8) -> None:
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params,
                    EngineConfig(max_len=prompt_len + gen + cfg.frontend_len))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, prompt_len),
                                          2, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["src_embeds"] = (jax.random.normal(
            jax.random.PRNGKey(2), (2, prompt_len, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
    t0 = time.time()
    out, state = engine.generate(batch, n_steps=gen)
    dt = time.time() - t0

    # cache footprint: the pooled-memory story per family
    n_cache = sum(int(x.size) * x.dtype.itemsize
                  for x in jax.tree.leaves(state)) / 2**20
    print(f"{arch:24s} [{cfg.family:6s}] generated {out.shape[1]} tok/row "
          f"in {dt*1e3:6.0f} ms | decode state {n_cache:7.2f} MiB | "
          f"tokens[0]={out[0].tolist()}")


def demo_continuous(arch: str = "qwen2.5-3b", n_requests: int = 12,
                    n_slots: int = 3) -> None:
    """A request stream through the slot pool: admission at drain
    boundaries, per-slot cache_len vectors, slot reuse after EOS/budget."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, EngineConfig(max_len=32, sync_interval=4))
    sched = Scheduler(n_slots=n_slots)
    for spec in synthetic_stream(n_requests, prompt_len=12, gen_len=8,
                                 vocab=cfg.vocab_size):
        sched.submit(spec["prompt"], spec["max_new_tokens"])
    t0 = time.time()
    report = engine.serve(scheduler=sched)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in report.requests)
    s = report.stats
    print(f"\ncontinuous batching [{arch}]: {s['drained']}/{n_requests} "
          f"requests, {n_tok} tokens in {dt*1e3:.0f} ms "
          f"({n_tok/dt:.0f} tok/s)")
    print(f"  slots={s['n_slots']} allocations={s['slot_allocations']} "
          f"(max reuse {s['max_slot_reuse']}) | "
          f"{s['host_syncs']} host syncs / {s['decode_steps']} decode steps")


def demo_paged(arch: str = "qwen2.5-3b", n_requests: int = 12,
               dense_slots: int = 3) -> None:
    """The paper's two-layer partition at the serving layer: inside the
    dense pool's layer-0 byte budget, the paged pool carries more
    concurrent slots; under pressure the youngest resident spills to the
    stacked layer-1 tier and is restored bit-exactly."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 32
    engine = Engine(model, params, EngineConfig(max_len=max_len,
                                                sync_interval=4))
    budget = dense_slots * kv_bytes_per_token(cfg) * max_len
    geom = derive_page_geometry(cfg, max_len, page_tokens=8, max_slots=16,
                                layer0_bytes=budget)
    sched = Scheduler(n_slots=derive_n_slots(cfg, max_len, pages=geom,
                                             max_slots=16), pages=geom)
    for spec in synthetic_stream(n_requests, prompt_len=12, gen_len=8,
                                 vocab=cfg.vocab_size, seed=1):
        sched.submit(spec["prompt"], spec["max_new_tokens"])
    t0 = time.time()
    report = engine.serve(scheduler=sched)
    dt = time.time() - t0
    s = report.stats
    n_tok = sum(len(r.tokens) for r in report.requests)
    print(f"\npaged two-tier pool       {arch}: {s['drained']}/{n_requests} "
          f"requests, {n_tok} tokens in {dt*1e3:.0f} ms ({n_tok/dt:.0f} tok/s)")
    print(f"  {s['n_slots']} slots vs {dense_slots} dense in the same "
          f"{s['pool_bytes']} layer-0 bytes | pages hw "
          f"{s['pages_high_water']}/{s['n_pages']} | {s['preemptions']} "
          f"preemptions -> {s['spilled_pages']} pages spilled, "
          f"{s['restores']} restores")


def demo_prefix_share(arch: str = "qwen2.5-3b", n_requests: int = 12) -> None:
    """Ref-counted prefix sharing over the paged pool: every request
    carries the same system prompt; with sharing on, admissions map the
    cached prefix pages read-only and prefill only the unique tail —
    same budget, more resident requests, identical outputs."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 40
    engine = Engine(model, params, EngineConfig(max_len=max_len,
                                                sync_interval=4))
    geom = derive_page_geometry(
        cfg, max_len, page_tokens=8, max_slots=16,
        layer0_bytes=16 * kv_bytes_per_token(cfg) * 8)
    stream = shared_prefix_stream(n_requests, system_len=16, suffix_len=8,
                                  gen_len=8, vocab=cfg.vocab_size)
    outs, stats = {}, {}
    for share in (False, True):
        sched = Scheduler(n_slots=derive_n_slots(cfg, max_len, pages=geom,
                                                 max_slots=16),
                          pages=geom, prefix_share=share)
        for spec in stream:
            sched.submit(spec["prompt"], spec["max_new_tokens"])
        report = engine.serve(scheduler=sched)
        outs[share] = {r.rid: r.tokens for r in report.requests}
        stats[share] = report.stats
    s = stats[True]
    print(f"\nprefix sharing            {arch}: {s['prefix_hits']} hits / "
          f"{s['prefix_misses']} misses, {s['shared_prefix_tokens']} prompt "
          f"tokens served from cache, {s['cow_copies']} COW copies")
    print(f"  residency {s['mapped_high_water']} mapped vs "
          f"{s['pages_high_water']} physical pages "
          f"({s['mapped_high_water'] / max(s['pages_high_water'], 1):.2f}x) "
          f"| outputs sharing on == off: {outs[True] == outs[False]}")


def main() -> int:
    print("family-spanning serving demo (reduced configs, CPU):")
    for arch in ("yi-6b", "deepseek-v2-236b", "falcon-mamba-7b",
                 "seamless-m4t-medium"):
        demo(arch)
    print("\nnote the SSM row: its decode state is O(1) in sequence length —"
          "\nwhy falcon-mamba/jamba run the long_500k cell (DESIGN.md §Shape-cell skip rules).")
    demo_continuous()
    demo_paged()
    demo_prefix_share()
    return 0


if __name__ == "__main__":
    sys.exit(main())
