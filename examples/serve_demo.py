"""Serving demo: prefill + batched greedy decode on three architecture
families (dense GQA, MLA+MoE, pure SSM) through the same Engine API —
including the O(1)-state long-context property of the SSM family.

    PYTHONPATH=src python examples/serve_demo.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve.engine import Engine, EngineConfig


def demo(arch: str, prompt_len: int = 16, gen: int = 8) -> None:
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params,
                    EngineConfig(max_len=prompt_len + gen + cfg.frontend_len))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, prompt_len),
                                          2, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["src_embeds"] = (jax.random.normal(
            jax.random.PRNGKey(2), (2, prompt_len, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
    t0 = time.time()
    out, state = engine.generate(batch, n_steps=gen)
    dt = time.time() - t0

    # cache footprint: the pooled-memory story per family
    n_cache = sum(int(x.size) * x.dtype.itemsize
                  for x in jax.tree.leaves(state)) / 2**20
    print(f"{arch:24s} [{cfg.family:6s}] generated {out.shape[1]} tok/row "
          f"in {dt*1e3:6.0f} ms | decode state {n_cache:7.2f} MiB | "
          f"tokens[0]={out[0].tolist()}")


def main() -> int:
    print("family-spanning serving demo (reduced configs, CPU):")
    for arch in ("yi-6b", "deepseek-v2-236b", "falcon-mamba-7b",
                 "seamless-m4t-medium"):
        demo(arch)
    print("\nnote the SSM row: its decode state is O(1) in sequence length —"
          "\nwhy falcon-mamba/jamba run the long_500k cell (DESIGN.md §Shape-cell skip rules).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
