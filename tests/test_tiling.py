"""Capacity-aware planner invariants (unit + hypothesis property tests)."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import tiling
from repro.core.hw_profiles import MiB, TPU_V5E, TpuProfile


def test_plan_matmul_respects_budget_and_alignment():
    plan = tiling.plan_matmul(4096, 4096, 4096)
    assert plan.bm % 128 == 0 and plan.bk % 128 == 0 and plan.bn % 128 == 0
    assert plan.vmem_bytes() <= TPU_V5E.vmem_bytes * 0.75


def test_plan_matmul_grows_with_capacity():
    """The paper's law: more scratchpad => bigger tiles => fewer reloads."""
    small = TpuProfile(name="small", peak_flops_bf16=1, hbm_bw=1, hbm_bytes=1,
                       ici_link_bw=1, ici_links=1, vmem_bytes=8 * MiB)
    big = TpuProfile(name="big", peak_flops_bf16=1, hbm_bw=1, hbm_bytes=1,
                     ici_link_bw=1, ici_links=1, vmem_bytes=128 * MiB)
    p_small = tiling.plan_matmul(8192, 8192, 8192, profile=small)
    p_big = tiling.plan_matmul(8192, 8192, 8192, profile=big)
    assert p_big.bm * p_big.bn > p_small.bm * p_small.bn
    t_small = p_small.hbm_traffic_bytes(8192, 8192, 8192)
    t_big = p_big.hbm_traffic_bytes(8192, 8192, 8192)
    assert t_big < t_small


def test_matmul_arithmetic_intensity_increases_with_blocks():
    lo = tiling.MatmulPlan(128, 128, 128)
    hi = tiling.MatmulPlan(512, 128, 512)
    m = k = n = 8192
    assert hi.arithmetic_intensity(m, k, n) > lo.arithmetic_intensity(m, k, n)


@hypothesis.given(
    m=st.integers(1, 65536), k=st.integers(1, 65536), n=st.integers(1, 65536),
    vmem_mib=st.sampled_from([16, 32, 64, 128]))
@hypothesis.settings(max_examples=80, deadline=None)
def test_plan_matmul_properties(m, k, n, vmem_mib):
    """For ANY problem: blocks are 128-aligned, fit the budget, and never
    exceed the (aligned-up) problem dims."""
    prof = TpuProfile(name="p", peak_flops_bf16=1, hbm_bw=1, hbm_bytes=1,
                      ici_link_bw=1, ici_links=1,
                      vmem_bytes=vmem_mib * MiB)
    plan = tiling.plan_matmul(m, k, n, profile=prof)
    assert plan.bm % 128 == 0 and plan.bk % 128 == 0 and plan.bn % 128 == 0
    assert plan.vmem_bytes() <= prof.vmem_bytes * 0.75
    assert plan.bm <= max(128, -(-m // 128) * 128)
    assert plan.bn <= max(128, -(-n // 128) * 128)
    assert plan.bk <= max(128, -(-k // 128) * 128)


@hypothesis.given(sq=st.integers(1, 1 << 20), skv=st.integers(1, 1 << 20),
                  hd=st.sampled_from([64, 128, 192, 256]))
@hypothesis.settings(max_examples=80, deadline=None)
def test_plan_attention_properties(sq, skv, hd):
    plan = tiling.plan_attention(sq, skv, hd)
    assert plan.block_q >= 128 and plan.block_kv >= 128
    assert plan.vmem_bytes(hd) <= TPU_V5E.vmem_bytes * 0.5
    assert plan.block_q <= 2048 and plan.block_kv <= 2048


@hypothesis.given(seq=st.integers(8, 1 << 20),
                  di=st.sampled_from([1024, 4096, 8192, 16384]),
                  ds=st.sampled_from([8, 16, 32]))
@hypothesis.settings(max_examples=60, deadline=None)
def test_plan_scan_chunk_properties(seq, di, ds):
    plan = tiling.plan_scan_chunk(seq, di, ds)
    assert plan.chunk >= 8
    assert plan.vmem_bytes(di, ds) <= TPU_V5E.vmem_bytes * 0.5


@hypothesis.given(spm_kib=st.integers(64, 64 * 1024))
@hypothesis.settings(max_examples=60, deadline=None)
def test_mempool_tile_monotone_in_capacity(spm_kib):
    """Tile size is monotone nondecreasing in SPM bytes & always fits."""
    t = tiling.mempool_tile_size(spm_kib * 1024)
    t2 = tiling.mempool_tile_size(spm_kib * 2 * 1024)
    assert t2 >= t
    assert tiling.MEMPOOL_RESIDENT_TILES * 4 * t * t <= spm_kib * 1024
    assert t % tiling.MEMPOOL_TILE_ALIGN == 0


def test_offchip_traffic_decreases_with_tile():
    m = 326400
    tr = [tiling.offchip_traffic_bytes(m, t) for t in (256, 384, 544, 800)]
    assert tr == sorted(tr, reverse=True)
