"""AdamW correctness vs a NumPy reference + int8-moment quantization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as opt


def _numpy_adamw(params, grads, m, v, step, cfg: opt.OptConfig):
    lr = float(opt.lr_schedule(cfg, jnp.asarray(step)))
    gn = np.sqrt(sum((g.astype(np.float64) ** 2).sum() for g in grads.values()))
    scale = min(1.0, cfg.clip_norm / max(gn, 1e-9))
    out_p, out_m, out_v = {}, {}, {}
    bc1 = 1 - cfg.b1 ** step
    bc2 = 1 - cfg.b2 ** step
    for k in params:
        g = grads[k] * scale
        out_m[k] = cfg.b1 * m[k] + (1 - cfg.b1) * g
        out_v[k] = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
        upd = (out_m[k] / bc1) / (np.sqrt(out_v[k] / bc2) + cfg.eps)
        wd = cfg.weight_decay * params[k] if params[k].ndim >= 2 else 0.0
        out_p[k] = params[k] - lr * (upd + wd)
    return out_p, out_m, out_v


def test_adamw_matches_numpy_reference():
    cfg = opt.OptConfig(warmup_steps=0, decay_steps=100)
    rng = np.random.default_rng(0)
    params = {"w": rng.standard_normal((8, 8)).astype(np.float32),
              "b": rng.standard_normal((8,)).astype(np.float32)}
    grads = {k: rng.standard_normal(p.shape).astype(np.float32)
             for k, p in params.items()}
    jp = jax.tree.map(jnp.asarray, params)
    jg = jax.tree.map(jnp.asarray, grads)
    state = opt.init_opt_state(jp, cfg)
    m0 = {k: np.zeros_like(p) for k, p in params.items()}
    v0 = {k: np.zeros_like(p) for k, p in params.items()}

    p_np, m_np, v_np = params, m0, v0
    p_jx = jp
    for step in range(1, 4):
        p_jx, state, _ = opt.adamw_update(p_jx, jg, state, cfg)
        p_np, m_np, v_np = _numpy_adamw(p_np, jg, m_np, v_np, step, cfg)
    for k in params:
        np.testing.assert_allclose(np.asarray(p_jx[k]), p_np[k],
                                   rtol=1e-5, atol=1e-6)


def test_lr_schedule_shape():
    cfg = opt.OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                        min_lr_ratio=0.1)
    lrs = [float(opt.lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=1e-3)
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-2)      # min ratio floor
    # warmup monotone up, decay monotone down
    assert all(a <= b + 1e-12 for a, b in zip(lrs[:2], lrs[1:3]))


def test_grad_clipping_caps_update():
    cfg = opt.OptConfig(warmup_steps=0, clip_norm=1.0)
    params = {"w": jnp.zeros((4, 4))}
    huge = {"w": jnp.full((4, 4), 1e6)}
    state = opt.init_opt_state(params, cfg)
    _, _, metrics = opt.adamw_update(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


# ------------------------------------------------------------- quantization

def test_quantize_roundtrip_error_bound():
    """Blockwise int8: |x - deq(q(x))| <= blockwise absmax / 127 / 2 + eps."""
    rng = np.random.default_rng(1)
    for shape in [(7,), (3, 300), (2, 2, 513)]:
        x = jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 10)
        q, s = opt._quantize(x)
        deq = opt._dequantize(q, s, x.shape)
        err = np.abs(np.asarray(deq - x))
        bound = np.asarray(jnp.repeat(s, opt.QBLOCK, axis=-1)
                           [..., :shape[-1]]) * 0.5 + 1e-7
        assert (err <= bound + 1e-6).all()
        assert q.dtype == jnp.int8


def test_quantized_moments_track_fp32():
    """Training with int8 moments stays close to fp32 moments (loss-neutral
    memory trick — DESIGN.md distributed-optimization section)."""
    def loss_fn(p, x, y):
        pred = x @ p["w"]
        return jnp.mean((pred - y) ** 2)

    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((64, 16)).astype(np.float32))
    w_true = jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32))
    y = x @ w_true

    results = {}
    for quant in (False, True):
        cfg = opt.OptConfig(peak_lr=3e-2, warmup_steps=0, decay_steps=300,
                            weight_decay=0.0, quantized_moments=quant)
        params = {"w": jnp.zeros((16, 4))}
        state = opt.init_opt_state(params, cfg)
        g_fn = jax.jit(jax.grad(loss_fn))
        upd = jax.jit(lambda p, g, s, c=cfg: opt.adamw_update(p, g, s, c))
        for _ in range(150):
            g = g_fn(params, x, y)
            params, state, _ = upd(params, g, state)
        results[quant] = float(loss_fn(params, x, y))
    assert results[True] < 0.01 * float(jnp.mean(y ** 2))  # actually converged
    assert results[True] == pytest.approx(results[False], rel=1.0, abs=0.02)


def test_quantized_state_memory_is_quarter():
    params = {"w": jnp.zeros((1024, 1024))}
    s_fp = opt.init_opt_state(params, opt.OptConfig(quantized_moments=False))
    s_q = opt.init_opt_state(params, opt.OptConfig(quantized_moments=True))

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    assert nbytes(s_q) < 0.27 * nbytes(s_fp)


def test_quantized_moments_preserve_param_shape():
    """The int8 payload keeps the parameter's own shape (sharding contract)."""
    params = {"w": jnp.zeros((64, 640))}
    state = opt.init_opt_state(params, opt.OptConfig(quantized_moments=True))
    assert state["m"]["w"].q.shape == (64, 640)
    assert state["m"]["w"].scale.shape == (64, -(-640 // opt.QBLOCK))
