"""Dry-run machinery unit tests: HLO collective parsing, pod-crossing
classification, traffic corrections, extrapolation — no 512-device compile
here (that's launch/dryrun.py's job, results checked via artifacts)."""

import pytest

from repro.launch import dryrun


# ------------------------------------------------------- HLO shape parsing

def test_shape_bytes():
    assert dryrun._shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert dryrun._shape_bytes("bf16[8,128]{1,0}, bf16[8,128]{1,0}") \
        == 2 * 8 * 128 * 2
    assert dryrun._shape_bytes("s32[16]") == 64
    assert dryrun._shape_bytes("pred[]") == 1          # scalar: one element


def test_collective_regex_matches_kinds():
    hlo = """
  ag = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %p), replica_groups={{0,1,2,3}}, dimensions={0}
  ar.1 = f32[256]{0} all-reduce(f32[256]{0} %x), replica_groups=[4,2]<=[8]
  rs = f32[32]{0} reduce-scatter(f32[256]{0} %y), replica_groups={{0,1}}
  a2a = bf16[16,16]{1,0} all-to-all(bf16[16,16]{1,0} %z), replica_groups={{0,1,2,3}}
  cp = u32[8]{0} collective-permute(u32[8]{0} %w), source_target_pairs={{0,1}}
"""
    rec = dryrun.collect_collectives(hlo, multi_pod=False)
    assert rec["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "reduce-scatter": 1, "all-to-all": 1,
                             "collective-permute": 1}
    assert rec["intra_bytes"] > 0 and rec["cross_pod_bytes"] == 0.0


def test_all_reduce_wire_factor():
    """Ring all-reduce moves ~2x the payload (reduce-scatter + all-gather)."""
    one_ar = "x = f32[128]{0} all-reduce(f32[128]{0} %a), replica_groups={{0,1}}"
    one_ag = "x = f32[128]{0} all-gather(f32[128]{0} %a), replica_groups={{0,1}}"
    ar = dryrun.collect_collectives(one_ar, False)["intra_bytes"]
    ag = dryrun.collect_collectives(one_ag, False)["intra_bytes"]
    assert ar == pytest.approx(2 * ag)


# ------------------------------------------------------ pod-crossing rules

def test_crosses_pod_explicit_groups():
    line = "x = f32[8]{0} all-reduce(f32[8]{0} %a), replica_groups={{0,256}}"
    assert dryrun._crosses_pod(line)
    line = "x = f32[8]{0} all-reduce(f32[8]{0} %a), replica_groups={{0,1,2,3}}"
    assert not dryrun._crosses_pod(line)


def test_crosses_pod_iota_groups():
    # 32 groups of 16 walking the minor dim of [2,16,16]: spans devices
    # 0..15 -> intra-pod
    line = "x = f32[8]{0} all-gather(f32[8]{0} %a), replica_groups=[32,16]<=[512]"
    assert not dryrun._crosses_pod(line)
    # 2-element groups with stride 256 (pod partners) -> crosses
    line = ("x = f32[8]{0} all-reduce(f32[8]{0} %a), "
            "replica_groups=[256,2]<=[2,256]T(1,0)")
    assert dryrun._crosses_pod(line)


# ------------------------------------------------- depth extrapolation

def test_scaled_cfg_linear_extrapolation():
    """Q(k) affine in body repetitions => extrapolation from k=1,2 is exact
    on a synthetic affine quantity."""
    from repro.configs import get_config
    cfg = get_config("yi-6b")
    cfg1, reps = dryrun._scaled_cfg(cfg, 1)
    cfg2, reps2 = dryrun._scaled_cfg(cfg, 2)
    assert reps == reps2
    body_layers1 = cfg1.n_layers
    body_layers2 = cfg2.n_layers
    # extrapolating the layer count itself must recover the real depth
    full = body_layers1 + (body_layers2 - body_layers1) * (reps - 1)
    assert full == cfg.n_layers


def test_scaled_cfg_respects_head_tail():
    """Head/tail layers (deepseek's leading dense layer) stay in every scaled
    config, so the k=1 -> k=2 slope isolates exactly one body repetition."""
    from repro.configs import get_config
    cfg = get_config("deepseek-v2-236b")      # 1 leading dense layer
    cfg1, reps = dryrun._scaled_cfg(cfg, 1)
    cfg2, _ = dryrun._scaled_cfg(cfg, 2)
    # the dense head layer is present in both scaled configs
    assert cfg1.kind_for_layer(0).mlp == "mlp"
    assert cfg2.kind_for_layer(0).mlp == "mlp"
    assert all(cfg2.kind_for_layer(i).mlp == "moe"
               for i in range(1, cfg2.n_layers))
    full = cfg1.n_layers + (cfg2.n_layers - cfg1.n_layers) * (reps - 1)
    assert full == cfg.n_layers


def test_visible_kv_elems_causal_window():
    # causal, 4 q-blocks of 64 over 256 kv, blocks of 64: 1+2+3+4 = 10 blocks
    assert dryrun._visible_kv_elems(256, 256, 64, 64, True, None) == 10 * 64
    # window=64 keeps ~2 blocks visible per q block
    w = dryrun._visible_kv_elems(256, 256, 64, 64, True, 64)
    assert w < 10 * 64


def test_train_overrides_cover_all_archs():
    from repro.configs import ARCH_IDS
    assert set(dryrun.TRAIN_OVERRIDES) == set(ARCH_IDS)


# ------------------------------------------------------ artifact contract

def test_existing_artifacts_schema():
    """Every artifact written so far obeys the schema EXPERIMENTS.md reads."""
    import glob
    import json
    import os
    base = os.path.join(os.path.dirname(dryrun.__file__),
                        "../../../benchmarks/artifacts/dryrun")
    paths = glob.glob(os.path.join(base, "*", "*", "*.json"))
    if not paths:
        pytest.skip("no dry-run artifacts yet")
    for p in paths:
        with open(p) as f:
            rec = json.load(f)
        assert rec["status"] in ("ok", "skipped", "error"), p
        if rec["status"] == "ok":
            assert rec["memory"]["temp_size_in_bytes"] is not None
            r = rec["roofline"]
            assert r["bound"] in ("compute", "memory", "collective")
            assert r["compute_s"] > 0 and r["memory_s"] > 0
            assert 0 < r["useful_flops_ratio"] <= 1.5, (p, r["useful_flops_ratio"])
