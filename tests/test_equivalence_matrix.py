"""Cross-feature equivalence matrix (ISSUE 7 satellite): every serving
feature combination must emit bit-identical greedy tokens to the one-shot
``Engine.generate`` reference on the same model.

Axes: {dense slab, paged pool, paged+prefix-share} x {chunked prefill
off/on} x {speculate off/on} x {GQA, sliding-window, MLA} attention
families — 36 cells, every serve under the device->host transfer guard
with the one-host-sync-per-chunk invariant asserted.

The mesh axis (ISSUE 8): a 1x1 mesh engine must be bit-identical to the
no-mesh engine (same cells, same reference), and mesh=2 runs the cells in
a subprocess (forcing host-platform devices requires XLA_FLAGS before jax
imports, which conftest forbids in this process) where each mode must
match the SAME mesh engine's one-shot rollout — within a mesh size the
serving machinery moves no bits; across mesh sizes tensor-parallel
all-reduces may legitimately reassociate.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm
from repro.serve.engine import Engine, EngineConfig

MAX_LEN = 64
PT = 8

TINY = ModelConfig(
    name="tiny-eq", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)
TINY_WINDOW = dataclasses.replace(TINY, name="tiny-eq-win", n_layers=3,
                                  window=8, local_global_ratio=2)
TINY_MLA = dataclasses.replace(TINY, name="tiny-eq-mla", n_kv_heads=4,
                               use_mla=True, kv_lora_rank=16,
                               qk_nope_head_dim=16, qk_rope_head_dim=8,
                               v_head_dim=16)
CONFIGS = {c.name: c for c in (TINY, TINY_WINDOW, TINY_MLA)}


def _requests():
    """Four requests tuned so every axis has work: two share a repetitive
    16-token system prefix (prefix sharing + proposer hits), one tiles a
    motif (high speculative acceptance), one is random (rejections)."""
    rng = np.random.RandomState(11)
    system = np.tile(rng.randint(2, 128, size=4).astype(np.int32), 4)
    tails = [rng.randint(2, 128, size=n).astype(np.int32) for n in (7, 11)]
    motif = np.tile(rng.randint(2, 128, size=5).astype(np.int32), 5)[:22]
    rand = rng.randint(2, 128, size=13).astype(np.int32)
    return [(np.concatenate([system, tails[0]]), 14),
            (np.concatenate([system, tails[1]]), 12),
            (motif, 16),
            (rand, 10)]


REQS = _requests()


@pytest.fixture(scope="module")
def engines():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = CONFIGS[name]
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = Engine(model, params,
                                 EngineConfig(max_len=MAX_LEN,
                                              sync_interval=4))
        return cache[name]

    return get


@pytest.fixture(scope="module")
def references(engines):
    """Per-config one-shot greedy rollouts — the ground truth every
    matrix cell is compared against."""
    cache = {}

    def get(name):
        if name not in cache:
            eng = engines(name)
            refs = []
            for prompt, gen in REQS:
                toks, _ = eng.generate(
                    {"tokens": jnp.asarray(prompt)[None]}, n_steps=gen)
                refs.append([int(t) for t in np.asarray(toks)[0]])
            cache[name] = refs
        return cache[name]

    return get


def _geometry(cfg):
    pb = sm.kv_bytes_per_token(cfg) * PT
    return sm.PageGeometry(page_tokens=PT, n_pages=41, n_spill_pages=65,
                           max_pages_per_slot=-(-MAX_LEN // PT),
                           page_bytes=pb)


@pytest.mark.parametrize("spec", [0, 4], ids=["spec0", "spec4"])
@pytest.mark.parametrize("chunk", [None, 6], ids=["whole", "chunk6"])
@pytest.mark.parametrize("mode", ["dense", "paged", "paged-share"])
@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_matrix_cell_matches_one_shot(engines, references, name, mode,
                                      chunk, spec):
    cfg = CONFIGS[name]
    eng = engines(name)
    refs = references(name)
    prev = eng.ecfg.speculate_tokens
    eng.ecfg.speculate_tokens = spec
    try:
        sch = sm.Scheduler(
            3,
            pages=None if mode == "dense" else _geometry(cfg),
            prefix_share=(mode == "paged-share"),
            chunk_prefill_tokens=chunk)
        rids = [sch.submit(p, g).rid for p, g in REQS]
        with jax.transfer_guard_device_to_host("disallow"):
            rep = eng.serve(scheduler=sch)
    finally:
        eng.ecfg.speculate_tokens = prev

    # one explicit host read per drain boundary, speculating or not
    assert rep.stats["host_syncs"] == rep.stats["chunks"]
    if spec:
        # one verify forward per boundary replaces sync_interval scan steps
        assert rep.stats["decode_steps"] == rep.stats["chunks"]
        assert rep.stats["spec_proposed"] > 0

    outs = rep.outputs
    for rid, ref in zip(rids, refs):
        got = outs[rid]
        assert len(got) > 0
        # continuous batching drains at EOS while one-shot pads EOS out to
        # the step budget, so the serve output is a prefix of the rollout
        assert got == ref[:len(got)], (name, mode, chunk, spec, rid)


# ---------------------------------------------------------------------------
# The mesh axis (ISSUE 8)
# ---------------------------------------------------------------------------

#: (cell name, prefix_share, chunk_prefill_tokens, speculate_tokens)
MESH_CELLS = (("paged", False, None, 0),
              ("paged-share", True, None, 0),
              ("chunked", False, 6, 0),
              ("speculate", False, None, 4))


@pytest.fixture(scope="module")
def mesh1_engine():
    """An engine configured with an explicit 1x1 mesh — the identity."""
    from repro.launch.mesh import make_host_mesh
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params,
                  EngineConfig(max_len=MAX_LEN, sync_interval=4,
                               mesh=make_host_mesh(1, 1)))


@pytest.mark.parametrize("cell", MESH_CELLS, ids=[c[0] for c in MESH_CELLS])
def test_mesh1_cell_matches_unmeshed_one_shot(references, mesh1_engine,
                                              cell):
    """mesh=1 is bit-identical to NO mesh: the reference rollouts here come
    from the unmeshed engine, so any spec-induced numeric drift at
    trivial mesh sizes fails the cell."""
    _, share, chunk, spec = cell
    eng = mesh1_engine
    refs = references(TINY.name)
    eng.ecfg.speculate_tokens = spec
    try:
        sch = sm.Scheduler(3, pages=_geometry(TINY), prefix_share=share,
                           chunk_prefill_tokens=chunk)
        rids = [sch.submit(p, g).rid for p, g in REQS]
        with jax.transfer_guard_device_to_host("disallow"):
            rep = eng.serve(scheduler=sch)
    finally:
        eng.ecfg.speculate_tokens = 0
    assert rep.stats["host_syncs"] == rep.stats["chunks"]
    for rid, ref in zip(rids, refs):
        got = rep.outputs[rid]
        assert len(got) > 0
        assert got == ref[:len(got)], (cell, rid)


# ---------------------------------------------------------------------------
# The disaggregation axis (ISSUE 9)
# ---------------------------------------------------------------------------

#: (cell name, prefix_share, n_layer0_data_pages, speculate_tokens).
#: Every cell runs chunked (handover at the final chunk is the point);
#: `preempt` shrinks layer 0 until mid-prefill preemption + restore fire.
DISAGG_CELLS = (("share-cow", True, 40, 0),
                ("preempt", False, 7, 0),
                ("speculate", False, 40, 4))


def _disagg_geometry(cfg, n_layer0):
    pb = sm.kv_bytes_per_token(cfg) * PT
    return sm.PageGeometry(page_tokens=PT, n_pages=n_layer0 + 1,
                           n_spill_pages=65,
                           max_pages_per_slot=-(-MAX_LEN // PT),
                           page_bytes=pb)


def _disagg_requests():
    """REQS plus an identical PAGE-ALIGNED prompt pair: the duplicate's
    prefix match covers the whole prompt, so the capped match ends
    mid-page and the share-cow cell takes the COW-frontier path (a fresh
    private copy of the final matched page), not just row sharing."""
    rng = np.random.RandomState(23)
    aligned = rng.randint(2, 128, size=3 * PT).astype(np.int32)
    long = rng.randint(2, 128, size=44).astype(np.int32)
    return list(REQS) + [(aligned, 8), (aligned.copy(), 6), (long, 12)]


@pytest.mark.parametrize("cell", DISAGG_CELLS, ids=[c[0] for c in DISAGG_CELLS])
def test_disagg_cell_matches_single_engine(engines, cell):
    """Disaggregated roles move no bits: each cell must be bit-identical
    to the SAME engine's single-engine chunked serve of the same stream,
    both under the transfer guard — and must actually exercise its feature
    (COW admissions, mid-prefill preemption + restore, or speculation)
    while holding the per-role sync budget: the decode role reads once per
    boundary, the prefill role only at prompt-completing boundaries."""
    _, share, n_layer0, spec = cell
    eng = engines(TINY.name)
    reqs = _disagg_requests()
    prev = eng.ecfg.speculate_tokens
    eng.ecfg.speculate_tokens = spec
    try:
        runs = {}
        for disagg in (False, True):
            sch = sm.Scheduler(
                3, pages=_disagg_geometry(TINY, n_layer0),
                prefix_share=share, chunk_prefill_tokens=6,
                disaggregate=disagg)
            rids = [sch.submit(p, g).rid for p, g in reqs]
            with jax.transfer_guard_device_to_host("disallow"):
                rep = eng.serve(scheduler=sch)
            runs[disagg] = ([rep.outputs[r] for r in rids], rep.stats)
    finally:
        eng.ecfg.speculate_tokens = prev

    outs, st = runs[True]
    assert outs == runs[False][0], cell[0]      # bit-identical token streams
    assert all(len(o) > 0 for o in outs)
    # the handover invariant: every drained prompt crossed roles once
    assert st["handovers"] == len(reqs)
    assert st["handover_pages"] > 0
    by_role = st["host_syncs_by_role"]
    assert by_role["decode"] == st["chunks"]
    assert 0 < by_role["prefill"] <= st["chunks"]
    assert st["host_syncs"] == by_role["decode"] + by_role["prefill"]
    # the cell exercised its feature in the disaggregated run
    if share:
        assert st["cow_copies"] > 0, "duplicate prompt never took COW"
        assert st["prefix_hits"] > 0
    if n_layer0 < 40:
        assert st["preemptions"] > 0, "tight pool never preempted"
        assert st["restores"] > 0
    if spec:
        assert st["spec_proposed"] > 0
        assert st["decode_steps"] == st["chunks"]


# ---------------------------------------------------------------------------
# The tier-codec axis (ISSUE 10)
# ---------------------------------------------------------------------------

#: (cell name, prefix_share, chunk_prefill_tokens, speculate_tokens,
#: disaggregate) — every serving mode the codec must compose with.
KVQ_CELLS = (("paged", False, None, 0, False),
             ("paged-share", True, None, 0, False),
             ("chunked", False, 6, 0, False),
             ("speculate", False, None, 4, False),
             ("disagg", False, 6, 0, True))

#: One-step logit-error budgets for the quantized codecs, pinned against
#: measured drift on these tiny models (int8 ~8e-3, fp8 ~2.3e-2) with
#: generous margin: per-step error past these bounds is an encoder
#: regression, not noise.
KVQ_LOGIT_BOUND = {"int8": 0.05, "fp8": 0.10}
#: Greedy FIRST-token agreement gate for quantized serving. Full-sequence
#: agreement is deliberately not gated — one early argmax flip on a
#: random tiny model legitimately diverges the rest of the rollout.
KVQ_FIRST_TOKEN_AGREEMENT = 0.75


def _quant_geometry(cfg, kv_quant):
    return sm.derive_page_geometry(cfg, MAX_LEN, page_tokens=PT,
                                   max_slots=3, layer0_bytes=64 * 1024,
                                   kv_quant=kv_quant)


@pytest.mark.parametrize("cell", KVQ_CELLS, ids=[c[0] for c in KVQ_CELLS])
def test_fp16_codec_cells_bit_identical(engines, references, cell):
    """kv_quant="fp16" is the identity codec: a geometry derived through
    the explicit codec path serves every mode bit-identical to the
    one-shot rollout, exactly like the codec-less pool."""
    _, share, chunk, spec, disagg = cell
    eng = engines(TINY.name)
    refs = references(TINY.name)
    prev = eng.ecfg.speculate_tokens
    eng.ecfg.speculate_tokens = spec
    try:
        sch = sm.Scheduler(3, pages=_quant_geometry(TINY, "fp16"),
                           prefix_share=share, chunk_prefill_tokens=chunk,
                           disaggregate=disagg)
        rids = [sch.submit(p, g).rid for p, g in REQS]
        with jax.transfer_guard_device_to_host("disallow"):
            rep = eng.serve(scheduler=sch)
    finally:
        eng.ecfg.speculate_tokens = prev
    assert rep.stats["layer0_codec"] == "fp16"
    for rid, ref in zip(rids, refs):
        got = rep.outputs[rid]
        assert len(got) > 0
        assert got == ref[:len(got)], (cell[0], rid)


@pytest.mark.parametrize("kv_quant", sorted(KVQ_LOGIT_BOUND))
def test_quantized_one_step_logit_drift_bounded(engines, kv_quant):
    """One decode step off a quantized pool: max|Δlogit| vs the fp16 pool
    stays inside the pinned budget and the argmax token agrees."""
    eng = engines(TINY.name)
    prompt, _ = REQS[0]
    logits = {}
    for qq in ("fp16", kv_quant):
        geom = _quant_geometry(TINY, qq)
        sch = sm.Scheduler(3, pages=geom)
        sch.submit(prompt, 8)
        plan = sch.plan_boundary(chunk_tokens=1, max_len=MAX_LEN)
        pool, _ = eng.init_paged_pool(sch)
        slot, rr = plan.admits[0]
        pool, _first = eng.prefill_role.paged_admit(pool, slot, rr, geom)
        pool = dataclasses.replace(
            pool, block_tables=jnp.asarray(sch.block_table()))
        out = eng.model.decode_step(
            eng.params, pool.tok[:, None], pool.state, pool.cache_len,
            block_tables=pool.block_tables, plans=eng.plans)
        lg = out[0] if isinstance(out, tuple) else out
        logits[qq] = np.asarray(
            lg[slot, 0, :TINY.vocab_size], np.float32)
    drift = float(np.max(np.abs(logits[kv_quant] - logits["fp16"])))
    assert drift <= KVQ_LOGIT_BOUND[kv_quant], drift
    assert int(np.argmax(logits[kv_quant])) == \
        int(np.argmax(logits["fp16"]))


@pytest.mark.parametrize("cell", KVQ_CELLS, ids=[c[0] for c in KVQ_CELLS])
@pytest.mark.parametrize("kv_quant", sorted(KVQ_LOGIT_BOUND))
def test_quantized_cells_serve_with_greedy_agreement(engines, references,
                                                     kv_quant, cell):
    """Quantized codecs compose with every serving mode: all requests
    drain with output, and the greedy FIRST token agrees with the fp16
    reference on at least the pinned fraction of the stream."""
    _, share, chunk, spec, disagg = cell
    eng = engines(TINY.name)
    refs = references(TINY.name)
    prev = eng.ecfg.speculate_tokens
    eng.ecfg.speculate_tokens = spec
    try:
        sch = sm.Scheduler(3, pages=_quant_geometry(TINY, kv_quant),
                           prefix_share=share, chunk_prefill_tokens=chunk,
                           disaggregate=disagg)
        rids = [sch.submit(p, g).rid for p, g in REQS]
        with jax.transfer_guard_device_to_host("disallow"):
            rep = eng.serve(scheduler=sch)
    finally:
        eng.ecfg.speculate_tokens = prev
    assert rep.stats["layer0_codec"] == kv_quant
    outs = [rep.outputs[r] for r in rids]
    assert all(len(o) > 0 for o in outs)
    agree = sum(o[0] == ref[0] for o, ref in zip(outs, refs))
    assert agree >= KVQ_FIRST_TOKEN_AGREEMENT * len(REQS), \
        (cell[0], kv_quant, agree)


def test_mesh2_matrix_in_subprocess():
    """mesh=2 on forced host-platform devices, in a child python (the XLA
    device-count flag only takes effect before jax imports)."""
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(here)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, os.path.join(here, "mesh_matrix_check.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert "MESH_MATRIX_OK" in proc.stdout
