"""The reproduction gate: our analytical models must reproduce the paper's
published numbers (Tables I-II primitives -> derived rows, Figs 6-9).

Every assertion cites the paper section it checks.
"""

import pytest

from repro.core import area_model, energy, perf_model, tiling
from repro.core.hw_profiles import (MEMPOOL_PROFILES, MiB, SPM_CAPACITIES_MIB,
                                    mempool_profile)


# --------------------------------------------------------------- §VI-A tiles

def test_mempool_tile_sizes_match_paper():
    """§VI-A: tile sizes t=256/384/544/800 fully utilize 1/2/4/8 MiB."""
    assert tiling.mempool_tile_size(1 * MiB) == 256
    assert tiling.mempool_tile_size(2 * MiB) == 384
    assert tiling.mempool_tile_size(4 * MiB) == 544
    assert tiling.mempool_tile_size(8 * MiB) == 800


def test_paper_m_is_lcm_of_tiles():
    """§VI-A: M=326400 is the least common multiple of the tile sizes."""
    import math
    m = 1
    for t in (256, 384, 544, 800):
        m = math.lcm(m, t)
    assert m == perf_model.PAPER_M == 326400


def test_loads_per_element_law():
    """§VI-A: each input element is loaded exactly M/t times."""
    for t in (256, 384, 544, 800):
        assert tiling.loads_per_element(perf_model.PAPER_M, t) == perf_model.PAPER_M / t


# ----------------------------------------------------------------- Fig. 6

@pytest.mark.parametrize("bw,paper_speedup,tol", [
    (4, 1.43, 0.02),    # "43 % for the 8 MiB case ... worst-case bandwidth"
    (16, 1.16, 0.02),   # "16 % over the baseline" at one DDR channel
    (64, 1.08, 0.02),   # "8 % benefit" at the optimistic bandwidth
])
def test_fig6_8mib_speedups(bw, paper_speedup, tol):
    got = perf_model.speedup_vs_baseline(8 * MiB, bw)
    assert abs(got - paper_speedup) <= tol, (bw, got, paper_speedup)


def test_fig6_speedup_monotonic_in_capacity():
    """Bigger SPM => more reuse => never slower (at fixed bandwidth)."""
    for bw in perf_model.PAPER_BANDWIDTHS:
        cycles = [perf_model.matmul_cycles(spm_bytes=c * MiB,
                                           bw_bytes_per_cycle=bw).total
                  for c in SPM_CAPACITIES_MIB]
        assert cycles == sorted(cycles, reverse=True), (bw, cycles)


def test_fig6_speedup_shrinks_with_bandwidth():
    """The capacity benefit decays as off-chip bandwidth rises (Fig. 6)."""
    s = [perf_model.speedup_vs_baseline(8 * MiB, bw)
         for bw in (4, 8, 16, 32, 64)]
    assert s == sorted(s, reverse=True), s


def test_phase_breakdown_components():
    pb = perf_model.matmul_cycles(spm_bytes=1 * MiB, bw_bytes_per_cycle=16)
    assert pb.memory_cycles > 0 and pb.compute_cycles > 0
    assert pb.static_cycles > 0 and pb.store_cycles > 0
    assert pb.total == pytest.approx(pb.memory_cycles + pb.compute_cycles
                                     + pb.static_cycles + pb.store_cycles)


def test_memory_phase_scales_with_bandwidth():
    lo = perf_model.matmul_cycles(spm_bytes=1 * MiB, bw_bytes_per_cycle=4)
    hi = perf_model.matmul_cycles(spm_bytes=1 * MiB, bw_bytes_per_cycle=64)
    assert lo.memory_cycles == pytest.approx(16 * hi.memory_cycles)
    assert lo.compute_cycles == pytest.approx(hi.compute_cycles)


# ----------------------------------------------------------------- Table I

def test_table1_reproduction():
    """§IV Table I: predicted footprints/utilizations within 6 % of paper."""
    for row in area_model.table1():
        paper = area_model.PAPER_TABLE1[(row["flow"], row["spm_mib"])]
        assert row["footprint"] == pytest.approx(paper["footprint"], rel=0.06)
        if paper["mem_util"] is not None:
            assert row["mem_util"] == pytest.approx(paper["mem_util"], abs=0.04)


def test_table1_8mib_partitioning():
    """§IV: the 8 MiB 3D tile moves one SPM bank + the I$ to the logic die."""
    p = area_model.partition_tile("3D", 8 * MiB)
    assert p.banks_on_mem_die == 15
    assert not p.icache_on_mem_die


def test_table1_default_partitioning_small():
    """§IV Fig. 1: 1-4 MiB 3D tiles keep all banks + I$ on the memory die."""
    for mib in (1, 2, 4):
        p = area_model.partition_tile("3D", mib * MiB)
        assert p.banks_on_mem_die == 16
        assert p.icache_on_mem_die


# ----------------------------------------------------------------- Table II

def test_table2_pdp_row():
    """Table II: PDP deltas 3D vs 2D = -12 %, -13 %, -16 %, -14 %."""
    pdp = energy.pdp_table()
    for mib, delta in ((1, -0.12), (2, -0.13), (4, -0.16), (8, -0.14)):
        got = pdp[f"MemPool-3D_{mib}MiB"] / pdp[f"MemPool-2D_{mib}MiB"] - 1.0
        assert got == pytest.approx(delta, abs=0.01), (mib, got)


def test_table2_frequency_gain_4mib():
    """§V-B: 3D(4 MiB) clocks 9.1 % higher than 2D(4 MiB)."""
    f3 = mempool_profile("3D", 4).freq_norm
    f2 = mempool_profile("2D", 4).freq_norm
    assert f3 / f2 - 1.0 == pytest.approx(0.091, abs=0.002)


def test_table2_2d_degradation():
    """§V-B: 2D groups degrade up to 12.5 % in frequency, 29.9 % in power."""
    freqs = [mempool_profile("2D", c).freq_norm for c in SPM_CAPACITIES_MIB]
    powers = [mempool_profile("2D", c).power_norm for c in SPM_CAPACITIES_MIB]
    assert 1.0 - min(freqs) == pytest.approx(0.125, abs=0.002)
    assert max(powers) - 1.0 == pytest.approx(0.299, abs=0.002)


def test_table2_3d_degradation_smaller():
    """§V-B: 3D degradation (~11.8 % freq, 28.4 % power) < 2D's, rel. 3D base.

    Note: the paper's prose says 11.8 %, but its own Table II (3-digit
    normalized values 1.040 -> 0.930) gives 10.6 % — the prose was evidently
    computed from unrounded silicon numbers. We assert against the table.
    """
    p1 = mempool_profile("3D", 1)
    freqs = [mempool_profile("3D", c).freq_norm / p1.freq_norm
             for c in SPM_CAPACITIES_MIB]
    powers = [mempool_profile("3D", c).power_norm / p1.power_norm
              for c in SPM_CAPACITIES_MIB]
    assert 1.0 - min(freqs) == pytest.approx(0.112, abs=0.012)
    assert max(powers) - 1.0 == pytest.approx(0.284, abs=0.005)
    # and strictly smaller than the 2D flow's degradation (the §V-B claim)
    freq_drop_2d = 1.0 - min(mempool_profile("2D", c).freq_norm
                             for c in SPM_CAPACITIES_MIB)
    assert 1.0 - min(freqs) < freq_drop_2d


# ----------------------------------------------------------------- Figs 7-9

def test_fig7_3d_beats_2d_by_up_to_9pct():
    """Fig. 7: 3D outperforms 2D by up to 9.1 % (the 4 MiB configuration)."""
    gains = {}
    for mib in SPM_CAPACITIES_MIB:
        d3 = energy.derive("3D", mib)
        d2 = energy.derive("2D", mib)
        gains[mib] = d3.performance / d2.performance - 1.0
    assert max(gains.values()) == pytest.approx(0.091, abs=0.003)
    assert max(gains, key=gains.get) == 4


def test_fig7_8mib_3d_vs_baseline():
    """Fig. 7: MemPool-3D(8 MiB) performs 8.4 % above the 2D-1MiB baseline."""
    d = energy.derive("3D", 8)
    assert d.performance - 1.0 == pytest.approx(0.084, abs=0.01)


def test_fig7_2d4mib_performance_drop():
    """Fig. 7: 2D(4 MiB) *drops* below 2D(1 MiB) (low frequency)."""
    assert energy.derive("2D", 4).performance < 1.0


def test_fig8_efficiency():
    """Fig. 8: 3D(1 MiB) is +14 % efficiency vs baseline; 3D(4 MiB) is
    +18.4 % vs 2D(4 MiB); 2D(8 MiB) is the worst, -21 %."""
    d31 = energy.derive("3D", 1)
    assert d31.efficiency - 1.0 == pytest.approx(0.14, abs=0.015)
    gain = energy.derive("3D", 4).efficiency / energy.derive("2D", 4).efficiency
    assert gain - 1.0 == pytest.approx(0.184, abs=0.01)
    d28 = energy.derive("2D", 8)
    assert d28.efficiency - 1.0 == pytest.approx(-0.21, abs=0.015)
    assert d28.efficiency == min(energy.derive(f, c).efficiency
                                 for f in ("2D", "3D")
                                 for c in SPM_CAPACITIES_MIB)


def test_fig8_3d4mib_energy_budget():
    """Abstract/§VI-B: 3D(4 MiB) runs on an energy budget 3.7 % smaller than
    2D(1 MiB) — 4x the SPM for less energy."""
    d = energy.derive("3D", 4)
    assert 1.0 - d.energy == pytest.approx(0.037, abs=0.01)


def test_fig9_edp():
    """Fig. 9: 3D(1 MiB) has the lowest EDP, 15.6 % below baseline."""
    all_m = energy.derive_all()
    best = min(all_m.values(), key=lambda m: m.edp)
    assert best.name == "MemPool-3D_1MiB"
    assert 1.0 - best.edp == pytest.approx(0.156, abs=0.01)


def test_3d_dominates_2d_at_same_capacity():
    """§V-B: at equal SPM capacity, 3D has higher perf and efficiency."""
    for mib in SPM_CAPACITIES_MIB:
        d3, d2 = energy.derive("3D", mib), energy.derive("2D", mib)
        assert d3.performance > d2.performance
        assert d3.efficiency > d2.efficiency
        assert d3.edp < d2.edp
