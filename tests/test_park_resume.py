"""Layer-2 host tier (ISSUE 10 satellite): park/resume must be invisible.

DESIGN.md §Tiered KV compression & host parking. At the fp16 codec a
parked session's blob holds the exact pool bytes it was resident with,
and a parked-then-resumed stream emits tokens bit-identical to the same
stream served uninterrupted — through preemption pressure, prefix
sharing (the shared page stays resident for its other reader and is
re-matched on resume, never re-prefilled), and disaggregated roles.
Every serve runs under the device->host transfer guard; only the park
gather itself reads the device.
"""

import dataclasses

import jax
import msgpack
import numpy as np
import pytest

from repro.models import build_model, transformer
from repro.models.config import ModelConfig
from repro.serve import park as park_mod
from repro.serve import scheduler as sm
from repro.serve.engine import Engine, EngineConfig

MAX_LEN = 64
PT = 8

TINY = ModelConfig(
    name="tiny-park", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)


def _requests():
    """Two prompts share a repetitive 16-token system prefix (so the
    sharing axis has a page to keep resident across a park), plus two
    independent prompts for queue pressure."""
    rng = np.random.RandomState(7)
    system = np.tile(rng.randint(2, 128, size=4).astype(np.int32), 4)
    tails = [rng.randint(2, 128, size=n).astype(np.int32) for n in (7, 11)]
    rand = rng.randint(2, 128, size=13).astype(np.int32)
    long = rng.randint(2, 128, size=27).astype(np.int32)
    return [(np.concatenate([system, tails[0]]), 14),
            (np.concatenate([system, tails[1]]), 12),
            (rand, 10),
            (long, 12)]


REQS = _requests()


@pytest.fixture(scope="module")
def engine():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params,
                  EngineConfig(max_len=MAX_LEN, sync_interval=4))


def _geometry(n_pages=41):
    pb = sm.kv_bytes_per_token(TINY) * PT
    return sm.PageGeometry(page_tokens=PT, n_pages=n_pages,
                           n_spill_pages=65,
                           max_pages_per_slot=-(-MAX_LEN // PT),
                           page_bytes=pb)


def _run(eng, reqs, *, park_at=0, geom=None, **sch_kwargs):
    """Serve ``reqs`` on a fresh scheduler; with ``park_at`` run the
    run_stream two-phase flow: serve ``park_at`` decode steps, park every
    decoding resident, requeue mid-prefill ones, resume the blobs into
    the SAME scheduler, serve to completion."""
    sch = sm.Scheduler(3, pages=geom or _geometry(), **sch_kwargs)
    rids = [sch.submit(p, g).rid for p, g in reqs]
    rid_map = {r: r for r in rids}          # submission rid -> final rid
    n_parked = 0
    if park_at:
        with jax.transfer_guard_device_to_host("disallow"):
            eng.serve(scheduler=sch, max_steps=park_at)
        blobs = []
        for slot in sorted(list(sch.active)):
            req = sch.active[slot]
            if req.status == sm.DECODING:
                blobs.append((req.rid, eng.park_request(sch, req.rid)))
            elif req.status == sm.PREFILLING:
                sch.requeue(slot)
        n_parked = len(blobs)
        for old_rid, blob in blobs:
            rid_map[old_rid] = eng.resume_parked(sch, blob).rid
    with jax.transfer_guard_device_to_host("disallow"):
        rep = eng.serve(scheduler=sch)
    outs = [rep.outputs[rid_map[r]] for r in rids]
    return outs, rep, n_parked


def test_park_resume_outputs_bit_exact(engine):
    """The headline guarantee: fp16 park/resume moves no bits — tokens
    after the interruption are identical to the uninterrupted stream."""
    outs_u, rep_u, _ = _run(engine, REQS)
    outs_p, rep_p, n_parked = _run(engine, REQS, park_at=4)
    assert n_parked > 0
    st = rep_p.stats
    assert st["parks"] == n_parked
    assert st["park_resumes"] == n_parked
    assert st["layer0_codec"] == "fp16"
    assert outs_p == outs_u
    assert all(len(o) > 0 for o in outs_p)
    assert rep_u.stats["parks"] == 0


def test_park_blob_holds_exact_pool_bytes(engine):
    """An fp16 park is a byte copy: the blob's page/row leaves round-trip
    to exactly the bytes that were resident when the session parked."""
    sch = sm.Scheduler(3, pages=_geometry())
    rids = [sch.submit(p, g).rid for p, g in REQS]
    with jax.transfer_guard_device_to_host("disallow"):
        engine.serve(scheduler=sch, max_steps=4)
    slot, req = next((s, r) for s, r in sorted(sch.active.items())
                     if r.status == sm.DECODING)
    pool, cfg = engine._last_pool, TINY
    pages = np.asarray(req.pages, np.int32)
    expect = {}
    for gname, gkey, is_paged in transformer.paged_cache_kinds(cfg):
        for name, arr in pool.state["caches"][gname][gkey].items():
            key = f"{gname}/{gkey}/{name}"
            if is_paged:
                expect["pages/" + key] = np.asarray(arr[:, pages])
            else:
                expect["rows/" + key] = np.asarray(arr[:, slot:slot + 1])
    prompt, tokens = list(req.prompt), list(req.tokens)
    blobs = [(req.rid, engine.park_request(sch, req.rid))]
    blob = blobs[0][1]
    for s in sorted(list(sch.active)):
        other = sch.active[s]
        if other.status == sm.DECODING:
            blobs.append((other.rid, engine.park_request(sch, other.rid)))
        elif other.status == sm.PREFILLING:
            sch.requeue(s)

    meta, arrays = park_mod.unpack_parked(blob)
    assert meta["prompt"] == [int(t) for t in prompt]
    assert meta["tokens"] == [int(t) for t in tokens] and meta["tokens"]
    assert meta["n_pages"] == len(pages)
    assert set(arrays) == set(expect)
    for key, got in arrays.items():
        want = expect[key]
        assert got.dtype == want.dtype, key
        assert got.shape == want.shape, key
        assert got.tobytes() == want.tobytes(), key

    # serializer round trip is itself lossless
    blob2 = park_mod.pack_parked(meta, arrays)
    meta2, arrays2 = park_mod.unpack_parked(blob2)
    assert meta2 == meta
    for key in arrays:
        assert arrays2[key].tobytes() == arrays[key].tobytes()

    # resume everything and drain: the stream still completes
    rid_map = {r: r for r in rids}
    for old_rid, b in blobs:
        rid_map[old_rid] = engine.resume_parked(sch, b).rid
    with jax.transfer_guard_device_to_host("disallow"):
        rep = engine.serve(scheduler=sch)
    assert all(len(rep.outputs[rid_map[r]]) > 0 for r in rids)


def test_park_resume_through_preemption(engine):
    """A pool tight enough to preempt still parks and resumes bit-exact:
    the layer-1 spill tier and the layer-2 host tier compose."""
    geom = _geometry(n_pages=8)
    outs_u, rep_u, _ = _run(engine, REQS, geom=geom,
                            chunk_prefill_tokens=6)
    outs_p, rep_p, n_parked = _run(engine, REQS, park_at=14, geom=geom,
                                   chunk_prefill_tokens=6)
    assert n_parked > 0
    assert rep_p.stats["preemptions"] > 0, "tight pool never preempted"
    assert outs_p == outs_u


def test_park_one_sharer_keeps_shared_pages_resident(engine):
    """Parking one reader of a shared prefix must not yank the shared
    pages: they drop one reference, stay resident for the other reader,
    and the resumed session re-matches them through the prefix index."""
    outs_u, rep_u, _ = _run(engine, REQS, prefix_share=True)

    sch = sm.Scheduler(3, pages=_geometry(), prefix_share=True)
    rids = [sch.submit(p, g).rid for p, g in REQS]
    with jax.transfer_guard_device_to_host("disallow"):
        engine.serve(scheduler=sch, max_steps=4)
    sharer = next(r for r in sch.active.values()
                  if r.status == sm.DECODING and r.n_shared > 0)
    shared = list(sharer.pages[:sharer.n_shared])
    assert shared
    refs_before = [sch.page_pool._refs[p] for p in shared]
    assert all(rc >= 2 for rc in refs_before)

    # park the SHARING reader first: the shared pages drop one reference
    # but stay resident for the reader that still maps them
    blobs = [(sharer.rid, engine.park_request(sch, sharer.rid))]
    for p, before in zip(shared, refs_before):
        assert p not in sch.page_pool._free_set, "shared page was freed"
        assert sch.page_pool._refs[p] == before - 1

    # a serve() boundary rebuilds the pool, so the rest of the residents
    # park too (the run_stream contract); the LAST reader's park finally
    # frees the shared pages — nothing leaks to the free list early
    for slot in sorted(list(sch.active)):
        req = sch.active[slot]
        if req.status == sm.DECODING:
            blobs.append((req.rid, engine.park_request(sch, req.rid)))
        elif req.status == sm.PREFILLING:
            sch.requeue(slot)
    assert sch.page_pool.in_use == 0
    assert all(p in sch.page_pool._free_set for p in shared)

    rid_map = {r: r for r in rids}
    for old_rid, blob in blobs:
        rid_map[old_rid] = engine.resume_parked(sch, blob).rid
    with jax.transfer_guard_device_to_host("disallow"):
        rep = engine.serve(scheduler=sch)
    assert rep.stats["parks"] == len(blobs)
    assert rep.stats["park_resumes"] == len(blobs)
    assert rep.stats["prefix_hits"] > 0
    assert [rep.outputs[rid_map[r]] for r in rids] == outs_u
    # everything drained: every page reference was put back
    assert sch.page_pool.in_use == 0
    assert sch.page_pool.mapped == 0


def test_park_resume_disaggregated(engine):
    """Park/resume composes with disaggregated roles: the resumed session
    re-enters as a decode-side resume and the stream stays bit-identical
    to the uninterrupted disaggregated run."""
    outs_u, rep_u, _ = _run(engine, REQS, chunk_prefill_tokens=6,
                            disaggregate=True)
    outs_p, rep_p, n_parked = _run(engine, REQS, park_at=14,
                                   chunk_prefill_tokens=6,
                                   disaggregate=True)
    assert n_parked > 0
    assert rep_p.stats["parks"] == n_parked
    assert rep_p.stats["handovers"] > 0
    assert outs_p == outs_u


def test_park_rejects_mid_prefill_and_unknown_rid(engine):
    """A mid-prefill resident has no emitted token to resume from — it
    must requeue, not park; an inactive rid is a KeyError."""
    sch = sm.Scheduler(3, pages=_geometry(), chunk_prefill_tokens=6)
    rids = [sch.submit(p, g).rid for p, g in REQS]
    with jax.transfer_guard_device_to_host("disallow"):
        engine.serve(scheduler=sch, max_steps=1)
    slot, req = next((s, r) for s, r in sorted(sch.active.items())
                     if r.status == sm.PREFILLING)
    with pytest.raises(ValueError, match="only decoding sessions park"):
        engine.park_request(sch, req.rid)
    with pytest.raises(KeyError, match="not active"):
        engine.park_request(sch, 10 ** 9)
    for s in sorted(list(sch.active)):
        sch.requeue(s)                  # all mid-prefill: restart them
    with jax.transfer_guard_device_to_host("disallow"):
        rep = engine.serve(scheduler=sch)
    assert all(len(rep.outputs[r]) > 0 for r in rids)


def test_submit_parked_validates():
    dense = sm.Scheduler(3)
    with pytest.raises(ValueError, match="park/resume requires the paged"):
        dense.submit_parked([1, 2, 3], 4, [5])
    paged = sm.Scheduler(3, pages=_geometry())
    with pytest.raises(ValueError, match="empty token list"):
        paged.submit_parked([1, 2, 3], 4, [])


def test_unpack_rejects_foreign_format():
    bad = msgpack.packb({"format": 99, "meta": {}, "arrays": {}},
                        use_bin_type=True)
    with pytest.raises(ValueError, match="blob format 99"):
        park_mod.unpack_parked(bad)
