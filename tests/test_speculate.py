"""Self-drafting speculative decoding (DESIGN.md §Speculative decoding):
proposer behavior, acceptance folding vs the sequential single-token
reference, the CapacityPartition draft budget, engine counters,
composition with preemption/spill, and the recurrent-family gate."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm
from repro.serve import speculate as sp
from repro.serve.engine import Engine, EngineConfig

TINY = ModelConfig(
    name="tiny-spec", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)
TINY_HYBRID = dataclasses.replace(TINY, name="tiny-spec-hyb",
                                  family="hybrid", n_layers=4, ssm_d_state=8,
                                  ssm_conv=4, attn_period=2, attn_offset=1)
MAX_LEN = 64
PT = 8


# ------------------------------------------------------------- proposer

def test_propose_ngram_continues_constant_run():
    ctx = np.asarray([5, 9, 3] + [7] * 20, np.int32)
    d = sp.propose_ngram(ctx, 6)
    np.testing.assert_array_equal(d, [7] * 6)


def test_propose_ngram_continues_cycle():
    """A short-period cycle must yield full-k proposals of the cycle, not
    proposals truncated at the end of the context."""
    ctx = np.asarray([1, 2] + [8, 9, 4] * 6, np.int32)
    d = sp.propose_ngram(ctx, 7)
    np.testing.assert_array_equal(d, [8, 9, 4, 8, 9, 4, 8])


def test_propose_ngram_prefers_most_recent_match():
    # trailing [3, 4] occurs twice; the most recent full-window hit wins
    ctx = np.asarray([3, 4, 10, 11, 12, 3, 4, 20, 21, 22, 3, 4], np.int32)
    np.testing.assert_array_equal(sp.propose_ngram(ctx, 2), [20, 21])


def test_propose_ngram_no_match_and_degenerate():
    assert sp.propose_ngram(np.arange(2, 30, dtype=np.int32), 4).size == 0
    assert sp.propose_ngram(np.asarray([5], np.int32), 4).size == 0
    assert sp.propose_ngram(np.asarray([], np.int32), 4).size == 0
    assert sp.propose_ngram(np.asarray([7] * 9, np.int32), 0).size == 0


def test_propose_ngram_caps_at_k():
    ctx = np.asarray([7] * 30, np.int32)
    assert sp.propose_ngram(ctx, 4).shape == (4,)


# ---------------------------------------------------- acceptance folding

def ref_fold(targets, drafts, dlen, done, n_gen, budget, cache_len,
             max_len, eos):
    """The sequential single-token reference: what ``emitted`` ordinary
    decode steps would have produced for each slot (same done/stop rules
    as the engine's ``_pool_chunk`` scan, applied token by token)."""
    S, k1 = targets.shape
    out = []
    for s in range(S):
        toks = []
        d, ng, cl = bool(done[s]), int(n_gen[s]), int(cache_len[s])
        if not d:
            for j in range(k1):
                t = int(targets[s, j])
                toks.append(t)
                ng += 1
                cl += 1
                if t == eos or ng >= int(budget[s]) or cl >= max_len:
                    d = True
                    break
                if j < k1 - 1 and j < int(dlen[s]) \
                        and int(drafts[s, j]) == t:
                    continue
                break
        out.append({"toks": toks, "tok": toks[-1] if toks else eos,
                    "done": d, "n_gen": ng, "cache_len": cl})
    return out


def assert_fold_matches_ref(targets, drafts, dlen, done, n_gen, budget,
                            cache_len, max_len=MAX_LEN, eos=1):
    import jax.numpy as jnp
    fold = sp.fold_acceptance(
        jnp.asarray(targets), jnp.asarray(drafts), jnp.asarray(dlen),
        done=jnp.asarray(done), n_gen=jnp.asarray(n_gen),
        budget=jnp.asarray(budget), cache_len=jnp.asarray(cache_len),
        max_len=max_len, eos_token=eos)
    ref = ref_fold(targets, drafts, dlen, done, n_gen, budget, cache_len,
                   max_len, eos)
    valid = np.asarray(fold.valid)
    for s, r in enumerate(ref):
        m = int(np.asarray(fold.emitted)[s])
        assert m == len(r["toks"]), (s, m, r)
        got = [int(t) for t, v in zip(np.asarray(targets)[s], valid[s]) if v]
        assert got == r["toks"], (s, got, r)
        # emitted positions are a contiguous prefix of the verify chunk
        assert valid[s, :m].all() and not valid[s, m:].any()
        assert int(np.asarray(fold.tok)[s]) == r["tok"]
        assert bool(np.asarray(fold.done)[s]) == r["done"]
        assert int(np.asarray(fold.n_gen)[s]) == r["n_gen"]
        assert int(np.asarray(fold.cache_len)[s]) == r["cache_len"]


def test_fold_hand_cases():
    k = 4
    targets = np.asarray([
        [10, 11, 12, 13, 14],   # full accept: all 4 drafts match
        [10, 99, 12, 13, 14],   # reject at draft 1 -> emit 2 tokens
        [10, 11, 12, 13, 14],   # done slot: emits nothing
        [20, 21, 22, 23, 24],   # dlen=0 (fresh admission): emits 1
        [10, 1, 12, 13, 14],    # EOS at position 1 stops mid-chunk
        [30, 31, 32, 33, 34],   # budget allows only 2 more tokens
        [40, 41, 42, 43, 44],   # max_len wall after 3 tokens
    ], np.int32)
    drafts = np.asarray([
        [10, 11, 12, 13], [10, 11, 12, 13], [10, 11, 12, 13],
        [0, 0, 0, 0], [10, 1, 12, 13], [30, 31, 32, 33],
        [40, 41, 42, 43],
    ], np.int32)
    dlen = np.asarray([4, 4, 4, 0, 4, 4, 4], np.int32)
    done = np.asarray([0, 0, 1, 0, 0, 0, 0], bool)
    n_gen = np.asarray([3, 3, 3, 1, 3, 3, 3], np.int32)
    budget = np.asarray([20, 20, 20, 20, 20, 5, 20], np.int32)
    cache_len = np.asarray([10, 10, 10, 10, 10, 10, MAX_LEN - 3], np.int32)
    assert_fold_matches_ref(targets, drafts, dlen, done, n_gen, budget,
                            cache_len)


def test_fold_reduces_to_single_step_at_dlen_zero():
    """With no drafts anywhere, the fold must be exactly one done-masked
    decode step: 1 token per live slot, argmax column 0."""
    S, k = 5, 3
    rng = np.random.RandomState(0)
    targets = rng.randint(2, 90, size=(S, k + 1)).astype(np.int32)
    drafts = rng.randint(2, 90, size=(S, k)).astype(np.int32)
    dlen = np.zeros((S,), np.int32)
    done = np.asarray([0, 1, 0, 1, 0], bool)
    assert_fold_matches_ref(targets, drafts, dlen, done,
                            np.full((S,), 2, np.int32),
                            np.full((S,), 30, np.int32),
                            np.full((S,), 9, np.int32))


# ------------------------------------------------------------ k budget

def test_derive_speculate_tokens_power_of_two_and_capped():
    k = sm.derive_speculate_tokens(TINY)
    assert k >= 1 and (k & (k - 1)) == 0
    assert k <= 8
    assert sm.derive_speculate_tokens(TINY, max_tokens=2) <= 2
    # a larger fraction of the compute tier can only raise the budget
    assert sm.derive_speculate_tokens(TINY, fraction=0.25) >= k


def test_derive_speculate_tokens_zero_when_nothing_fits():
    # fraction so small not even one draft token's streamed bytes fit
    assert sm.derive_speculate_tokens(TINY, fraction=1e-12) == 0


def test_repetitive_stream_shape():
    stream = sm.repetitive_stream(5, 24, 16, 128, seed=3, motif_len=6)
    assert len(stream) == 5
    for s in stream:
        p = s["prompt"]
        assert 6 <= p.shape[0] <= 24
        assert 1 <= s["max_new_tokens"] <= 16
        # the prompt tiles its leading motif
        motif = p[:6]
        for i in range(p.shape[0]):
            assert p[i] == motif[i % 6]


# ------------------------------------------------------- engine behavior

def _geometry(cfg, n_layer0=40, n_layer1=64):
    pb = sm.kv_bytes_per_token(cfg) * PT
    return sm.PageGeometry(page_tokens=PT, n_pages=n_layer0 + 1,
                           n_spill_pages=n_layer1 + 1,
                           max_pages_per_slot=-(-MAX_LEN // PT),
                           page_bytes=pb)


@pytest.fixture(scope="module")
def engine():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params,
                  EngineConfig(max_len=MAX_LEN, sync_interval=4,
                               speculate_tokens=4))


def _stream(seed=0, n=6):
    return sm.repetitive_stream(n, 24, 20, TINY.vocab_size, seed=seed)


def _serve(engine, stream, *, spec, paged=False, n_layer0=40):
    prev = engine.ecfg.speculate_tokens
    engine.ecfg.speculate_tokens = spec
    try:
        sch = sm.Scheduler(3, pages=_geometry(TINY, n_layer0)
                           if paged else None)
        for s in stream:
            sch.submit(s["prompt"], s["max_new_tokens"])
        with jax.transfer_guard_device_to_host("disallow"):
            rep = engine.serve(scheduler=sch)
        return rep
    finally:
        engine.ecfg.speculate_tokens = prev


def test_spec_counters_and_sync_discipline(engine):
    base = _serve(engine, _stream(), spec=0)
    rep = _serve(engine, _stream(), spec=4)
    assert rep.outputs == base.outputs
    st = rep.stats
    assert st["speculate_tokens"] == 4
    assert st["spec_proposed"] > 0
    assert 0 <= st["spec_accepted"] <= st["spec_proposed"]
    assert st["spec_rejected"] == st["spec_proposed"] - st["spec_accepted"]
    assert 0.0 <= st["spec_acceptance_rate"] <= 1.0
    # one verify forward AND one host sync per drain boundary
    assert st["decode_steps"] == st["chunks"] == st["host_syncs"]
    # speculation must emit the stream in fewer forwards than sequential
    assert st["decode_steps"] < base.stats["decode_steps"]
    assert "spec_proposed" not in base.stats


def test_spec_survives_preemption_and_spill(engine):
    """A tight layer-0 pool forces preempt/spill/restore mid-speculation;
    outputs must still match the roomy-pool non-speculative run."""
    base = _serve(engine, _stream(7), spec=0, paged=True)
    rep = _serve(engine, _stream(7), spec=4, paged=True, n_layer0=12)
    assert rep.stats["preemptions"] >= 1
    assert rep.stats["restores"] >= 1
    assert rep.outputs == base.outputs


def test_spec_composes_with_share_and_chunked(engine):
    """Speculation + prefix sharing + chunked prefill in one stream stays
    bit-exact; shared pages are never written by verify chunks (a
    corruption would surface in the later matcher's tokens)."""
    rng = np.random.RandomState(5)
    base_prompt = rng.randint(2, TINY.vocab_size, size=16).astype(np.int32)
    tails = [rng.randint(2, TINY.vocab_size, size=n).astype(np.int32)
             for n in (5, 9)]
    reqs = [(np.concatenate([base_prompt, tails[0]]), 12),
            (np.concatenate([base_prompt, tails[1]]), 10),
            (np.tile(rng.randint(2, TINY.vocab_size, size=6), 4)
             .astype(np.int32), 14)]

    def serve(spec, share, chunk):
        prev = engine.ecfg.speculate_tokens
        engine.ecfg.speculate_tokens = spec
        try:
            sch = sm.Scheduler(3, pages=_geometry(TINY), prefix_share=share,
                               chunk_prefill_tokens=chunk)
            for p, g in reqs:
                sch.submit(p, g)
            with jax.transfer_guard_device_to_host("disallow"):
                return engine.serve(scheduler=sch)
        finally:
            engine.ecfg.speculate_tokens = prev

    base = serve(0, False, None)
    rep = serve(4, True, 6)
    assert rep.outputs == base.outputs
    assert rep.stats["prefix_hits"] >= 1


def test_speculate_rejects_recurrent_families():
    model = build_model(TINY_HYBRID)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="roll back"):
        Engine(model, params,
               EngineConfig(max_len=MAX_LEN, speculate_tokens=4))
    # the model-level contract refuses too, independent of the engine
    import jax.numpy as jnp
    eng = Engine(model, params, EngineConfig(max_len=MAX_LEN))
    pool = eng.init_pool(2)
    with pytest.raises(ValueError, match="attention-only"):
        model.verify_step(params, jnp.zeros((2, 3), jnp.int32),
                          pool.state, pool.cache_len)
