"""Per-architecture smoke tests (reduced configs, CPU, one step) plus
prefill/decode consistency — the serving-path correctness gate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import build_model
from repro.models.api import Model
from repro.train import optimizer as opt_mod
from repro.train.loop import TrainConfig, make_train_step

B, S = 2, 32


def _batch(cfg, key, b=B, s=S):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, s, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16) * 0.02
    elif cfg.frontend_len:
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.frontend_len, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16) * 0.02
    return batch


@pytest.fixture(scope="module")
def models():
    """Init each reduced arch once per test session (compile cost)."""
    out = {}
    for arch in ARCH_IDS:
        cfg = get_reduced(arch)
        m = build_model(cfg)
        out[arch] = (m, m.init(jax.random.PRNGKey(0)))
    return out


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_forward_shapes_and_finite(models, arch):
    model, params = models[arch]
    cfg = model.cfg
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = model.loss(params, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_train_step_no_nans(models, arch):
    model, params = models[arch]
    step = make_train_step(model, TrainConfig())
    state = opt_mod.init_opt_state(params, opt_mod.OptConfig())
    batch = _batch(model.cfg, jax.random.PRNGKey(2))
    p2, s2, metrics = jax.jit(step)(params, state, batch)
    assert bool(jnp.isfinite(metrics["total_loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params must actually move
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_microbatched_grads_match_full(models, arch):
    """Grad accumulation (scan) == single-shot on the same global batch."""
    model, params = models[arch]
    state = opt_mod.init_opt_state(params, opt_mod.OptConfig())
    batch = _batch(model.cfg, jax.random.PRNGKey(3), b=4)
    one = make_train_step(model, TrainConfig(n_microbatches=1))
    two = make_train_step(model, TrainConfig(n_microbatches=2))
    _, _, m1 = jax.jit(one)(params, state, batch)
    _, _, m2 = jax.jit(two)(params, state, batch)
    # MoE top-k routing is batch-local so losses match exactly; tolerance for
    # bf16 accumulation ordering.
    np.testing.assert_allclose(float(m1["total_loss"]),
                               float(m2["total_loss"]), rtol=2e-2)


DECODE_ARCHS = ["yi-6b", "deepseek-v2-236b", "falcon-mamba-7b",
                "gemma3-27b", "jamba-1.5-large-398b", "seamless-m4t-medium",
                "qwen2-vl-2b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_then_decode_matches_full_forward(models, arch):
    """Teacher-forcing equivalence: prefill(t[:k]) + decode steps must yield
    the same logits as one full forward — KV/state caches are exact."""
    model, params = models[arch]
    cfg = model.cfg
    b, s, n_dec = 1, 16, 4
    batch = _batch(cfg, jax.random.PRNGKey(4), b=b, s=s)
    toks = batch["tokens"]

    # ground truth: full forward over all s tokens
    if cfg.family == "encdec":
        from repro.models import encdec, layers
        enc_out = encdec.encode(cfg, params, batch["src_embeds"], remat=False)
        x_full, _ = encdec.decode(cfg, params, toks, enc_out, remat=False)
        full_logits = layers.unembed_logits(params["tok"], x_full)
    else:
        from repro.models import layers, transformer
        x_full, _, _ = transformer.forward(
            cfg, params, toks,
            frontend_embeds=batch.get("frontend_embeds"), remat=False)
        full_logits = layers.unembed_logits(params["tok"], x_full)

    # prefill on the first s - n_dec tokens, then decode one-by-one
    k0 = s - n_dec
    off = cfg.frontend_len if (cfg.family != "encdec" and cfg.frontend_len) else 0
    pre_batch = dict(batch, tokens=toks[:, :k0])
    if "labels" in pre_batch:
        del pre_batch["labels"]
    logits, state = model.prefill(params, pre_batch, max_len=s + off)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32),
        np.asarray(full_logits[:, off + k0 - 1], np.float32),
        rtol=5e-2, atol=5e-2)

    cache_len = jnp.asarray(off + k0, jnp.int32)
    for i in range(n_dec - 1):
        logits, state = model.decode_step(params, toks[:, k0 + i:k0 + i + 1],
                                          state, cache_len)
        cache_len = cache_len + 1
        np.testing.assert_allclose(
            np.asarray(logits[:, -1], np.float32),
            np.asarray(full_logits[:, off + k0 + i], np.float32),
            rtol=5e-2, atol=5e-2, err_msg=f"{arch} step {i}")


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_input_specs_cover_all_runnable_shapes(models, arch):
    """input_specs must produce ShapeDtypeStructs for every runnable cell."""
    from repro.models.api import SHAPES
    model, _ = models[arch]
    for name in model.runnable_shapes():
        spec = model.input_specs(SHAPES[name])
        for leaf in jax.tree.leaves(spec):
            assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")


def test_init_param_count_matches_analytic():
    """config.param_count() (roofline MODEL_FLOPS source) must agree with the
    actual initialized tree within the norm/bias rounding."""
    for arch in ("yi-6b", "qwen3-moe-30b-a3b", "falcon-mamba-7b"):
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        analytic, _ = cfg.param_count()
        assert actual == pytest.approx(analytic, rel=0.06), \
            (arch, actual, analytic)


def test_window_attention_matches_full_when_window_large():
    """A sliding window >= seq is exactly full causal attention."""
    from repro.kernels import ref
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 32, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 32, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 32, 16))
    full = ref.attention_ref(q, k, v, causal=True, window=None)
    win = ref.attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(win, full, rtol=1e-5, atol=1e-5)


def test_mrope_differs_from_rope_and_is_finite():
    from repro.models import layers
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 8, 32))
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    pos3 = jnp.stack([pos, pos * 2, pos * 3])  # distinct h/w streams
    r1 = layers.apply_rope(x, pos, 1e4)
    r3 = layers.apply_mrope(x, pos3, 1e4, (4, 6, 6))
    assert bool(jnp.isfinite(r3).all())
    assert float(jnp.abs(r1 - r3).max()) > 1e-3
    # equal position streams reduce M-RoPE to plain RoPE
    pos3_eq = jnp.stack([pos, pos, pos])
    r3_eq = layers.apply_mrope(x, pos3_eq, 1e4, (4, 6, 6))
    np.testing.assert_allclose(r3_eq, r1, rtol=1e-5, atol=1e-5)
