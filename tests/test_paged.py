"""Paged two-tier KV pool: allocator invariants, scheduler consistency,
paged == dense bit-exact equivalence, and the page-walk kernel."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm
from repro.serve.engine import Engine, EngineConfig

TINY = ModelConfig(
    name="tiny-paged", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)

TINY_WINDOW = dataclasses.replace(
    TINY, name="tiny-window", n_layers=3, window=8, local_global_ratio=2)

TINY_MLA = dataclasses.replace(
    TINY, name="tiny-mla", n_kv_heads=4, use_mla=True, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)

TINY_HYBRID = dataclasses.replace(
    TINY, name="tiny-hybrid", family="hybrid", n_layers=4,
    ssm_d_state=8, ssm_conv=4, attn_period=2, attn_offset=1)


def _tight_geometry(cfg, max_len=32, page_tokens=8, n_layer0=6, n_layer1=8):
    pb = sm.kv_bytes_per_token(cfg) * page_tokens
    return sm.derive_page_geometry(
        cfg, max_len, page_tokens=page_tokens, max_slots=3,
        layer0_bytes=pb * n_layer0, layer1_bytes=pb * n_layer1)


# ------------------------------------------------------------ page pool

def test_page_pool_alloc_free_roundtrip():
    pool = sm.PagePool(6)
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert set(a).isdisjoint(b)
    assert 0 not in a + b                    # null page never handed out
    assert pool.alloc(1) is None             # exhausted: all-or-nothing
    assert pool.in_use == 5 and pool.high_water == 5
    pool.free(a)
    assert pool.alloc(4) is None             # only 3 free: no partial grant
    c = pool.alloc(3)
    assert set(c) == set(a)


def test_page_pool_rejects_double_free_and_foreign_pages():
    pool = sm.PagePool(4)
    a = pool.alloc(2)
    pool.free(a)
    with pytest.raises(RuntimeError, match="double free"):
        pool.free([a[0]])
    with pytest.raises(ValueError, match="outside"):
        pool.free([99])
    with pytest.raises(ValueError, match="outside"):
        pool.free([0])                       # the null page is not poolable


def test_geometry_rejects_undersized_layer0():
    with pytest.raises(ValueError, match="layer-0 budget"):
        sm.derive_page_geometry(TINY, 64, page_tokens=8,
                                layer0_bytes=sm.kv_bytes_per_token(TINY) * 8)


def test_derive_n_slots_paged_beats_dense_in_same_budget():
    """The capacity win: inside the SAME layer-0 byte budget, the paged
    pool carries >= 1.3x the dense pool's concurrent slots."""
    max_len = 28
    dense_slots = 3
    budget = dense_slots * sm.kv_bytes_per_token(TINY) * max_len
    geom = sm.derive_page_geometry(TINY, max_len, page_tokens=8,
                                   max_slots=32, layer0_bytes=budget)
    paged_slots = sm.derive_n_slots(TINY, max_len, pages=geom, max_slots=32)
    assert geom.layer0_bytes <= budget
    assert paged_slots >= 1.3 * dense_slots


# Hypothesis property tests for the allocator live in
# tests/test_paged_properties.py (whole-module importorskip, like
# test_properties.py) so these tests still run without hypothesis.

# ------------------------------------------- paged == dense equivalence

@pytest.mark.parametrize("cfg", [TINY_WINDOW, TINY_MLA, TINY_HYBRID],
                         ids=lambda c: c.name)
def test_paged_matches_dense_bit_exact(cfg):
    """Same stream through the dense slot-slab pool and the paged two-tier
    pool (sized to force preemption + spill): outputs must be IDENTICAL,
    under the drain-boundary transfer-guard discipline."""
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=32, eos_token=1, sync_interval=4))
    rng = np.random.RandomState(1)
    prompts = [rng.randint(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 9, 4, 7)]
    dense_sch = sm.Scheduler(n_slots=3)
    for p in prompts:
        dense_sch.submit(p, 20)
    dense = eng.serve(scheduler=dense_sch)
    paged_sch = sm.Scheduler(n_slots=3, pages=_tight_geometry(cfg))
    for p in prompts:
        paged_sch.submit(p, 20)
    with jax.transfer_guard_device_to_host("disallow"):
        paged = eng.serve(scheduler=paged_sch)
    assert {r.rid: r.tokens for r in paged.requests} == \
        {r.rid: r.tokens for r in dense.requests}
    # the tight layer-0 budget must actually exercise the spill tier
    assert paged.stats["preemptions"] >= 1
    assert paged.stats["restores"] >= 1
    assert paged.stats["spill_high_water"] >= 1
    assert paged.stats["host_syncs"] == paged.stats["chunks"]


def test_paged_matches_one_shot_generate():
    """Paged continuous batching == one-shot generate for the same prompts
    (ISSUE acceptance: same transfer-guard discipline as PR 2)."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=32, eos_token=1, sync_interval=4))
    toks = jax.random.randint(jax.random.PRNGKey(7), (3, 8), 2,
                              TINY.vocab_size)
    want, _ = eng.generate({"tokens": toks}, n_steps=7)
    sch = sm.Scheduler(n_slots=3, pages=_tight_geometry(TINY))
    for i in range(3):
        sch.submit(np.asarray(toks[i]), 7)
    with jax.transfer_guard_device_to_host("disallow"):
        got = eng.serve(scheduler=sch).outputs
    for i in range(3):
        ref = list(map(int, want[i]))
        assert got[i] == ref[:len(got[i])]
        assert len(got[i]) <= 7
        if len(got[i]) < 7:
            assert got[i][-1] == eng.ecfg.eos_token


def test_paged_stream_reuse_and_rejection():
    """32 mixed requests (including an oversized one) drain through a tiny
    paged pool with page reuse; the bad request is rejected cleanly."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=32, eos_token=1, sync_interval=4))
    rng = np.random.RandomState(0)
    sch = sm.Scheduler(n_slots=3, pages=_tight_geometry(TINY))
    bad = sch.submit(rng.randint(2, 128, size=100), 4)    # > max_len
    for _ in range(32):
        sch.submit(rng.randint(2, 128, size=rng.randint(3, 17)),
                   int(rng.randint(2, 10)))
    report = eng.serve(scheduler=sch)
    assert report.stats["drained"] == 32
    assert report.stats["rejected"] == 1
    by_rid = {r.rid: r for r in report.requests}
    assert by_rid[bad.rid].status == sm.REJECTED
    assert by_rid[bad.rid].tokens == []
    assert report.stats["pages_in_use"] == 0              # all pages freed
    assert report.stats["pages_high_water"] >= 3
    for req in report.requests:
        if req.status == sm.DRAINED:
            assert 1 <= len(req.tokens) <= req.max_new_tokens


# ------------------------------------------------------ page-walk kernel

def test_paged_flash_decode_matches_oracle():
    """The Pallas page-walk kernel (interpret mode on CPU) == gather +
    dense-masked oracle, within online-softmax tolerance."""
    from repro.kernels.paged_attention import (decode_attention_masked,
                                               paged_decode_attention)
    rng = np.random.RandomState(0)
    b, hq, hkv, d, pt, p_max, n_pages = 3, 4, 2, 16, 8, 4, 13
    bt = np.zeros((b, p_max), np.int32)
    ids = list(range(1, n_pages))
    for i in range(b):
        for p in range(p_max):
            bt[i, p] = ids.pop()
    bt = jnp.asarray(bt)
    cache_len = jnp.asarray([5, 0, 30], jnp.int32)
    k = jnp.asarray(rng.randn(b, hkv, p_max * pt, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, p_max * pt, d), jnp.float32)
    kp = jnp.zeros((n_pages, hkv, pt, d), jnp.float32)
    vp = jnp.zeros((n_pages, hkv, pt, d), jnp.float32)
    for i in range(b):
        for p in range(p_max):
            kp = kp.at[bt[i, p]].set(k[i, :, p * pt:(p + 1) * pt])
            vp = vp.at[bt[i, p]].set(v[i, :, p * pt:(p + 1) * pt])
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    for window in (None, 6):
        want = decode_attention_masked(q, k, v, cache_len, window=window)
        got = paged_decode_attention(q, kp, vp, bt, cache_len,
                                     window=window, impl="pallas")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


# --------------------------------------------- preemption policy edges

def test_growth_self_spills_instead_of_evicting_older_resident():
    """When the grower is itself the youngest resident, IT spills — an
    older sequence is never sacrificed for a younger one."""
    geom = _tight_geometry(TINY, max_len=32, page_tokens=8,
                           n_layer0=6, n_layer1=8)
    sch = sm.Scheduler(n_slots=2, pages=geom)
    a = sch.submit(np.arange(2, 22, dtype=np.int32), 12)   # 20-tok prompt
    b = sch.submit(np.arange(2, 6, dtype=np.int32), 24)    # 4-tok prompt
    plan = sch.plan_boundary(chunk_tokens=8, max_len=32)
    assert [r.rid for _, r in plan.admits] == [a.rid, b.rid]
    assert sch.page_pool.n_free == 0          # 4 + 2 pages: layer 0 full
    a.tokens.extend([7] * 8)                  # simulate one decode chunk
    b.tokens.extend([7] * 8)
    plan = sch.plan_boundary(chunk_tokens=8, max_len=32)
    # A (older, fully grown) keeps its pages; B (younger) needed one more
    # page and self-spilled rather than evicting A
    assert [act.req.rid for act in plan.spills] == [b.rid]
    assert b.status == sm.PREEMPTED and b.preemptions == 1
    assert a.rid in {r.rid for r in sch.active.values()}
    # drain A -> B restores with its full need and finishes
    while sch.has_work():
        for slot in sorted(sch.active):
            req = sch.active[slot]
            take = min(8, req.max_new_tokens - len(req.tokens),
                       32 - req.cache_len)
            req.tokens.extend([7] * max(take, 0))
            if len(req.tokens) >= req.max_new_tokens or req.cache_len >= 32:
                sch.complete(slot)
        if sch.has_work():
            sch.plan_boundary(chunk_tokens=8, max_len=32)
    assert b.status == sm.DRAINED and sch.restores == 1
    assert a.preemptions == 0                 # the oldest never spilled


def test_spill_tier_exhaustion_leaves_scheduler_consistent():
    """A failed preemption (layer 1 full) must not orphan the victim or
    leak its pages: allocation is checked before any bookkeeping."""
    geom = _tight_geometry(TINY, max_len=32, page_tokens=8,
                           n_layer0=4, n_layer1=1)     # 1 spill page only
    sch = sm.Scheduler(n_slots=2, pages=geom)
    sch.submit(np.arange(2, 8, dtype=np.int32), 20)    # 6-tok: 2 pages
    sch.submit(np.arange(2, 8, dtype=np.int32), 20)
    sch.plan_boundary(chunk_tokens=8, max_len=32)      # both admitted: 4/4
    for req in sch.active.values():
        req.tokens.extend([7] * 8)
    with pytest.raises(RuntimeError, match="spill tier exhausted"):
        sch.plan_boundary(chunk_tokens=8, max_len=32)  # 2-page victim > 1
    # victim untouched: still active, pages conserved, nothing leaked
    assert len(sch.active) == 2
    active_pages = [p for r in sch.active.values() for p in r.pages]
    assert sorted(active_pages) == [1, 2, 3, 4]
    assert sch.spill_pool.in_use == 0 and sch.seat_pool.in_use == 0
