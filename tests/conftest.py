"""Shared fixtures. Tests run on the default 1-CPU-device backend —
the 512-device forcing is confined to launch/dryrun.py (see system design)."""

from __future__ import annotations

import os

# Make sure a stray environment doesn't leak the dry-run's device forcing or
# cost-mode lowering into the test process.
os.environ.pop("REPRO_COST_MODE", None)
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "tests must see the real device count (dry-run flags leaked into env)"

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tree_allfinite(tree) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


def assert_close(a, b, *, rtol=2e-4, atol=2e-4, err_msg=""):
    np.testing.assert_allclose(np.asarray(a, dtype=np.float64),
                               np.asarray(b, dtype=np.float64),
                               rtol=rtol, atol=atol, err_msg=err_msg)
