"""Shared fixtures. Tests run on the default 1-CPU-device backend —
the 512-device forcing is confined to launch/dryrun.py (see system design)."""

from __future__ import annotations

import os

# Make sure a stray environment doesn't leak the dry-run's device forcing or
# cost-mode lowering into the test process.
os.environ.pop("REPRO_COST_MODE", None)
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "tests must see the real device count (dry-run flags leaked into env)"

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _restore_target_registry():
    """Snapshot/restore the active HardwareTarget state around every test.

    ``register_target``/``set_target``/``$REPRO_TARGET`` are process-global;
    a test that registers a custom target or switches the current one must
    not leak that choice into the rest of the suite (capacity-derived knobs
    like prefill chunks and speculative-draft budgets all price against the
    active target)."""
    from repro.core import target as target_mod

    registry = dict(target_mod._REGISTRY)
    current = target_mod._CURRENT
    env = os.environ.get("REPRO_TARGET")
    try:
        yield
    finally:
        target_mod._REGISTRY.clear()
        target_mod._REGISTRY.update(registry)
        target_mod.set_target(current)
        if env is None:
            os.environ.pop("REPRO_TARGET", None)
        else:
            os.environ["REPRO_TARGET"] = env


def tree_allfinite(tree) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


def assert_close(a, b, *, rtol=2e-4, atol=2e-4, err_msg=""):
    np.testing.assert_allclose(np.asarray(a, dtype=np.float64),
                               np.asarray(b, dtype=np.float64),
                               rtol=rtol, atol=atol, err_msg=err_msg)
