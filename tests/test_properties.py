"""Hypothesis property tests on system invariants beyond the planner."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model, tiling
from repro.core.hw_profiles import MiB
from repro.kernels import ref
from repro.train import optimizer as opt


@hypothesis.given(
    st.integers(1, 8).map(lambda i: 32 * i),     # seq
    st.sampled_from([1, 2, 4]),                  # heads
    st.sampled_from([16, 32]),                   # head dim
    st.booleans(),                               # causal
    st.sampled_from([None, 16, 48]),             # window
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_attention_blockwise_equals_direct(seq, h, d, causal, window):
    """The blockwise online-softmax path == direct softmax for any config."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seq * h + d), 3)
    q = jax.random.normal(k1, (1, h, seq, d))
    k = jax.random.normal(k2, (1, h, seq, d))
    v = jax.random.normal(k3, (1, h, seq, d))
    a = ref.attention_ref(q, k, v, causal=causal, window=window)
    b = ref.attention_ref_blockwise(q, k, v, causal=causal, window=window,
                                    block_q=32, block_kv=32)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@hypothesis.given(st.lists(st.floats(-1e4, 1e4), min_size=1, max_size=600))
@hypothesis.settings(max_examples=40, deadline=None)
def test_quantize_dequantize_bounded(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    q, s = opt._quantize(x)
    deq = opt._dequantize(q, s, x.shape)
    step = np.asarray(jnp.repeat(s, opt.QBLOCK, axis=-1)[..., :x.shape[-1]])
    assert (np.abs(np.asarray(deq) - np.asarray(x)) <= step * 0.5 + 1e-6).all()


@hypothesis.given(st.integers(0, 10_000), st.integers(0, 3))
@hypothesis.settings(max_examples=25, deadline=None)
def test_pipeline_pure_function_of_step(step, host):
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=4, seed=1)
    p1 = SyntheticPipeline(cfg, host_index=host, n_hosts=4)
    p2 = SyntheticPipeline(cfg, host_index=host, n_hosts=4)
    np.testing.assert_array_equal(p1.batch_at(step)["tokens"],
                                  p2.batch_at(step)["tokens"])


@hypothesis.given(st.integers(1, 64).map(lambda i: i * MiB // 4),
                  st.floats(1.0, 128.0))
@hypothesis.settings(max_examples=30, deadline=None)
def test_perf_model_cycles_positive_and_bw_monotone(spm, bw):
    c1 = perf_model.matmul_cycles(spm_bytes=spm, bw_bytes_per_cycle=bw).total
    c2 = perf_model.matmul_cycles(spm_bytes=spm, bw_bytes_per_cycle=bw * 2).total
    assert c1 > 0 and c2 <= c1


@hypothesis.given(st.integers(1, 100), st.integers(1, 100), st.integers(1, 100))
@hypothesis.settings(max_examples=30, deadline=None)
def test_matmul_plan_traffic_at_least_compulsory(m, k, n):
    """HBM traffic >= compulsory (read A,B once, write C once)."""
    m, k, n = m * 64, k * 64, n * 64
    plan = tiling.plan_matmul(m, k, n)
    tr = plan.hbm_traffic_bytes(m, k, n)
    compulsory = (m * k + k * n) * 2 + m * n * 2
    assert tr >= compulsory * 0.99


@hypothesis.given(st.data())
@hypothesis.settings(max_examples=15, deadline=None)
def test_selective_scan_associative_split(data):
    """For any split point, scan(prefix)+carry == scan(full) — the property
    the chunked kernel and the decode path both rely on."""
    length = data.draw(st.sampled_from([8, 16, 32]))
    split = data.draw(st.integers(1, length - 1))
    di, ds, b = 8, 4, 1
    key = jax.random.PRNGKey(split)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, length, di)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, length, di))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[2], (di, ds)) * 0.1)
    bb = jax.random.normal(ks[3], (b, length, ds)) * 0.1
    c = jax.random.normal(ks[4], (b, length, ds)) * 0.1
    d = jnp.ones((di,))
    full = ref.selective_scan_ref(x, dt, a, bb, c, d)
    y1, h = ref.selective_scan_ref(x[:, :split], dt[:, :split], a,
                                   bb[:, :split], c[:, :split], d,
                                   return_state=True)
    y2 = ref.selective_scan_ref(x[:, split:], dt[:, split:], a, bb[:, split:],
                                c[:, split:], d, h0=h)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1), full,
                               rtol=2e-4, atol=2e-4)


@hypothesis.given(st.integers(2, 512), st.integers(2, 512))
@hypothesis.settings(max_examples=30, deadline=None)
def test_reuse_law_traffic_consistency(m_blocks, t):
    """offchip_traffic == (2*loads_per_element*M^2 + M^2) * word — the two
    published formulations of §VI-A agree."""
    m = m_blocks * t                      # t | M, as in the paper
    lpe = tiling.loads_per_element(m, t)
    traffic = tiling.offchip_traffic_bytes(m, t)
    assert traffic == (2 * lpe * m * m + m * m) * 4
