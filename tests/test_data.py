"""Data pipeline: determinism, resumability, host sharding, structure."""

import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticPipeline


def _cfg(**kw):
    base = dict(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_batch_at_is_deterministic():
    p1 = SyntheticPipeline(_cfg())
    p2 = SyntheticPipeline(_cfg())
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])


def test_different_steps_differ():
    p = SyntheticPipeline(_cfg())
    assert not np.array_equal(p.batch_at(0)["tokens"], p.batch_at(1)["tokens"])


def test_host_shards_differ_and_partition_batch():
    cfg = _cfg(global_batch=8)
    hosts = [SyntheticPipeline(cfg, host_index=i, n_hosts=4) for i in range(4)]
    batches = [h.batch_at(5)["tokens"] for h in hosts]
    assert all(b.shape[0] == 2 for b in batches)
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(batches[i], batches[j])


def test_labels_are_next_tokens():
    p = SyntheticPipeline(_cfg())
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_learnable():
    """Each token has at most `branching` successors — structure a model can
    learn (the loss-decreases integration test depends on this)."""
    cfg = _cfg(branching=4, seq_len=256, global_batch=16)
    p = SyntheticPipeline(cfg)
    succ = {}
    for step in range(4):
        toks = p.batch_at(step)["tokens"]
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= cfg.branching


def test_iterator_matches_batch_at_and_resumes():
    p = SyntheticPipeline(_cfg())
    it = p.iterator(start_step=10, depth=2)
    got = [next(it) for _ in range(3)]
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], p.batch_at(10 + i)["tokens"])


def test_frontend_and_encdec_batches():
    b = SyntheticPipeline(_cfg(frontend_len=4, d_model=16)).batch_at(0)
    assert b["frontend_embeds"].shape == (8, 4, 16)
    assert b["tokens"].shape == (8, 28)
    b = SyntheticPipeline(_cfg(encdec=True, d_model=16)).batch_at(0)
    assert b["src_embeds"].shape == (8, 32, 16)
    assert b["tokens"].shape == (8, 32)


def test_global_batch_must_divide_hosts():
    with pytest.raises(AssertionError):
        SyntheticPipeline(_cfg(global_batch=6), host_index=0, n_hosts=4)
