"""Hypothesis property tests for the paged pool's allocator + scheduler:
no double-mapped page, alloc/free conservation, block tables always
consistent with the free list, and — with prefix sharing — refcount
conservation plus the copy-on-write aliasing rules (DESIGN.md §Prefix
sharing & copy-on-write)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.models.config import ModelConfig
from repro.serve import scheduler as sm

TINY = ModelConfig(
    name="tiny-paged-prop", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)


@hypothesis.given(
    st.integers(2, 40),                      # pool size
    st.lists(st.tuples(st.booleans(), st.integers(0, 7)), max_size=60),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_page_pool_conservation(n_pages, ops):
    """No page is double-mapped; alloc/free conserves the page set."""
    pool = sm.PagePool(n_pages)
    universe = set(range(1, n_pages))
    held = []
    for is_alloc, n in ops:
        if is_alloc:
            got = pool.alloc(n)
            if got is None:
                assert n > pool.n_free       # refusal only when short
            else:
                assert len(got) == n and len(set(got)) == n
                for blk in held:
                    assert set(got).isdisjoint(blk)
                held.append(got)
        elif held:
            pool.free(held.pop(n % len(held)))
    in_use = set().union(*held) if held else set()
    assert in_use | set(pool._free) == universe
    assert in_use.isdisjoint(pool._free)
    assert pool.in_use == len(in_use)


@hypothesis.given(st.integers(0, 2 ** 31 - 1), st.integers(4, 12),
                  st.integers(2, 6))
@hypothesis.settings(max_examples=25, deadline=None)
def test_scheduler_block_tables_consistent_with_free_list(seed, n_reqs,
                                                          n_slots):
    """Drive plan_boundary with a simulated decode loop: block tables must
    always map exactly the pages the free lists do not hold, with no page
    shared between two slots (and same for the spill tier)."""
    rng = np.random.RandomState(seed)
    max_len, chunk, pt = 32, 4, 8
    pb = sm.kv_bytes_per_token(TINY) * pt
    geom = sm.derive_page_geometry(
        TINY, max_len, page_tokens=pt, max_slots=n_slots,
        layer0_bytes=pb * int(rng.randint(4, 10)),
        layer1_bytes=pb * int(rng.randint(6, 12)))
    sch = sm.Scheduler(n_slots=n_slots, pages=geom)
    for _ in range(n_reqs):
        sch.submit(rng.randint(2, 128, size=rng.randint(1, 12)),
                   int(rng.randint(1, 16)))
    for _ in range(200):
        if not sch.has_work():
            break
        sch.plan_boundary(chunk_tokens=chunk, max_len=max_len)
        # ---- invariants after every boundary
        active_pages = [p for r in sch.active.values() for p in r.pages]
        assert len(active_pages) == len(set(active_pages))   # no double map
        assert set(active_pages).isdisjoint(sch.page_pool._free)
        assert set(active_pages) | set(sch.page_pool._free) == \
            set(range(1, geom.n_pages))                      # conservation
        bt = sch.block_table()
        for slot, req in sch.active.items():
            assert list(bt[slot, :len(req.pages)]) == req.pages
            assert (bt[slot, len(req.pages):] == 0).all()    # null tail
        spilled = [p for r in sch.queue if r.status == sm.PREEMPTED
                   for p in r.spill_pages]
        assert len(spilled) == len(set(spilled))
        assert set(spilled).isdisjoint(sch.spill_pool._free)
        # ---- simulate the decode chunk + drain boundary
        for slot in sorted(sch.active):
            req = sch.active[slot]
            take = min(chunk, req.max_new_tokens - len(req.tokens),
                       max_len - req.cache_len)
            req.tokens.extend([7] * max(take, 0))
            if (len(req.tokens) >= req.max_new_tokens
                    or req.cache_len >= max_len):
                sch.complete(slot)
    assert not sch.has_work()
    assert sch.page_pool.in_use == 0                         # all returned
    assert sch.spill_pool.in_use == 0


def check_sharing_invariants(sch, geom):
    """Refcount + COW invariants that must hold at every drain boundary
    (shared with the hypothesis property below so a plain deterministic
    loop can also drive it)."""
    pool, pt = sch.page_pool, geom.page_tokens
    # refcount conservation: sum of refcounts == mapped block-table entries
    mapped = sum(len(r.pages) for r in sch.active.values())
    assert pool.mapped == mapped
    assert sum(pool._refs[1:]) == mapped
    # no page freed while a reader holds it
    assert all(pool._refs[p] == 0 for p in pool._free)
    assert all(pool._refs[p] >= 1 for p in range(1, geom.n_pages)
               if p not in pool._free_set)
    # conservation of the physical page set
    in_use = {p for r in sch.active.values() for p in r.pages}
    assert in_use | set(pool._free) == set(range(1, geom.n_pages))
    for slot, req in sch.active.items():
        # within one request no logical index maps the same page twice
        assert len(req.pages) == len(set(req.pages))
        for i, page in enumerate(req.pages):
            if pool._refs[page] > 1:
                # an aliased page lies wholly inside the prompt: strictly
                # behind every reader's write frontier, write-immutable
                assert (i + 1) * pt <= req.prompt_len
        # the COW/write-frontier page is never aliased
        w = req.cache_len // pt
        if w < len(req.pages):
            assert pool._refs[req.pages[w]] == 1
    # every indexed page is resident (dropped exactly at refcount zero)
    if sch.prefix_index is not None:
        for page in sch.prefix_index._by_page:
            assert pool._refs[page] >= 1


@hypothesis.given(st.integers(0, 2 ** 31 - 1), st.integers(8, 24),
                  st.integers(2, 6))
@hypothesis.settings(max_examples=25, deadline=None)
def test_refcount_conservation_under_prefix_sharing(seed, n_reqs, n_slots):
    """Drive plan_boundary over a shared-prefix workload with sharing on:
    refcounts always equal mapped entries, no page frees early, shared
    pages stay behind write frontiers, COW pages stay private."""
    rng = np.random.RandomState(seed)
    max_len, chunk, pt = 32, 4, 8
    pb = sm.kv_bytes_per_token(TINY) * pt
    geom = sm.derive_page_geometry(
        TINY, max_len, page_tokens=pt, max_slots=n_slots,
        layer0_bytes=pb * int(rng.randint(4, 12)),
        layer1_bytes=pb * int(rng.randint(8, 16)))
    sch = sm.Scheduler(n_slots=n_slots, pages=geom, prefix_share=True)
    # a small pool of system prefixes => plenty of index hits, including
    # page-aligned full matches (the COW case)
    systems = [rng.randint(2, 128, size=n).astype(np.int32)
               for n in (8, 16, 12)]
    for _ in range(n_reqs):
        system = systems[int(rng.randint(len(systems)))]
        tail = rng.randint(2, 128, size=int(rng.randint(0, 8)))
        sch.submit(np.concatenate([system, tail.astype(np.int32)]),
                   int(rng.randint(1, 12)))
    for _ in range(300):
        if not sch.has_work():
            break
        sch.plan_boundary(chunk_tokens=chunk, max_len=max_len)
        check_sharing_invariants(sch, geom)
        for slot in sorted(sch.active):
            req = sch.active[slot]
            take = min(chunk, req.max_new_tokens - len(req.tokens),
                       max_len - req.cache_len)
            req.tokens.extend([7] * max(take, 0))
            if (len(req.tokens) >= req.max_new_tokens
                    or req.cache_len >= max_len):
                sch.complete(slot)
        check_sharing_invariants(sch, geom)
    assert not sch.has_work()
    assert sch.page_pool.in_use == 0 and sch.page_pool.mapped == 0
    assert sch.spill_pool.in_use == 0
    assert len(sch.prefix_index) == 0                # index dies with pages
