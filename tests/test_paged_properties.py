"""Hypothesis property tests for the paged pool's allocator + scheduler:
no double-mapped page, alloc/free conservation, block tables always
consistent with the free list."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.models.config import ModelConfig
from repro.serve import scheduler as sm

TINY = ModelConfig(
    name="tiny-paged-prop", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)


@hypothesis.given(
    st.integers(2, 40),                      # pool size
    st.lists(st.tuples(st.booleans(), st.integers(0, 7)), max_size=60),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_page_pool_conservation(n_pages, ops):
    """No page is double-mapped; alloc/free conserves the page set."""
    pool = sm.PagePool(n_pages)
    universe = set(range(1, n_pages))
    held = []
    for is_alloc, n in ops:
        if is_alloc:
            got = pool.alloc(n)
            if got is None:
                assert n > pool.n_free       # refusal only when short
            else:
                assert len(got) == n and len(set(got)) == n
                for blk in held:
                    assert set(got).isdisjoint(blk)
                held.append(got)
        elif held:
            pool.free(held.pop(n % len(held)))
    in_use = set().union(*held) if held else set()
    assert in_use | set(pool._free) == universe
    assert in_use.isdisjoint(pool._free)
    assert pool.in_use == len(in_use)


@hypothesis.given(st.integers(0, 2 ** 31 - 1), st.integers(4, 12),
                  st.integers(2, 6))
@hypothesis.settings(max_examples=25, deadline=None)
def test_scheduler_block_tables_consistent_with_free_list(seed, n_reqs,
                                                          n_slots):
    """Drive plan_boundary with a simulated decode loop: block tables must
    always map exactly the pages the free lists do not hold, with no page
    shared between two slots (and same for the spill tier)."""
    rng = np.random.RandomState(seed)
    max_len, chunk, pt = 32, 4, 8
    pb = sm.kv_bytes_per_token(TINY) * pt
    geom = sm.derive_page_geometry(
        TINY, max_len, page_tokens=pt, max_slots=n_slots,
        layer0_bytes=pb * int(rng.randint(4, 10)),
        layer1_bytes=pb * int(rng.randint(6, 12)))
    sch = sm.Scheduler(n_slots=n_slots, pages=geom)
    for _ in range(n_reqs):
        sch.submit(rng.randint(2, 128, size=rng.randint(1, 12)),
                   int(rng.randint(1, 16)))
    for _ in range(200):
        if not sch.has_work():
            break
        sch.plan_boundary(chunk_tokens=chunk, max_len=max_len)
        # ---- invariants after every boundary
        active_pages = [p for r in sch.active.values() for p in r.pages]
        assert len(active_pages) == len(set(active_pages))   # no double map
        assert set(active_pages).isdisjoint(sch.page_pool._free)
        assert set(active_pages) | set(sch.page_pool._free) == \
            set(range(1, geom.n_pages))                      # conservation
        bt = sch.block_table()
        for slot, req in sch.active.items():
            assert list(bt[slot, :len(req.pages)]) == req.pages
            assert (bt[slot, len(req.pages):] == 0).all()    # null tail
        spilled = [p for r in sch.queue if r.status == sm.PREEMPTED
                   for p in r.spill_pages]
        assert len(spilled) == len(set(spilled))
        assert set(spilled).isdisjoint(sch.spill_pool._free)
        # ---- simulate the decode chunk + drain boundary
        for slot in sorted(sch.active):
            req = sch.active[slot]
            take = min(chunk, req.max_new_tokens - len(req.tokens),
                       max_len - req.cache_len)
            req.tokens.extend([7] * max(take, 0))
            if (len(req.tokens) >= req.max_new_tokens
                    or req.cache_len >= max_len):
                sch.complete(slot)
    assert not sch.has_work()
    assert sch.page_pool.in_use == 0                         # all returned
    assert sch.spill_pool.in_use == 0
