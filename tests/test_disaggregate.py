"""Disaggregated prefill/decode roles (DESIGN.md §Disaggregated serving):
the handover primitive's ownership guard, the scheduler's HandoverStep
emission at the final prefill chunk, role-filtered block-table views, the
construction-time gates, and the end-to-end counters/sync discipline of a
disaggregated serve. Bit-identity across the feature matrix lives in
tests/test_equivalence_matrix.py."""

import jax
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm
from repro.serve.engine import Engine, EngineConfig
from repro.serve.pool import DECODE_ROLE, PREFILL_ROLE, PoolManager

MAX_LEN = 64
PT = 8

TINY = ModelConfig(
    name="tiny-disagg", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)


def _geometry(cfg, n_layer0=40, n_layer1=64):
    pb = sm.kv_bytes_per_token(cfg) * PT
    return sm.PageGeometry(page_tokens=PT, n_pages=n_layer0 + 1,
                           n_spill_pages=n_layer1 + 1,
                           max_pages_per_slot=-(-MAX_LEN // PT),
                           page_bytes=pb)


# ------------------------------------------------- the handover primitive

def _bare_pools():
    """Ownership bookkeeping touches neither the model nor the pool
    arrays, so a PoolManager with no model is a valid unit-test subject."""
    return PoolManager(None, None, lambda x: x)


def test_transfer_ownership_flips_one_entry():
    pools = _bare_pools()
    pools.claim(3, PREFILL_ROLE)
    pools.transfer_ownership(3, [5, 9, 12])
    assert pools.owner[3] == DECODE_ROLE
    assert (pools.handovers, pools.handover_pages) == (1, 3)
    pools.release(3)
    assert 3 not in pools.owner
    pools.release(3)                       # idempotent


def test_transfer_ownership_guards_src():
    """A handover for a slot the prefill role does not own (never claimed,
    preempted away, or already handed over) must refuse loudly — silent
    acceptance would corrupt role routing."""
    pools = _bare_pools()
    with pytest.raises(RuntimeError, match="owned by None"):
        pools.transfer_ownership(0, [1])
    pools.claim(0, PREFILL_ROLE)
    pools.transfer_ownership(0, [1])
    with pytest.raises(RuntimeError, match="owned by 'decode'"):
        pools.transfer_ownership(0, [1])   # double handover
    assert (pools.handovers, pools.handover_pages) == (1, 1)


def test_released_slot_cannot_hand_over():
    """Preemption frees the slot and its owner entry with it — a stale
    handover planned against a released slot must refuse; the restore
    re-claims under whatever role the request is in by then."""
    pools = _bare_pools()
    pools.claim(2, PREFILL_ROLE)
    pools.release(2)
    with pytest.raises(RuntimeError):
        pools.transfer_ownership(2, [4])


# ------------------------------------- scheduler: HandoverStep emission

def test_handover_at_final_chunk():
    """A chunked prompt hands over exactly once, at the boundary that
    plans its final chunk; earlier boundaries keep prefill ownership."""
    sch = sm.Scheduler(2, pages=_geometry(TINY), disaggregate=True,
                       chunk_prefill_tokens=6)
    req = sch.submit(np.arange(2, 16, dtype=np.int32), 4)   # 14 tokens
    plan1 = sch.plan_boundary(chunk_tokens=4, max_len=MAX_LEN)
    assert [s.final for s in plan1.prefill_steps] == [False]
    assert plan1.handovers == [] and req.owner == PREFILL_ROLE
    plan2 = sch.plan_boundary(chunk_tokens=4, max_len=MAX_LEN)
    assert plan2.handovers == [] and req.owner == PREFILL_ROLE
    plan3 = sch.plan_boundary(chunk_tokens=4, max_len=MAX_LEN)
    assert [s.final for s in plan3.prefill_steps] == [True]
    (h,) = plan3.handovers
    assert (h.slot, h.req) == (0, req)
    assert h.pages == list(req.pages) and h.pages
    assert req.owner == DECODE_ROLE
    assert (sch.handovers, sch.handover_pages) == (1, len(req.pages))


def test_handover_immediate_when_unchunked():
    """Whole-prompt admission completes prefill within its boundary, so
    the handover rides the same plan."""
    sch = sm.Scheduler(2, pages=_geometry(TINY), disaggregate=True)
    req = sch.submit(np.arange(2, 16, dtype=np.int32), 4)
    plan = sch.plan_boundary(chunk_tokens=4, max_len=MAX_LEN)
    assert [h.req for h in plan.handovers] == [req]
    assert req.owner == DECODE_ROLE


def test_block_table_role_views():
    """The decode view carries a slot's row exactly from its handover on;
    before that the row lives only in the prefill view (junk decode writes
    for mid-prefill slots route to null page 0)."""
    sch = sm.Scheduler(2, pages=_geometry(TINY), disaggregate=True,
                       chunk_prefill_tokens=6)
    sch.submit(np.arange(2, 16, dtype=np.int32), 4)
    sch.plan_boundary(chunk_tokens=4, max_len=MAX_LEN)
    full = sch.block_table()
    assert full[0].any()
    assert sch.block_table(role=PREFILL_ROLE)[0].tolist() == full[0].tolist()
    assert not sch.block_table(role=DECODE_ROLE)[0].any()
    sch.plan_boundary(chunk_tokens=4, max_len=MAX_LEN)
    sch.plan_boundary(chunk_tokens=4, max_len=MAX_LEN)      # final chunk
    full = sch.block_table()
    assert sch.block_table(role=DECODE_ROLE)[0].tolist() == full[0].tolist()
    assert not sch.block_table(role=PREFILL_ROLE)[0].any()


# ------------------------------------------------- construction-time gates

def test_disaggregate_requires_pages():
    with pytest.raises(ValueError, match="paged pool"):
        sm.Scheduler(2, disaggregate=True)
    sch = sm.Scheduler(2)
    with pytest.raises(ValueError, match="paged pool"):
        sch.enable_disaggregation()


def test_enable_disaggregation_must_precede_admission():
    sch = sm.Scheduler(2, pages=_geometry(TINY), chunk_prefill_tokens=6)
    sch.submit(np.arange(2, 10, dtype=np.int32), 4)
    sch.plan_boundary(chunk_tokens=4, max_len=MAX_LEN)
    with pytest.raises(RuntimeError, match="precede the first admission"):
        sch.enable_disaggregation()


def test_engine_rejects_disagg_on_dense_pool():
    model = build_model(TINY)
    eng = Engine(model, model.init(jax.random.PRNGKey(0)),
                 EngineConfig(max_len=MAX_LEN, sync_interval=4,
                              disaggregate=True))
    sch = sm.Scheduler(2)                  # dense slot-slab, no pages
    sch.submit(np.arange(2, 10, dtype=np.int32), 4)
    with pytest.raises(ValueError, match="paged pool"):
        eng.serve(scheduler=sch)


# --------------------------------------------------- end-to-end discipline

@pytest.fixture(scope="module")
def engine():
    model = build_model(TINY)
    return Engine(model, model.init(jax.random.PRNGKey(0)),
                  EngineConfig(max_len=MAX_LEN, sync_interval=4))


def _requests(n=5, seed=3):
    rng = np.random.RandomState(seed)
    reqs = [(rng.randint(2, 128, size=int(rng.randint(4, 20))
                         ).astype(np.int32), int(rng.randint(3, 8)))
            for _ in range(n)]
    reqs.append((rng.randint(2, 128, size=40).astype(np.int32), 5))
    return reqs


def test_disagg_serve_counters_and_sync_discipline(engine):
    """One disaggregated serve: every prompt hands over exactly once,
    pool-manager and scheduler counters agree, ownership drains with the
    slots, and the per-role sync budget holds — the decode role reads one
    fetch per boundary, the prefill role only at boundaries that completed
    a prompt (all under the transfer guard)."""
    reqs = _requests()
    sch = sm.Scheduler(3, pages=_geometry(TINY), disaggregate=True,
                       chunk_prefill_tokens=8)
    rids = [sch.submit(p, g).rid for p, g in reqs]
    with jax.transfer_guard_device_to_host("disallow"):
        rep = engine.serve(scheduler=sch)

    st = rep.stats
    assert st["disaggregate"] is True
    assert st["handovers"] == len(reqs)
    assert st["handover_pages"] > 0
    assert (engine.pools.handovers, engine.pools.handover_pages) == \
        (st["handovers"], st["handover_pages"])
    assert engine.pools.owner == {}        # all slots drained and released
    by_role = st["host_syncs_by_role"]
    assert by_role[DECODE_ROLE] == st["chunks"]
    assert 0 < by_role[PREFILL_ROLE] <= st["chunks"]
    assert st["host_syncs"] == by_role[DECODE_ROLE] + by_role[PREFILL_ROLE]
    assert st["decode_tokens"] > 0
    assert len(st["boundary_decode_wall_s"]) == st["chunks"]
    assert all(len(rep.outputs[r]) > 0 for r in rids)


def test_disagg_matches_combined(engine):
    """The role split moves no bits: same engine, same requests, with and
    without disaggregation — bit-identical outputs."""
    reqs = _requests(seed=9)
    outs = {}
    for disagg in (False, True):
        sch = sm.Scheduler(3, pages=_geometry(TINY), disaggregate=disagg,
                           chunk_prefill_tokens=8)
        rids = [sch.submit(p, g).rid for p, g in reqs]
        with jax.transfer_guard_device_to_host("disallow"):
            rep = engine.serve(scheduler=sch)
        outs[disagg] = [rep.outputs[r] for r in rids]
    assert outs[True] == outs[False]


def test_engine_config_flag_enables_routing(engine):
    """EngineConfig(disaggregate=True) must route a plain paged scheduler
    through enable_disaggregation() — no silent combined fallback."""
    prev = engine.ecfg.disaggregate
    engine.ecfg.disaggregate = True
    try:
        sch = sm.Scheduler(3, pages=_geometry(TINY),
                           chunk_prefill_tokens=8)
        sch.submit(np.arange(2, 20, dtype=np.int32), 4)
        with jax.transfer_guard_device_to_host("disallow"):
            rep = engine.serve(scheduler=sch)
    finally:
        engine.ecfg.disaggregate = prev
    assert sch.disaggregate is True
    assert rep.stats["handovers"] == 1
