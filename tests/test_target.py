"""HardwareTarget registry, CapacityPartition invariants, plan-cache hits."""

import pytest

from repro.core import planner, tiling
from repro.core.hw_profiles import MiB, TPU_V5E
from repro.core.target import (CapacityPartition, available_targets,
                               get_target, mempool_target, set_target,
                               tpu_target, use_target)


@pytest.fixture(autouse=True)
def _clean_target():
    set_target(None)
    yield
    set_target(None)


# ------------------------------------------------------------------ registry

def test_default_target_is_v5e():
    t = get_target()
    assert t.name == "tpu-v5e" and t.kind == "tpu"
    assert t.profile is TPU_V5E
    assert t.hierarchy.names == ("vmem", "hbm", "ici", "dci")
    assert t.scratchpad_bytes == TPU_V5E.vmem_bytes


def test_registry_has_all_profiles():
    names = available_targets()
    assert "tpu-v5e" in names and "tpu-v5p" in names
    assert len(available_targets(kind="mempool")) == 8


def test_get_by_name_and_normalization():
    # canonical profile spelling and normalized spelling both resolve
    assert get_target("MemPool-3D_4MiB") is get_target("mempool-3d-4mib")
    assert get_target("mempool-3d-4mib").kind == "mempool"
    assert get_target("mempool-3d-4mib").hierarchy.names == (
        "tile", "group", "cluster", "offchip")


def test_unknown_target_raises_with_choices():
    with pytest.raises(KeyError, match="tpu-v5e"):
        get_target("tpu-v9000")


def test_set_target_and_restore():
    prev = set_target("tpu-v5p")
    assert prev is None
    assert get_target().name == "tpu-v5p"
    set_target(None)
    assert get_target().name == "tpu-v5e"


def test_use_target_context():
    with use_target("mempool-2d-1mib") as t:
        assert t.kind == "mempool"
        assert get_target() is t
    assert get_target().name == "tpu-v5e"


def test_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_TARGET", "tpu-v5p")
    assert get_target().name == "tpu-v5p"
    # explicit set_target wins over the environment
    set_target("tpu-v5e")
    assert get_target().name == "tpu-v5e"


# --------------------------------------------------------- CapacityPartition

def test_partition_budget_within_capacity():
    part = CapacityPartition(capacity_bytes=128 * MiB, fraction=0.75)
    assert part.budget_bytes <= part.capacity_bytes
    assert part.budget_bytes == int(128 * MiB * 0.75)


def test_partition_required_bytes_accounting():
    part = CapacityPartition(capacity_bytes=1000, fraction=1.0, n_buffers=2)
    # 2 copies of each streamed byte + resident
    assert part.required_bytes(300, 100) == 700
    assert part.fits(300, 100) and not part.fits(500, 100)


def test_partition_margin_floor():
    # single-buffered flow keeps the db margin; full double-buffering
    # subsumes it (mult = max(n_buffers, 1 + margin))
    single = CapacityPartition(1000, n_buffers=1, db_margin=0.125)
    double = CapacityPartition(1000, n_buffers=2, db_margin=0.125)
    assert single.streamed_multiplier == 1.125
    assert double.streamed_multiplier == 2.0


def test_partition_validation():
    with pytest.raises(ValueError):
        CapacityPartition(1000, fraction=0.0)
    with pytest.raises(ValueError):
        CapacityPartition(1000, n_buffers=0)


def test_double_buffering_shrinks_blocks():
    """n_buffers=2 halves the streamed budget -> strictly smaller blocks
    when capacity binds."""
    cap = 16 * MiB
    p1 = CapacityPartition(cap, fraction=0.75, n_buffers=1)
    p2 = CapacityPartition(cap, fraction=0.75, n_buffers=2)
    m1 = tiling.plan_matmul(8192, 8192, 8192, partition=p1)
    m2 = tiling.plan_matmul(8192, 8192, 8192, partition=p2)
    assert m2.n_buffers == 2 and m1.n_buffers == 1
    assert m2.vmem_bytes() <= p2.budget_bytes
    assert (m2.bm * m2.bn, m2.bk) <= (m1.bm * m1.bn, m1.bk)
    assert m2.bm * m2.bk * m2.bn < m1.bm * m1.bk * m1.bn
    a1 = tiling.plan_attention(1 << 16, 1 << 16, 128, partition=p1)
    a2 = tiling.plan_attention(1 << 16, 1 << 16, 128, partition=p2)
    assert a2.block_q * a2.block_kv <= a1.block_q * a1.block_kv


def test_mempool_tile_rule_through_partition():
    """Acceptance: the paper's t = 256/384/544/800 via the partition path."""
    for mib, want in [(1, 256), (2, 384), (4, 544), (8, 800)]:
        target = get_target(f"mempool-2d-{mib}mib")
        part = tiling.mempool_partition(target.scratchpad_bytes)
        assert tiling.mempool_tile_size(target.scratchpad_bytes,
                                        partition=part) == want
        # the partition reproduces the paper's 3.25-tile working-set factor
        assert 2.0 * part.streamed_multiplier + 1.0 == pytest.approx(
            tiling.MEMPOOL_RESIDENT_TILES)


def test_target_partition_respects_scratchpad():
    for name in ("tpu-v5e", "mempool-3d-8mib"):
        t = get_target(name)
        part = t.partition(fraction=0.5)
        assert part.budget_bytes == int(t.scratchpad_bytes * 0.5)
        assert part.align == t.tile_align


# ----------------------------------------------------------------- plan cache

def test_plan_cache_returns_same_object():
    planner.plan_cache_clear()
    p1 = planner.matmul_kernel_plan(2048, 2048, 2048)
    p2 = planner.matmul_kernel_plan(2048, 2048, 2048)
    assert p1 is p2
    info = planner.plan_cache_info()["matmul"]
    assert info.hits >= 1 and info.misses == 1


def test_plan_cache_keys_on_target_and_shape():
    planner.plan_cache_clear()
    base = planner.attention_plan(4096, 4096, 128)
    other_shape = planner.attention_plan(8192, 8192, 128)
    other_target = planner.attention_plan(4096, 4096, 128,
                                          target=get_target("tpu-v5p"))
    assert base is not other_shape
    assert base is not other_target
    assert planner.plan_cache_info()["attention"].misses == 3


def test_plan_cache_keys_on_dtype():
    planner.plan_cache_clear()
    bf16 = planner.matmul_kernel_plan(4096, 4096, 4096, in_bytes=2)
    f32 = planner.matmul_kernel_plan(4096, 4096, 4096, in_bytes=4)
    assert bf16 is not f32


def test_model_plans_threaded_once():
    """Model.kernel_plans goes through the planner cache: same shape cell ->
    same plan objects, no re-planning."""
    from repro.models import build_model
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128)
    model = build_model(cfg)
    plans_a = model.kernel_plans(64, 64)
    plans_b = model.kernel_plans(64, 64)
    assert plans_a.attention is plans_b.attention
    assert plans_a.matmul is plans_b.matmul
    assert plans_a.target_name == get_target().name


def test_mempool_target_plans_shrink_with_capacity():
    """The same planning stack runs against MemPool targets: more SPM ->
    bigger matmul blocks (the paper's law through the unified interface)."""
    with use_target("mempool-2d-1mib"):
        small = tiling.plan_matmul(4096, 4096, 4096)
    with use_target("mempool-2d-8mib"):
        big = tiling.plan_matmul(4096, 4096, 4096)
    assert big.bm * big.bn >= small.bm * small.bn
    assert big.vmem_bytes() > small.vmem_bytes()


# ------------------------------------------------------------ tiered split

def test_stacked_partition_budgets():
    """TieredPartition stacks the same budget formula across two layers —
    the paper's die split: layer 0 keeps the base budget, layer 1 adds a
    fraction of the level's capacity on top."""
    part = CapacityPartition(capacity_bytes=1000, fraction=0.8, n_buffers=1)
    tiers = part.stacked(0.5)
    assert tiers.layer0 is part
    assert tiers.layer0.budget_bytes == 800
    assert tiers.layer1.budget_bytes == 400        # 1000 * 0.5 * 0.8
    assert tiers.budget_bytes == 1200              # the 3D capacity win
    assert tiers.tier_budgets() == (800, 400)


def test_stacked_partition_units_and_resident_charge():
    part = CapacityPartition(capacity_bytes=1000, fraction=1.0, n_buffers=1)
    tiers = part.stacked(1.0)
    # 100-byte units: 10 per layer; resident state charged to layer 0 only
    assert tiers.units_per_tier(100) == (10, 10)
    assert tiers.units_per_tier(100, resident_bytes=250) == (7, 10)


def test_stacked_partition_rejects_negative_layer1():
    part = CapacityPartition(capacity_bytes=1000, n_buffers=1)
    with pytest.raises(ValueError, match="layer1_fraction"):
        part.stacked(-0.1)
    empty = part.stacked(0.0)                      # a 2D flow: no layer 1
    assert empty.layer1.budget_bytes == 0
    assert empty.units_per_tier(100)[1] == 0
