"""Multi-device checks, executed in a subprocess with 8 forced host devices
(tests/test_distributed.py drives this). Exits non-zero on any failure.

Bundled into one process because each subprocess pays jax-import + compile
startup; each check prints PASS so the parent can assert on coverage.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd


def check_mesh_device_count():
    assert len(jax.devices()) == 8, jax.devices()
    print("PASS mesh_device_count")


def check_moe_ep_matches_dense():
    """Production EP shard_map path == dense GShard path (same routing)."""
    from repro.configs import get_reduced
    from repro.models import moe as moe_mod

    cfg = get_reduced("qwen3-moe-30b-a3b")
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model),
                          jnp.float32) * 0.1

    y_dense, aux_dense = moe_mod.moe_block(p, x, cfg=cfg, impl="dense")

    mesh = shd.make_mesh((2, 4), ("data", "model"))
    with shd.use_mesh(mesh):
        y_ep, aux_ep = jax.jit(
            lambda p, x: moe_mod.moe_block(p, x, cfg=cfg, impl="ep"))(p, x)
    np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                               np.asarray(y_dense, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(float(aux_ep), float(aux_dense), rtol=1e-3)
    print("PASS moe_ep_matches_dense")


def check_moe_ep_capacity_drops():
    """With capacity_factor<<1 the EP path drops tokens (zero contribution)
    instead of crashing — GShard semantics."""
    import dataclasses
    from repro.configs import get_reduced
    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(get_reduced("qwen3-moe-30b-a3b"),
                              capacity_factor=0.25)
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model)) * 0.1
    mesh = shd.make_mesh((2, 4), ("data", "model"))
    with shd.use_mesh(mesh):
        y, _ = jax.jit(lambda p, x: moe_mod.moe_block(p, x, cfg=cfg,
                                                      impl="ep"))(p, x)
    assert bool(jnp.isfinite(y).all())
    print("PASS moe_ep_capacity_drops")


def check_moe_partial_k_matches_dense():
    """Decode-sized batches take the token-gathering partial-K path; it must
    agree with the dense oracle exactly like the weight-gather path."""
    from repro.configs import get_reduced
    from repro.models import moe as moe_mod

    cfg = get_reduced("qwen3-moe-30b-a3b")
    key = jax.random.PRNGKey(3)
    p = moe_mod.init_moe(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 1, cfg.d_model),
                          jnp.float32) * 0.1
    y_dense, aux_dense = moe_mod.moe_block(p, x, cfg=cfg, impl="dense")
    mesh = shd.make_mesh((2, 4), ("data", "model"))
    with shd.use_mesh(mesh):
        y_ep, aux_ep = jax.jit(
            lambda p, x: moe_mod.moe_block(p, x, cfg=cfg, impl="ep"))(p, x)
    np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                               np.asarray(y_dense, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(float(aux_ep), float(aux_dense), rtol=1e-3)
    print("PASS moe_partial_k_matches_dense")


def check_compressed_psum():
    """int8+EF gradient sync: mean error bounded by quant step; error
    feedback replays the residual next round."""
    from repro.distributed import collectives

    mesh = shd.make_mesh((8,), ("pod",), explicit=True)
    g_local = np.random.default_rng(0).standard_normal((8, 64)).astype(np.float32)
    err0 = np.zeros((8, 64), np.float32)

    def body(g, e):
        return collectives.compressed_psum_mean(g, e, "pod", 8)

    out, new_err = jax.jit(shd.shard_map(
        body, mesh=mesh, in_specs=(P("pod"), P("pod")),
        out_specs=(P("pod"), P("pod"))))(jnp.asarray(g_local), jnp.asarray(err0))
    true_mean = g_local.mean(axis=0)
    got = np.asarray(out)[0]
    scale = np.abs(g_local).max() / 127.0
    assert np.abs(got - true_mean).max() <= scale * 1.01, \
        (np.abs(got - true_mean).max(), scale)
    # error feedback: residual equals what quantization dropped locally
    assert np.abs(np.asarray(new_err)).max() <= scale * 0.51
    # over repeated rounds with the SAME gradient, the time-average of the
    # compressed means converges to the true mean (unbiased over time)
    e = jnp.asarray(err0)
    acc = np.zeros_like(true_mean)
    rounds = 16
    for _ in range(rounds):
        out, e = jax.jit(shd.shard_map(
            body, mesh=mesh, in_specs=(P("pod"), P("pod")),
            out_specs=(P("pod"), P("pod"))))(jnp.asarray(g_local), e)
        acc += np.asarray(out)[0]
    drift = np.abs(acc / rounds - true_mean).max()
    assert drift <= scale * 0.15, drift
    print("PASS compressed_psum")


def check_sharded_train_step():
    """A reduced model train step under a real (2,4) mesh with the production
    sharding rules: must compile, run, and produce finite loss."""
    from repro.configs import get_reduced
    from repro.distributed import sharding as shd
    from repro.models import build_model
    from repro.train import optimizer as opt_mod
    from repro.train.loop import TrainConfig, make_train_step

    cfg = get_reduced("yi-6b")
    model = build_model(cfg)
    mesh = shd.make_mesh((2, 4), ("data", "model"))
    with shd.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, shd.named_shardings(params, mesh))
        tcfg = TrainConfig(n_microbatches=2)
        state = opt_mod.init_opt_state(params, tcfg.opt)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
        step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
        p2, s2, metrics = step(params, state, batch)
        assert np.isfinite(float(metrics["total_loss"]))
        # the wq parameter kept its rule-prescribed sharding
        wq = p2["groups"]["blocks"]["pos0"]["attn"]["wq"]
        assert "model" in str(wq.sharding.spec), wq.sharding
    print("PASS sharded_train_step")


def check_pooled_decode():
    """Decode with the KV cache sharded on the sequence dim across `model`
    (flash-decoding / pooled memory) == single-device decode."""
    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("yi-6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    _, state = model.prefill(params, {"tokens": toks}, max_len=32)
    nxt = toks[:, -1:]
    cache_len = jnp.asarray(16, jnp.int32)
    ref_logits, _ = model.decode_step(params, nxt, state, cache_len)

    mesh = shd.make_mesh((2, 4), ("data", "model"))
    from repro.launch.dryrun import decode_shard_specs
    with shd.use_mesh(mesh):
        inputs = {"tokens": nxt, "state": state, "cache_len": cache_len}
        specs = decode_shard_specs(jax.eval_shape(lambda: inputs), mesh,
                                   batch=2)
        sharded = jax.device_put(inputs, specs)
        logits, _ = jax.jit(model.decode_step)(
            params, sharded["tokens"], sharded["state"], sharded["cache_len"])
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=3e-2, atol=3e-2)
    print("PASS pooled_decode")


def check_elastic_reshard_roundtrip():
    """Save on a (2,4) mesh, restore onto (4,2) — values identical."""
    import tempfile
    from repro.train.checkpoint import CheckpointManager
    from repro.distributed import sharding as shd

    state = {"w_gate": jax.random.normal(jax.random.PRNGKey(0), (64, 32))}
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    sh_a = shd.named_shardings(state, mesh_a)
    sh_b = shd.named_shardings(state, mesh_b)
    placed = jax.device_put(state, sh_a)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=1)
        mgr.save(1, placed, blocking=True)
        _, restored = mgr.restore(jax.eval_shape(lambda: state), shardings=sh_b)
    assert restored["w_gate"].sharding.mesh.shape["data"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w_gate"]),
                                  np.asarray(state["w_gate"]))
    print("PASS elastic_reshard_roundtrip")


CHECKS = [check_mesh_device_count, check_moe_ep_matches_dense,
          check_moe_ep_capacity_drops, check_moe_partial_k_matches_dense,
          check_compressed_psum, check_sharded_train_step,
          check_pooled_decode, check_elastic_reshard_roundtrip]


if __name__ == "__main__":
    names = sys.argv[1:]
    for fn in CHECKS:
        if names and fn.__name__ not in names:
            continue
        fn()
    print("ALL_DIST_CHECKS_PASSED")
