"""Analytic HBM traffic model (roofline memory term) sanity checks."""

import pytest

from repro.configs import get_config
from repro.core import traffic
from repro.core.traffic import MeshDims

MESH = MeshDims(pod=1, data=16, model=16)


def test_train_components_positive():
    cfg = get_config("yi-6b")
    t = traffic.step_traffic(cfg, kind="train", seq_len=4096,
                             global_batch=256, mesh=MESH, n_micro=8)
    for k in ("params", "optimizer", "acts", "attn", "loss"):
        assert t[k] > 0, k
    assert t["cache"] == 0.0
    assert t["total"] == pytest.approx(sum(v for k, v in t.items()
                                           if k != "total"))


def test_decode_reads_cache_not_logits_heavy():
    cfg = get_config("yi-6b")
    t = traffic.step_traffic(cfg, kind="decode", seq_len=32768,
                             global_batch=128, mesh=MESH)
    assert t["cache"] > 0
    assert t["optimizer"] == 0


def test_decode_cache_scales_with_seq():
    cfg = get_config("yi-6b")
    t1 = traffic.step_traffic(cfg, kind="decode", seq_len=8192,
                              global_batch=128, mesh=MESH)
    t2 = traffic.step_traffic(cfg, kind="decode", seq_len=32768,
                              global_batch=128, mesh=MESH)
    assert t2["cache"] == pytest.approx(4 * t1["cache"], rel=0.01)


def test_mla_cache_smaller_than_gqa():
    """MLA's latent cache (576/token) vs GQA at same scale — the pooled-
    capacity play. deepseek kv=128 heads x 128 dim would be 32768 B/token
    uncompressed; latent is 1152 B/token."""
    ds = get_config("deepseek-v2-236b")
    mla_bytes = traffic._cache_bytes_per_device(ds, 128, 32768, MESH)
    import dataclasses
    fake = dataclasses.replace(ds, use_mla=False)
    gqa_bytes = traffic._cache_bytes_per_device(fake, 128, 32768, MESH)
    assert mla_bytes < gqa_bytes / 20


def test_window_caps_decode_attn_traffic():
    gm = get_config("gemma3-27b")
    t_local = traffic._decode_attn_traffic(gm, gm.kind_for_layer(0), 8,
                                           524288, MESH)
    t_global = traffic._decode_attn_traffic(gm, gm.kind_for_layer(5), 8,
                                            524288, MESH)
    assert gm.kind_for_layer(0).window == 1024
    assert gm.kind_for_layer(5).window is None
    assert t_local < t_global / 100


def test_residency_train_fits_v5e():
    """Static residency per device must fit a 16 GiB chip for every arch's
    train_4k cell (quantized moments where the dry-run uses them).
    jamba-1.5 (398B) is the one borderline case on a single 256-chip pod —
    its optimizer state alone is ~2.8 TB; it must fit on the 512-chip
    multi-pod mesh (which is how a 398B model would actually be trained)."""
    from repro.launch.dryrun import TRAIN_OVERRIDES
    from repro.configs import ARCH_IDS
    multi = MeshDims(pod=2, data=16, model=16)
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ov = TRAIN_OVERRIDES.get(arch, {})
        mesh = multi if arch == "jamba-1.5-large-398b" else MESH
        r = traffic.hbm_residency(cfg, kind="train", seq_len=4096,
                                  global_batch=256, mesh=mesh,
                                  quantized_moments=ov.get("quantized", False))
        assert r["total"] < 16 * 2**30 * 0.9, (arch, r["total"] / 2**30)


def test_residency_decode_fits_v5e():
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        r = traffic.hbm_residency(cfg, kind="decode", seq_len=32768,
                                  global_batch=128, mesh=MESH)
        assert r["total"] < 16 * 2**30 * 0.9, (arch, r["total"] / 2**30)


def test_microbatching_multiplies_param_traffic():
    cfg = get_config("qwen2.5-3b")
    t1 = traffic.step_traffic(cfg, kind="train", seq_len=4096,
                              global_batch=256, mesh=MESH, n_micro=1)
    t8 = traffic.step_traffic(cfg, kind="train", seq_len=4096,
                              global_batch=256, mesh=MESH, n_micro=8)
    assert t8["params"] == pytest.approx(8 * t1["params"])
    assert t8["acts"] == pytest.approx(t1["acts"], rel=0.01)
