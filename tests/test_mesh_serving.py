"""Mesh-sharded serving budgets: per-shard pool scaling, CLI mesh parsing.

The scheduler stays mesh-oblivious — block tables and free lists are global
logical state — except for the budget scale: an m-way mesh with head-axis
page placement exposes ``kv_shards(cfg, m)`` x the pool bytes and slots
(per-device budgets multiply, per the MaxText ``device_count * per_device``
convention). The mesh=1/mesh=2 serving equivalence cells live in
tests/test_equivalence_matrix.py.
"""

import dataclasses

import pytest

from repro.core.target import CapacityPartition, TieredPartition
from repro.launch.mesh import parse_mesh
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm

TINY = ModelConfig(
    name="tiny-mesh", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)
TINY_MLA = dataclasses.replace(TINY, name="tiny-mesh-mla", n_kv_heads=4,
                               use_mla=True, kv_lora_rank=16,
                               qk_nope_head_dim=16, qk_rope_head_dim=8,
                               v_head_dim=16)

MAX_LEN = 64
L0 = sm.kv_bytes_per_token(TINY) * 8 * MAX_LEN


def test_parse_mesh():
    assert parse_mesh("2") == ((1, 2), ("data", "model"))
    assert parse_mesh("1") == ((1, 1), ("data", "model"))
    assert parse_mesh("2x4") == ((2, 4), ("data", "model"))
    assert parse_mesh("2x4x2", "pod,data,model") \
        == ((2, 4, 2), ("pod", "data", "model"))


def test_parse_mesh_rejects_bad_specs():
    with pytest.raises(ValueError):
        parse_mesh("2x2x2")             # 3 sizes, 2 axes
    with pytest.raises(ValueError):
        parse_mesh("0x2")               # sizes must be >= 1
    with pytest.raises(ValueError):
        parse_mesh("2", "")             # no axis names


def test_capacity_partition_scaled():
    part = CapacityPartition(capacity_bytes=1 << 20, fraction=0.5)
    assert part.scaled(1) is part
    doubled = part.scaled(2)
    assert doubled.capacity_bytes == 2 << 20
    assert doubled.fraction == part.fraction      # only capacity scales
    with pytest.raises(ValueError):
        part.scaled(0)


def test_tiered_partition_scaled():
    tiers = TieredPartition(layer0=CapacityPartition(capacity_bytes=1024),
                            layer1=CapacityPartition(capacity_bytes=4096))
    assert tiers.scaled(1) is tiers
    t2 = tiers.scaled(2)
    assert t2.layer0.capacity_bytes == 2048
    assert t2.layer1.capacity_bytes == 8192


def test_kv_shards_gqa_divisibility():
    assert sm.kv_shards(TINY, 1) == 1
    assert sm.kv_shards(TINY, 2) == 2
    # 2 KV heads cannot split 3 ways: all-or-nothing fallback
    assert sm.kv_shards(TINY, 3) == 1


def test_kv_shards_mla_replicates():
    """MLA latent pages carry no head axis — capacity must NOT be priced
    bigger than the arrays actually shard."""
    assert sm.kv_shards(TINY_MLA, 2) == 1


def test_page_geometry_scales_per_shard():
    g1 = sm.derive_page_geometry(TINY, MAX_LEN, page_tokens=8,
                                 max_slots=32, layer0_bytes=L0)
    g2 = sm.derive_page_geometry(TINY, MAX_LEN, page_tokens=8,
                                 max_slots=32, layer0_bytes=L0,
                                 model_shards=2)
    assert g2.page_bytes == g1.page_bytes         # pages stay page-sized
    # data pages double (page 0 is the reserved null page in both)
    assert g2.n_pages - 1 == 2 * (g1.n_pages - 1)
    assert g2.n_spill_pages - 1 == 2 * (g1.n_spill_pages - 1)


def test_page_geometry_mla_does_not_scale():
    kw = dict(page_tokens=8, max_slots=32,
              layer0_bytes=sm.kv_bytes_per_token(TINY_MLA) * 8 * MAX_LEN)
    g1 = sm.derive_page_geometry(TINY_MLA, MAX_LEN, **kw)
    g2 = sm.derive_page_geometry(TINY_MLA, MAX_LEN, model_shards=2, **kw)
    assert g2.n_pages == g1.n_pages


def test_derive_n_slots_scales_with_mesh():
    g1 = sm.derive_page_geometry(TINY, MAX_LEN, page_tokens=8,
                                 max_slots=32, layer0_bytes=L0)
    g2 = sm.derive_page_geometry(TINY, MAX_LEN, page_tokens=8,
                                 max_slots=32, layer0_bytes=L0,
                                 model_shards=2)
    s1 = sm.derive_n_slots(TINY, MAX_LEN, pages=g1, max_slots=32)
    s2 = sm.derive_n_slots(TINY, MAX_LEN, pages=g2, max_slots=32,
                           model_shards=2)
    assert s1 >= 1
    assert s2 == 2 * s1
    # the max_slots cap scales with the mesh too (it is per-shard)
    s2_capped = sm.derive_n_slots(TINY, MAX_LEN, pages=g2, max_slots=s1,
                                  model_shards=2)
    assert s2_capped == 2 * s1
