"""Chunked prefill (DESIGN.md §Chunked prefill): bit-identity with
one-shot admission across attention families, budget/cursor invariants,
composition with preemption and copy-on-write, bounded jit compile cache,
and rejection of recurrent-state families."""

import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm
from repro.serve.engine import Engine, EngineConfig

TINY = ModelConfig(
    name="tiny-chunk", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)
TINY_WINDOW = dataclasses.replace(TINY, name="tiny-chunk-win", n_layers=3,
                                  window=8, local_global_ratio=2)
TINY_MLA = dataclasses.replace(TINY, name="tiny-chunk-mla", n_kv_heads=4,
                               use_mla=True, kv_lora_rank=16,
                               qk_nope_head_dim=16, qk_rope_head_dim=8,
                               v_head_dim=16)
TINY_HYBRID = dataclasses.replace(TINY, name="tiny-chunk-hyb",
                                  family="hybrid", n_layers=4, ssm_d_state=8,
                                  ssm_conv=4, attn_period=2, attn_offset=1)
MAX_LEN = 64
PT = 8


def _geometry(cfg, n_layer0=40, n_layer1=64):
    pb = sm.kv_bytes_per_token(cfg) * PT
    return sm.PageGeometry(page_tokens=PT, n_pages=n_layer0 + 1,
                           n_spill_pages=n_layer1 + 1,
                           max_pages_per_slot=-(-MAX_LEN // PT),
                           page_bytes=pb)


def _mixed_stream(n=6, system_len=16, vocab=128, seed=7):
    """Shared-prefix shorts plus one long prompt spanning many chunks."""
    rng = np.random.RandomState(seed)
    system = rng.randint(2, vocab, size=system_len).astype(np.int32)
    out = []
    for _ in range(n):
        tail = rng.randint(2, vocab,
                           size=int(rng.randint(2, 9))).astype(np.int32)
        out.append((np.concatenate([system, tail]), int(rng.randint(2, 7))))
    out.append((rng.randint(2, vocab, size=48).astype(np.int32), 5))
    return out


@pytest.fixture(scope="module")
def engines():
    cache = {}

    def get(cfg):
        if cfg.name not in cache:
            model = build_model(cfg)
            cache[cfg.name] = Engine(
                model, model.init(jax.random.PRNGKey(0)),
                EngineConfig(max_len=MAX_LEN, sync_interval=4))
        return cache[cfg.name]

    return get


def _serve(engine, reqs, *, chunk, share=False, paged=True, n_layer0=40):
    geom = _geometry(engine.model.cfg, n_layer0) if paged else None
    sch = sm.Scheduler(3, pages=geom, prefix_share=share,
                       chunk_prefill_tokens=chunk)
    for p, g in reqs:
        sch.submit(p, g)
    with jax.transfer_guard_device_to_host("disallow"):
        rep = engine.serve(scheduler=sch)
    return {r.rid: r.tokens for r in rep.requests}, rep.stats, sch


# ------------------------------------------------------------ bit-identity

@pytest.mark.parametrize("cfg", [TINY, TINY_WINDOW, TINY_MLA],
                         ids=lambda c: c.name)
@pytest.mark.parametrize("share", [False, True], ids=["plain", "share"])
def test_chunked_matches_one_shot_paged(engines, cfg, share):
    """Chunked admission must be bit-identical to whole-prompt admission
    for every attention family, with sharing on or off, and keep the
    one-host-sync-per-boundary contract (enforced by the transfer guard
    around the serve loop)."""
    eng = engines(cfg)
    reqs = _mixed_stream()
    base, _, _ = _serve(eng, reqs, chunk=None, share=False)
    out, st, _ = _serve(eng, reqs, chunk=6, share=share)
    assert out == base
    assert st["prefill_chunks"] > len(reqs)   # the long prompt split
    assert st["host_syncs"] == st["chunks"]


def test_chunked_matches_one_shot_dense(engines):
    eng = engines(TINY)
    reqs = _mixed_stream(seed=11)
    base, _, _ = _serve(eng, reqs, chunk=None, paged=False)
    out, st, _ = _serve(eng, reqs, chunk=6, paged=False)
    assert out == base
    assert st["prefill_chunks"] > len(reqs)
    assert st["host_syncs"] == st["chunks"]


def test_chunked_survives_preemption_and_cow(engines):
    """A tight layer-0 pool forces mid-prefill preemption (the cursor must
    survive spill/restore) and identical page-aligned prompts force
    copy-on-write admissions — outputs must still match the roomy-pool
    one-shot run."""
    eng = engines(TINY)
    rng = np.random.RandomState(13)
    p24 = rng.randint(2, 128, size=24).astype(np.int32)
    reqs = [(p24.copy(), 16), (p24.copy(), 16), (p24.copy(), 16),
            (rng.randint(2, 128, size=44).astype(np.int32), 12),
            (p24.copy(), 10)]
    base, _, _ = _serve(eng, reqs, chunk=None, share=False, n_layer0=24)
    hit_preempt = hit_cow = False
    for share in (False, True):
        out, st, _ = _serve(eng, reqs, chunk=5, share=share, n_layer0=9)
        assert out == base, share
        hit_preempt |= st["preemptions"] > 0
        hit_cow |= st.get("cow_copies", 0) > 0
    assert hit_preempt, "tight pool never preempted a mid-prefill request"
    assert hit_cow, "identical prompts never took the COW path"


# --------------------------------------------------- scheduler invariants

def test_boundary_budget_caps_prefill_and_decode_interleaves(engines):
    """The deterministic stall regression: with chunking, no boundary
    prefills more than the budget (one-shot admission puts the whole long
    prompt into a single boundary), and decode tokens keep flowing at
    boundaries that also carry prefill chunks."""
    eng = engines(TINY)
    reqs = _mixed_stream(seed=5)
    _, st_one, sch_one = _serve(eng, reqs, chunk=None)
    _, st_chunk, sch_chunk = _serve(eng, reqs, chunk=8)
    assert st_one["max_boundary_prefill_tokens"] >= 48   # the admission stall
    assert 0 < st_chunk["max_boundary_prefill_tokens"] <= 8
    emitted = eng.last_stats["boundary_tokens"]
    prefilled = sch_chunk.boundary_prefill_tokens
    assert len(emitted) == len(prefilled)
    overlap = [t for p, t in zip(prefilled, emitted) if p > 0 and t > 0]
    assert overlap, "no boundary interleaved prefill chunks with decode"


def test_dense_plan_prefill_budget_sharing():
    """Oldest-first budget split: a boundary's budget flows to the oldest
    in-prefill request first; the remainder starts the next one."""
    sch = sm.Scheduler(2, chunk_prefill_tokens=4)
    sch.submit(np.arange(2, 12, dtype=np.int32), 4)     # 10 tokens
    sch.submit(np.arange(2, 5, dtype=np.int32), 4)      # 3 tokens
    assert len(sch.admit()) == 2
    got = []
    for _ in range(5):
        got.extend((s.req.rid, s.start, s.n_tokens, s.final)
                   for s in sch.plan_prefill())
    # request 0 consumes whole boundaries until its final 2-token chunk
    # leaves budget for request 1 to start within the same boundary
    assert got == [(0, 0, 4, False), (0, 4, 4, False),
                   (0, 8, 2, True), (1, 0, 2, False), (1, 2, 1, True)]
    assert sch.active[0].prefill_pos == 10
    assert sch.active[1].prefill_pos == 3


def test_derive_prefill_chunk_power_of_two():
    chunk = sm.derive_prefill_chunk(TINY)
    assert chunk >= 1 and chunk & (chunk - 1) == 0
    assert chunk <= 512
    assert sm.derive_prefill_chunk(TINY, max_chunk=64) <= 64


# ------------------------------------------------------- jit cache bounds

def test_compile_cache_stays_logarithmic(engines):
    """Chunk lengths are bucketed to powers of two, so the jitted
    chunk-prefill variants stay O(log max_len) x {final, non-final} even
    after serving many distinct prompt lengths."""
    eng = engines(TINY)
    rng = np.random.RandomState(3)
    reqs = [(rng.randint(2, 128, size=n).astype(np.int32), 3)
            for n in (3, 5, 9, 13, 17, 23, 31, 41, 47)]
    _serve(eng, reqs, chunk=16)
    _serve(eng, reqs, chunk=16, paged=False)
    bound = 2 * (int(math.log2(MAX_LEN)) + 1)
    assert 0 < len(eng._chunk_prefill_fns) <= bound
    assert 0 < len(eng._dense_chunk_prefill_fns) <= bound
    for (_, _, n_pad, _) in eng._chunk_prefill_fns:
        assert n_pad & (n_pad - 1) == 0 or n_pad == MAX_LEN
    for (n_pad, _) in eng._dense_chunk_prefill_fns:
        assert n_pad & (n_pad - 1) == 0 or n_pad == MAX_LEN


def test_bucket_len_sequence_pinned():
    """EngineCore.bucket_len is THE bucketing rule (one helper, three
    former call sites) — pin the exact sequence so dedup can never shift a
    jit-cache key. Power-of-two mode over a 64-deep cache, the slot-depth
    overrun edge, and the prompt-pad multiple mode."""
    from repro.serve.engine import EngineCore
    bl = EngineCore.bucket_len
    seq = [bl(n, 64) for n in range(1, 65)]
    assert seq == ([1, 2] + [4] * 2 + [8] * 4 + [16] * 8
                   + [32] * 16 + [64] * 32)
    assert len(set(seq)) == int(math.log2(64)) + 1   # O(log max_len) keys
    assert bl(80, 64) == 64                          # clamped to the limit
    # the depth edge: a padded chunk that would overrun the cache from
    # `start` falls back to the exact length (traced-start writes must not
    # clamp backwards over earlier chunks)
    assert bl(5, 64, start=56) == 8                  # 56 + 8 == 64: fits
    assert bl(5, 64, start=61) == 5                  # 61 + 8 > 64: exact
    # multiple mode (prompt_pad_multiple admission bucketing)
    assert [bl(n, 64, multiple=8) for n in (1, 7, 8, 9, 16, 17)] == \
        [8, 8, 8, 16, 16, 24]
    assert bl(100, 64, multiple=8) == 64


# ------------------------------------------------------------- family gate

@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_recurrent_families_rejected(paged):
    """SSM/hybrid models have no resumable KV prefix: chunked serving must
    refuse loudly instead of silently corrupting recurrent state."""
    model = build_model(TINY_HYBRID)
    eng = Engine(model, model.init(jax.random.PRNGKey(0)),
                 EngineConfig(max_len=MAX_LEN, sync_interval=4))
    geom = _geometry(TINY_HYBRID) if paged else None
    sch = sm.Scheduler(2, pages=geom, chunk_prefill_tokens=4)
    sch.submit(np.arange(2, 10, dtype=np.int32), 3)
    with pytest.raises(ValueError, match="chunked prefill requires"
                                         " attention-only"):
        eng.serve(scheduler=sch)
