"""Serving engine: greedy generation, determinism, EOS handling, and the
continuous-batching slot pool (equivalence, slot reuse, on-device decode)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve.engine import Engine, EngineConfig
from repro.serve.scheduler import Request, Scheduler

TINY = ModelConfig(
    name="tiny-serve", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)


@pytest.fixture(scope="module")
def engine():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, EngineConfig(max_len=64, eos_token=1))


def test_generate_shapes_and_determinism(engine):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2,
                              TINY.vocab_size)
    out1, _ = engine.generate({"tokens": toks}, n_steps=6)
    out2, _ = engine.generate({"tokens": toks}, n_steps=6)
    assert out1.shape[0] == 2 and out1.shape[1] <= 6
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < TINY.vocab_size


def test_generate_matches_teacher_forced_argmax(engine):
    """Greedy decode must equal argmax over the full-forward logits computed
    on the generated prefix — cache exactness at the engine level."""
    from repro.models import layers, transformer
    model, params = engine.model, engine.params
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 2,
                              TINY.vocab_size)
    gen, _ = engine.generate({"tokens": toks}, n_steps=4)
    seq = jnp.concatenate([toks, gen], axis=1)
    x, _, _ = transformer.forward(TINY, params, seq, remat=False)
    logits = layers.unembed_logits(params["tok"], x)
    for i in range(gen.shape[1]):
        pos = toks.shape[1] + i - 1
        want = int(jnp.argmax(logits[0, pos, :TINY.vocab_size]))
        got = int(gen[0, i])
        if got == 1:   # EOS fill after termination
            break
        assert got == want, (i, got, want)


def test_eos_stops_generation():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(max_len=64, eos_token=1))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 4), 2,
                              TINY.vocab_size)
    out, _ = eng.generate({"tokens": toks}, n_steps=8)
    hit = np.where(np.asarray(out[0]) == 1)[0]
    if hit.size:   # everything after the first EOS must stay EOS
        assert (np.asarray(out[0])[hit[0]:] == 1).all()


# ------------------------------------------------- on-device decode loop

def test_no_per_token_host_sync(engine):
    """The decode loop must stay on-device: any implicit device->host
    transfer (the old per-token ``bool(done.all())``) faults under the
    transfer guard. Host reads happen only at drain boundaries, through
    the engine's counted fetch path."""
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 2,
                              TINY.vocab_size)
    with jax.transfer_guard_device_to_host("disallow"):
        out, _ = engine.generate({"tokens": toks}, n_steps=12)
    stats = engine.last_stats
    assert stats["decode_steps"] == 11
    # drain boundaries only: at most one sync per sync_interval chunk
    n_chunks = -(-11 // engine.ecfg.sync_interval)
    assert stats["host_syncs"] <= n_chunks
    assert out.shape[1] <= 12


def test_serve_no_per_token_host_sync(engine):
    rng = np.random.RandomState(0)
    sch = Scheduler(n_slots=2)
    for _ in range(4):
        sch.submit(rng.randint(2, TINY.vocab_size, size=6), 5)
    with jax.transfer_guard_device_to_host("disallow"):
        report = engine.serve(scheduler=sch)
    assert report.stats["drained"] == 4
    assert report.stats["host_syncs"] == report.stats["chunks"]


# ------------------------------------------------- continuous batching

def test_continuous_matches_one_shot(engine):
    """Continuous-batched outputs must equal one-shot generate for the
    same prompts — slot scatter + per-slot cache_len change nothing."""
    toks = jax.random.randint(jax.random.PRNGKey(7), (3, 8), 2,
                              TINY.vocab_size)
    want, _ = engine.generate({"tokens": toks}, n_steps=7)
    sch = Scheduler(n_slots=3)
    for i in range(3):
        sch.submit(np.asarray(toks[i]), 7)
    report = engine.serve(scheduler=sch)
    got = report.outputs
    for i in range(3):
        ref = list(map(int, want[i]))
        # one-shot pads with EOS after termination; continuous drains the
        # slot instead — compare up to the continuous length
        assert got[i] == ref[:len(got[i])]
        assert len(got[i]) <= 7
        if len(got[i]) < 7:              # early drain must be a real EOS
            assert got[i][-1] == engine.ecfg.eos_token


def test_continuous_matches_one_shot_mixed_lengths(engine):
    """Rows at different fill depths decode together bit-exactly."""
    rng = np.random.RandomState(3)
    prompts = [rng.randint(2, TINY.vocab_size, size=n).astype(np.int32)
               for n in (4, 9, 6)]
    want = [engine.generate({"tokens": jnp.asarray(p)[None]}, n_steps=5)[0]
            for p in prompts]
    sch = Scheduler(n_slots=3)
    for p in prompts:
        sch.submit(p, 5)
    got = engine.serve(scheduler=sch).outputs
    for i, w in enumerate(want):
        ref = list(map(int, w[0]))
        assert got[i] == ref[:len(got[i])], (i, got[i], ref)


def test_stream_slot_reuse_and_completion():
    """ISSUE acceptance: >=32 mixed-length requests complete through the
    scheduler with slot reuse observed."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=40, sync_interval=4,
                              prompt_pad_multiple=4))
    rng = np.random.RandomState(0)
    sch = Scheduler(n_slots=4)
    for _ in range(32):
        sch.submit(rng.randint(2, TINY.vocab_size,
                               size=rng.randint(3, 17)),
                   int(rng.randint(2, 10)))
    report = eng.serve(scheduler=sch)
    assert report.stats["drained"] == 32
    assert report.stats["max_slot_reuse"] >= 2        # slots were reused
    assert sum(report.stats["slot_allocations"]) == 32
    for req in report.requests:
        assert 1 <= len(req.tokens) <= req.max_new_tokens
        assert req.admit_step >= req.submit_step
        assert req.finish_step >= req.admit_step


def test_slot_freed_after_eos_budget():
    """A drained slot (budget exhausted) is reallocated to a queued
    request without disturbing the other slot's in-flight decode."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(max_len=48, sync_interval=2))
    rng = np.random.RandomState(4)
    sch = Scheduler(n_slots=1)
    a = sch.submit(rng.randint(2, TINY.vocab_size, size=4), 3)
    b = sch.submit(rng.randint(2, TINY.vocab_size, size=4), 3)
    report = eng.serve(scheduler=sch)
    assert report.stats["slot_allocations"] == [2]    # same slot, twice
    assert report.stats["drained"] == 2
    # second occupant matches its solo run: no bleed-through from the first
    solo, _ = eng.generate({"tokens": jnp.asarray(b.prompt)[None]}, n_steps=3)
    ref = list(map(int, solo[0]))
    assert b.tokens == ref[:len(b.tokens)]


def test_padded_prompt_clamped_to_slot_depth():
    """prompt_pad_multiple rounding must never exceed max_len, and a prompt
    deeper than the slot is rejected with a clear error."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=30, sync_interval=2,
                              prompt_pad_multiple=8))
    rng = np.random.RandomState(5)
    sch = Scheduler(n_slots=1)
    sch.submit(rng.randint(2, TINY.vocab_size, size=26), 3)  # pads to 30, not 32
    report = eng.serve(scheduler=sch)
    assert report.stats["drained"] == 1
    with pytest.raises(ValueError, match="exceeds the KV slot depth"):
        eng.admit_into_slot(eng.init_pool(1), 0,
                            rng.randint(2, TINY.vocab_size, size=31), 3)


def test_oversized_prompt_rejected_without_aborting_stream(engine):
    """One invalid request must not abort serve() or leak its slot."""
    rng = np.random.RandomState(6)
    sch = Scheduler(n_slots=2)
    ok1 = sch.submit(rng.randint(2, TINY.vocab_size, size=6), 4)
    bad = sch.submit(rng.randint(2, TINY.vocab_size, size=100), 4)  # > max_len
    ok2 = sch.submit(rng.randint(2, TINY.vocab_size, size=6), 4)
    report = engine.serve(scheduler=sch)
    by_rid = {r.rid: r for r in report.requests}
    assert by_rid[bad.rid].status == "rejected"
    assert by_rid[bad.rid].tokens == []
    for req in (ok1, ok2):
        assert by_rid[req.rid].status == "drained"
        assert 1 <= len(by_rid[req.rid].tokens) <= 4


def test_nonpositive_budget_rejected_at_submit():
    sch = Scheduler(n_slots=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        sch.submit(np.arange(2, 6, dtype=np.int32), 0)


def test_prompt_padding_rejected_for_ssm():
    cfg = dataclasses.replace(TINY, name="tiny-ssm", family="ssm",
                              n_layers=2, ssm_d_state=8, ssm_conv=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="prompt_pad_multiple"):
        Engine(model, params, EngineConfig(max_len=32,
                                           prompt_pad_multiple=8))
