"""Serving engine: greedy generation, determinism, EOS handling."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve.engine import Engine, EngineConfig

TINY = ModelConfig(
    name="tiny-serve", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)


@pytest.fixture(scope="module")
def engine():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, EngineConfig(max_len=64, eos_token=1))


def test_generate_shapes_and_determinism(engine):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2,
                              TINY.vocab_size)
    out1, _ = engine.generate({"tokens": toks}, n_steps=6)
    out2, _ = engine.generate({"tokens": toks}, n_steps=6)
    assert out1.shape[0] == 2 and out1.shape[1] <= 6
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < TINY.vocab_size


def test_generate_matches_teacher_forced_argmax(engine):
    """Greedy decode must equal argmax over the full-forward logits computed
    on the generated prefix — cache exactness at the engine level."""
    from repro.models import layers, transformer
    model, params = engine.model, engine.params
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 2,
                              TINY.vocab_size)
    gen, _ = engine.generate({"tokens": toks}, n_steps=4)
    seq = jnp.concatenate([toks, gen], axis=1)
    x, _, _ = transformer.forward(TINY, params, seq, remat=False)
    logits = layers.unembed_logits(params["tok"], x)
    for i in range(gen.shape[1]):
        pos = toks.shape[1] + i - 1
        want = int(jnp.argmax(logits[0, pos, :TINY.vocab_size]))
        got = int(gen[0, i])
        if got == 1:   # EOS fill after termination
            break
        assert got == want, (i, got, want)


def test_eos_stops_generation():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(max_len=64, eos_token=1))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 4), 2,
                              TINY.vocab_size)
    out, _ = eng.generate({"tokens": toks}, n_steps=8)
    hit = np.where(np.asarray(out[0]) == 1)[0]
    if hit.size:   # everything after the first EOS must stay EOS
        assert (np.asarray(out[0])[hit[0]:] == 1).all()
