"""Checkpoint manager: atomicity, round-trip fidelity, keep-k, async, elastic
restore (logical arrays -> new shardings)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _state(step):
    k = jax.random.PRNGKey(step)
    return {
        "params": {"w": jax.random.normal(k, (16, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"m": jnp.full((16, 8), 0.5), "step": jnp.asarray(step)},
    }


def test_roundtrip_bitexact(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state(7)
    mgr.save(7, state, blocking=True)
    step, restored = mgr.restore(jax.eval_shape(lambda: state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_pruning(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(1), blocking=True)
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names)
    # a stale tmp dir (simulated crash) must be invisible to restore
    os.makedirs(tmp_path / ".tmp-99")
    assert mgr.latest_step() == 1


def test_async_save_overlaps_then_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _state(5), blocking=False)      # returns immediately
    mgr.wait()
    assert mgr.latest_step() == 5


def test_manifest_extra_payload(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(3, _state(3), blocking=True, extra={"mesh": "16x16", "loss": 1.5})
    with open(tmp_path / "step_0000000003" / "manifest.json") as f:
        man = json.load(f)
    assert man["step"] == 3 and man["mesh"] == "16x16"


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore places logical arrays onto
    whatever shardings the *new* mesh prescribes."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    state = _state(11)
    mgr.save(11, state, blocking=True)

    mesh = jax.make_mesh((1,), ("data",))
    sharding = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sharding, state)
    step, restored = mgr.restore(jax.eval_shape(lambda: state),
                                 shardings=shardings)
    assert step == 11
    w = restored["params"]["w"]
    assert w.sharding == sharding
    np.testing.assert_array_equal(np.asarray(w), np.asarray(state["params"]["w"]))


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore({"x": jax.ShapeDtypeStruct((1,), jnp.float32)})


def test_restore_shape_mismatch_caught(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, {"w": jnp.zeros((4, 4))}, blocking=True)
    with pytest.raises(AssertionError):
        mgr.restore({"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)})
