"""Subprocess helper for the mesh=2 equivalence cells (NOT a pytest file).

Run by tests/test_equivalence_matrix.py as a child python with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` — the flag must be
set before jax imports, and tests/conftest.py forbids it in the pytest
process itself. Asserts that under a 1x2 (data x model) mesh every serving
mode {paged, paged+share, chunked, speculate} emits tokens bit-identical
to the SAME mesh engine's one-shot rollout, with one host sync per drain
boundary, under the device->host transfer guard. Prints MESH_MATRIX_OK
on success.
"""

import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    sys.exit("run with XLA_FLAGS=--xla_force_host_platform_device_count=2")

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm
from repro.serve.engine import Engine, EngineConfig

MAX_LEN = 64
PT = 8
CFG = ModelConfig(
    name="tiny-mesh2", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)

#: (cell name, prefix_share, chunk_prefill_tokens, speculate_tokens)
CELLS = (("paged", False, None, 0),
         ("paged-share", True, None, 0),
         ("chunked", False, 6, 0),
         ("speculate", False, None, 4))


def requests():
    # mirrors tests/test_equivalence_matrix.py: every cell has work
    rng = np.random.RandomState(11)
    system = np.tile(rng.randint(2, 128, size=4).astype(np.int32), 4)
    tails = [rng.randint(2, 128, size=n).astype(np.int32) for n in (7, 11)]
    motif = np.tile(rng.randint(2, 128, size=5).astype(np.int32), 5)[:22]
    rand = rng.randint(2, 128, size=13).astype(np.int32)
    return [(np.concatenate([system, tails[0]]), 14),
            (np.concatenate([system, tails[1]]), 12),
            (motif, 16),
            (rand, 10)]


def main() -> None:
    assert jax.device_count() >= 2, \
        f"expected >=2 forced host devices, got {jax.devices()}"
    mesh = make_host_mesh(1, 2)
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=MAX_LEN, sync_interval=4, mesh=mesh))
    reqs = requests()
    refs = []
    for prompt, gen in reqs:
        toks, _ = eng.generate({"tokens": jnp.asarray(prompt)[None]},
                               n_steps=gen)
        refs.append([int(t) for t in np.asarray(toks)[0]])
    pb = sm.kv_bytes_per_token(CFG) * PT
    geom = sm.PageGeometry(page_tokens=PT, n_pages=41, n_spill_pages=65,
                           max_pages_per_slot=-(-MAX_LEN // PT),
                           page_bytes=pb)
    for name, share, chunk, spec in CELLS:
        eng.ecfg.speculate_tokens = spec
        try:
            sch = sm.Scheduler(3, pages=geom, prefix_share=share,
                               chunk_prefill_tokens=chunk)
            rids = [sch.submit(p, g).rid for p, g in reqs]
            with jax.transfer_guard_device_to_host("disallow"):
                rep = eng.serve(scheduler=sch)
        finally:
            eng.ecfg.speculate_tokens = 0
        # mesh size must not change the sync discipline: one explicit
        # host read per drain boundary
        assert rep.stats["host_syncs"] == rep.stats["chunks"], \
            (name, rep.stats["host_syncs"], rep.stats["chunks"])
        if spec:
            assert rep.stats["spec_proposed"] > 0, name
        for rid, ref in zip(rids, refs):
            got = rep.outputs[rid]
            assert got and got == ref[:len(got)], (name, rid, got, ref)
        print(f"mesh=2 {name}: ok "
              f"({rep.stats['host_syncs']} syncs, "
              f"{sum(len(rep.outputs[r]) for r in rids)} tokens)")
    print("MESH_MATRIX_OK")


if __name__ == "__main__":
    main()
