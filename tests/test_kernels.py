"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Kernels execute in interpret mode on CPU (the kernel body runs in Python);
the oracles in kernels/ref.py are the ground truth.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiling
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul3d import matmul3d
from repro.kernels.mamba_scan import mamba_scan

KEY = jax.random.PRNGKey(42)


def _k(i):
    return jax.random.fold_in(KEY, i)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- matmul

@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),       # single block
    (256, 384, 512),       # multi-block all dims
    (512, 128, 256),       # deep M
    (128, 512, 128),       # deep K (accumulator carry)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    a = jax.random.normal(_k(1), (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(_k(2), (k, n), jnp.float32).astype(dtype)
    plan = tiling.MatmulPlan(bm=128, bk=128, bn=128)
    got = matmul3d(a, b, plan=plan, out_dtype=jnp.float32, interpret=True)
    want = ref.matmul_ref(a, b, jnp.float32)
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_matmul_block_shapes_irrelevant_to_result():
    """The paper's tiling changes traffic, never the numerics."""
    a = jax.random.normal(_k(3), (512, 512), jnp.float32)
    b = jax.random.normal(_k(4), (512, 512), jnp.float32)
    outs = []
    for bm, bk, bn in [(128, 128, 128), (256, 128, 256), (512, 256, 128)]:
        plan = tiling.MatmulPlan(bm, bk, bn)
        outs.append(matmul3d(a, b, plan=plan, out_dtype=jnp.float32,
                             interpret=True))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


def test_matmul_wrapper_pads_and_crops():
    """ops.matmul handles non-block-multiple shapes via pad+crop."""
    a = jax.random.normal(_k(5), (200, 300), jnp.float32)
    b = jax.random.normal(_k(6), (300, 100), jnp.float32)
    got = ops.matmul(a, b, plan=tiling.MatmulPlan(128, 128, 128), impl="pallas")
    want = ref.matmul_ref(a, b)
    assert got.shape == (200, 100)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- attention

@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])   # MHA/GQA/MQA
@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 64), (False, None),
])
def test_attention_sweep(hq, hkv, causal, window):
    b, s, d = 2, 256, 64
    q = jax.random.normal(_k(7), (b, hq, s, d), jnp.float32)
    k = jax.random.normal(_k(8), (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(_k(9), (b, hkv, s, d), jnp.float32)
    plan = tiling.AttentionPlan(block_q=128, block_kv=128)
    got = flash_attention(q, k, v, plan=plan, causal=causal, window=window,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_dtypes(dtype):
    b, h, s, d = 1, 2, 128, 64
    q = jax.random.normal(_k(10), (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(_k(11), (b, h, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(_k(12), (b, h, s, d), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, plan=tiling.AttentionPlan(64, 64),
                          causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert got.dtype == dtype
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_attention_q_offset_decode_semantics():
    """q_offset must reproduce 'query block at absolute position' masking —
    the decode/chunked-prefill contract."""
    b, h, s_kv, d = 1, 2, 256, 64
    sq, off = 128, 128
    q_full = jax.random.normal(_k(13), (b, h, s_kv, d), jnp.float32)
    k = jax.random.normal(_k(14), (b, h, s_kv, d), jnp.float32)
    v = jax.random.normal(_k(15), (b, h, s_kv, d), jnp.float32)
    full = ref.attention_ref(q_full, k, v, causal=True)
    part = flash_attention(q_full[:, :, off:off + sq], k, v,
                           plan=tiling.AttentionPlan(64, 64), causal=True,
                           q_offset=off, interpret=True)
    np.testing.assert_allclose(part, full[:, :, off:off + sq],
                               rtol=2e-4, atol=2e-4)


def test_attention_block_size_invariance():
    b, h, s, d = 1, 2, 256, 64
    q = jax.random.normal(_k(16), (b, h, s, d), jnp.float32)
    k = jax.random.normal(_k(17), (b, h, s, d), jnp.float32)
    v = jax.random.normal(_k(18), (b, h, s, d), jnp.float32)
    outs = [flash_attention(q, k, v, plan=tiling.AttentionPlan(bq, bkv),
                            causal=True, window=96, interpret=True)
            for bq, bkv in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)


def test_attention_blockwise_ref_matches_direct():
    """The XLA long-sequence path (blockwise oracle) == direct softmax."""
    b, h, s, d = 2, 2, 320, 32
    q = jax.random.normal(_k(19), (b, h, s, d), jnp.float32)
    k = jax.random.normal(_k(20), (b, h, s, d), jnp.float32)
    v = jax.random.normal(_k(21), (b, h, s, d), jnp.float32)
    for window in (None, 100):
        got = ref.attention_ref_blockwise(q, k, v, causal=True, window=window,
                                          block_q=64, block_kv=64)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- mamba scan

@pytest.mark.parametrize("length,chunk", [(64, 16), (128, 64), (128, 128)])
@pytest.mark.parametrize("di,ds", [(128, 16), (256, 8)])
def test_mamba_scan_sweep(length, chunk, di, ds):
    b = 2
    x = jax.random.normal(_k(22), (b, length, di), jnp.float32) * 0.1
    dt = jax.nn.softplus(jax.random.normal(_k(23), (b, length, di))) * 0.1
    a = -jnp.exp(jax.random.normal(_k(24), (di, ds)) * 0.1)
    bb = jax.random.normal(_k(25), (b, length, ds)) * 0.1
    c = jax.random.normal(_k(26), (b, length, ds)) * 0.1
    d = jnp.ones((di,))
    got = mamba_scan(x, dt, a, bb, c, d, plan=tiling.ScanChunkPlan(chunk),
                     interpret=True)
    want = ref.selective_scan_ref(x, dt, a, bb, c, d)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_mamba_scan_chunk_invariance():
    """State carried across chunk boundaries == monolithic scan (the paper's
    resident-tile rule applied to the SSM state)."""
    b, length, di, ds = 1, 128, 128, 16
    x = jax.random.normal(_k(27), (b, length, di)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(_k(28), (b, length, di))) * 0.1
    a = -jnp.exp(jax.random.normal(_k(29), (di, ds)) * 0.1)
    bb = jax.random.normal(_k(30), (b, length, ds)) * 0.1
    c = jax.random.normal(_k(31), (b, length, ds)) * 0.1
    d = jnp.ones((di,))
    outs = [mamba_scan(x, dt, a, bb, c, d, plan=tiling.ScanChunkPlan(ch),
                       interpret=True) for ch in (16, 32, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)


def test_selective_scan_decode_state_carry():
    """Split scan (prefix + carried h0) == full scan — the decode contract."""
    b, length, di, ds = 2, 64, 64, 16
    x = jax.random.normal(_k(32), (b, length, di)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(_k(33), (b, length, di))) * 0.1
    a = -jnp.exp(jax.random.normal(_k(34), (di, ds)) * 0.1)
    bb = jax.random.normal(_k(35), (b, length, ds)) * 0.1
    c = jax.random.normal(_k(36), (b, length, ds)) * 0.1
    d = jnp.ones((di,))
    full = ref.selective_scan_ref(x, dt, a, bb, c, d)
    half = length // 2
    y1, h = ref.selective_scan_ref(x[:, :half], dt[:, :half], a, bb[:, :half],
                                   c[:, :half], d, return_state=True)
    y2 = ref.selective_scan_ref(x[:, half:], dt[:, half:], a, bb[:, half:],
                                c[:, half:], d, h0=h)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), full,
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ ops dispatch

def test_ops_dispatch_ref_on_cpu():
    """impl='auto' uses the oracle on CPU (Pallas only via interpret)."""
    a = jax.random.normal(_k(37), (64, 64))
    b = jax.random.normal(_k(38), (64, 64))
    np.testing.assert_allclose(ops.matmul(a, b), ref.matmul_ref(a, b),
                               rtol=1e-5, atol=1e-5)


def test_ops_attention_grad_flows():
    """The model's attention path must be differentiable (training dep)."""
    q = jax.random.normal(_k(39), (1, 2, 64, 32))
    k = jax.random.normal(_k(40), (1, 2, 64, 32))
    v = jax.random.normal(_k(41), (1, 2, 64, 32))

    def f(q):
        return ops.attention(q, k, v, causal=True).sum()

    g = jax.grad(f)(q)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0
