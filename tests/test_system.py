"""End-to-end system integration: train on structured data until the loss
drops, checkpoint mid-run, crash, restore, and continue bit-exactly.
This is the fault-tolerance contract exercised end to end.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import FailureInjector
from repro.train.loop import TrainConfig, make_train_step

TINY = ModelConfig(
    name="tiny-lm", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
)


def _setup(seed=0, peak_lr=3e-3):
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(seed))
    tcfg = TrainConfig(opt=opt_mod.OptConfig(
        peak_lr=peak_lr, warmup_steps=10, decay_steps=200, weight_decay=0.0))
    state = opt_mod.init_opt_state(params, tcfg.opt)
    step = jax.jit(make_train_step(model, tcfg))
    data = SyntheticPipeline(DataConfig(
        vocab_size=TINY.vocab_size, seq_len=64, global_batch=8, seed=7,
        branching=2))
    return step, params, state, data


@pytest.mark.slow
def test_train_loss_decreases():
    """The model must actually learn the Markov structure: final loss well
    below both the initial loss and the uniform-prediction entropy."""
    step, params, state, data = _setup()
    losses = []
    for i in range(120):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["total_loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    # branching=2 Markov chain: optimal loss ~ ln(2)=0.69; init ~ ln(256)=5.5
    assert last < 0.6 * first, (first, last)
    assert last < 2.5, last


@pytest.mark.slow
def test_checkpoint_restart_is_bit_exact(tmp_path):
    """Crash at step 6, restore from the step-6 checkpoint, and the restarted
    run must produce the SAME final parameters as the uninterrupted run."""
    # ---- uninterrupted reference run: 10 steps
    step, params0, state0, data = _setup()
    p, s = params0, state0
    for i in range(10):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        p, s, _ = step(p, s, batch)
    ref_params = p

    # ---- run with a crash at step 6 + restore
    mgr = CheckpointManager(str(tmp_path), keep=2)
    inj = FailureInjector(fail_at_steps=(6,), kind="crash")
    p, s = params0, state0
    crashed_at = None
    for i in range(10):
        if inj.check(i):
            crashed_at = i
            break
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        p, s, _ = step(p, s, batch)
        if (i + 1) % 3 == 0:
            mgr.save(i + 1, {"params": p, "opt": s}, blocking=True)
    assert crashed_at == 6 and mgr.latest_step() == 6

    tmpl = jax.eval_shape(lambda: {"params": params0, "opt": state0})
    start, restored = mgr.restore(tmpl)
    p, s = restored["params"], restored["opt"]
    for i in range(start, 10):            # resume from the checkpointed step
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        p, s, _ = step(p, s, batch)

    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_moe_train_loss_decreases():
    """MoE path end-to-end (router + aux loss + experts learn)."""
    cfg = dataclasses.replace(
        TINY, name="tiny-moe", family="moe", n_experts=4, top_k=2,
        moe_d_ff=128, d_ff=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=opt_mod.OptConfig(
        peak_lr=3e-3, warmup_steps=10, decay_steps=200, weight_decay=0.0))
    state = opt_mod.init_opt_state(params, tcfg.opt)
    step = jax.jit(make_train_step(model, tcfg))
    data = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=7,
        branching=2))
    losses = []
    for i in range(80):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < 0.6 * np.mean(losses[:5]), losses[::10]


def test_straggler_detector_wired_to_step_times():
    """Step-time telemetry -> detector integration (host 0 simulated slow)."""
    from repro.train.fault_tolerance import StragglerDetector
    det = StragglerDetector(min_samples=4)
    step, params, state, data = _setup()
    import time
    for i in range(6):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        t0 = time.monotonic()
        params, state, _ = step(params, state, batch)
        dt = time.monotonic() - t0
        det.record(0, dt * 10.0)          # host 0: 10x slower
        for h in (1, 2, 3):
            det.record(h, dt)
    assert det.stragglers() == [0]
