"""Tier-codec tests (ISSUE 10): per-page quantized KV codecs.

Pins the tentpole's numeric guarantees (DESIGN.md §Tiered KV compression
& host parking): the int8 codec's round-trip error bound per leaf kind,
the write path's per-page scale invariants (monotone growth within a
page, RESET at offset 0 so a reused page never inherits a stale amax),
spilled-then-restored quantized serving bit-identical to never-spilled
(same-codec tier copies move codes + scales verbatim), and the loud
rejection of quantized codecs on recurrent families. A hypothesis
property extends the allocator model of ``test_paged_properties.py``
with codec-tagged pages: a page's bytes never change tier codec without
a planned tier copy, and per-page scales live exactly as long as the
page is mapped.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (INT8_QMAX, dequantize_page_int8,
                                           quantize_page_int8)
from repro.models import build_model
from repro.models.attention import _paged_cache_write_q
from repro.models.config import ModelConfig
from repro.serve import scheduler as sm
from repro.serve.engine import Engine, EngineConfig
from repro.serve.pool import CODECS, quant_policy

MAX_LEN = 64
PT = 8

TINY = ModelConfig(
    name="tiny-kvq", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
)
TINY_MLA = dataclasses.replace(
    TINY, name="tiny-kvq-mla", n_kv_heads=4, use_mla=True, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
TINY_HYBRID = dataclasses.replace(
    TINY, name="tiny-kvq-hybrid", family="hybrid", n_layers=4,
    ssm_d_state=8, ssm_conv=4, attn_period=2, attn_offset=1)


# ------------------------------------------------------------- codec bounds

#: (leaf kind, page shape, per-page reduced axes) — one entry per distinct
#: pooled-leaf layout the write paths quantize: GQA k/v pages are
#: (pages, kv_heads, page_tokens, head_dim) with ONE scale per page (all
#: axes but the page axis reduced), MLA latent/rope pages are
#: (pages, page_tokens, width).
LEAF_KINDS = [
    ("gqa-kv", (5, 2, PT, 16), (1, 2, 3)),
    ("mla-ckv", (5, PT, 16), (1, 2)),
    ("mla-krope", (5, PT, 8), (1, 2)),
]


@pytest.mark.parametrize("kind,shape,axes", LEAF_KINDS,
                         ids=[k[0] for k in LEAF_KINDS])
def test_int8_round_trip_error_bound_per_leaf_kind(kind, shape, axes):
    """|dequant(quant(x)) - x| <= scale/2 per element, scale = amax/127
    per page — the symmetric-int8 contract every pooled leaf relies on."""
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(*shape).astype(np.float32) * 3.0)
    codes, scales = quantize_page_int8(x, axes)
    assert codes.dtype == jnp.int8
    assert scales.shape == (shape[0],)
    back = dequantize_page_int8(codes, scales, axes)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.asarray(scales)[(slice(None),) + (None,) * (len(shape) - 1)]
    assert (err <= bound / 2 + 1e-7).all(), (kind, err.max())
    # codes stay in the symmetric range: -128 is never produced
    assert int(np.asarray(codes).min()) >= -int(INT8_QMAX)


def test_int8_all_zero_page_round_trips_exactly():
    """An all-zero page gets scale 0 and all-zero codes; dequant is exact
    (no 0/0) — fresh pages past the KV frontier stay clean."""
    x = jnp.zeros((3, 2, PT, 16), jnp.float32)
    codes, scales = quantize_page_int8(x, (1, 2, 3))
    assert (np.asarray(scales) == 0).all()
    assert (np.asarray(codes) == 0).all()
    back = dequantize_page_int8(codes, scales, (1, 2, 3))
    assert (np.asarray(back) == 0).all()


def test_int8_exact_values_round_trip_bit_exact():
    """Values already on the code lattice (k * amax/127) survive the
    round trip exactly — the property same-codec tier copies lean on."""
    scale = 0.5 / INT8_QMAX
    vals = np.array([-127, -64, 0, 1, 64, 127], np.float32) * scale
    x = jnp.asarray(np.tile(vals, (2, 1, PT, 1))[..., :6])
    codes, scales = quantize_page_int8(x, (1, 2, 3))
    back = dequantize_page_int8(codes, scales, (1, 2, 3))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# ---------------------------------------------------- write-path invariants

def _write_tokens(tokens, cache_lens):
    """Drive ``_paged_cache_write_q`` token by token over a 1-row pool
    (axis=0 layout: pages are (n_pages, page_tokens, width)) and return
    the scale trajectory observed after each append."""
    n_pages, width = 4, 6
    pages = jnp.zeros((n_pages, PT, width), jnp.int8)
    scales = jnp.zeros((n_pages,), jnp.float32)
    bt = jnp.asarray([[1, 2, 3]], jnp.int32)          # one slot, 3 pages
    traj = []
    for tok, pos in zip(tokens, cache_lens):
        new = jnp.asarray(tok, jnp.float32).reshape(1, 1, width)
        pages, scales = _paged_cache_write_q(
            pages, scales, new, jnp.asarray([pos], jnp.int32), bt, 0)
        traj.append((np.asarray(pages), np.asarray(scales)))
    return traj


def test_scale_monotone_within_page_and_resets_at_offset_zero():
    """Within one page the scale only grows (history codes only get
    COARSER, never clip); at offset 0 it RESETS to the fresh token's amax
    instead of inheriting the previous tenant's."""
    width = 6
    big = np.full((width,), 8.0, np.float32)
    small = np.full((width,), 0.5, np.float32)
    tiny = np.full((width,), 0.125, np.float32)
    # page 1: offsets 0..2 with amplitudes small, big, tiny
    # page 2: offset 0 (pos == PT) with amplitude tiny -> reset, not max
    traj = _write_tokens([small, big, tiny, tiny], [0, 1, 2, PT])
    scales = [t[1] for t in traj]
    s_small, s_big, s_tiny = (0.5 / INT8_QMAX, 8.0 / INT8_QMAX,
                              0.125 / INT8_QMAX)
    assert scales[0][1] == pytest.approx(s_small)
    assert scales[1][1] == pytest.approx(s_big)       # grew to cover big
    assert scales[2][1] == pytest.approx(s_big)       # monotone: no shrink
    assert scales[3][1] == pytest.approx(s_big)       # untouched page keeps
    assert scales[3][2] == pytest.approx(s_tiny)      # offset-0 RESET
    # the grown scale still represents the earlier small token within the
    # coarser lattice's half-step
    page1 = traj[2][0][1].astype(np.float32) * scales[2][1]
    assert np.abs(page1[0] - small).max() <= scales[2][1] / 2 + 1e-7


def test_scale_reset_protects_reused_page_precision():
    """A page reused after a big-amplitude tenant re-quantizes the NEW
    tenant at its own fine scale — without the reset the 0.01 token would
    round to codes of one or two steps of the stale 8.0-amax lattice."""
    width = 6
    big = np.full((width,), 8.0, np.float32)
    fine = np.linspace(-0.01, 0.01, width).astype(np.float32)
    traj = _write_tokens([big, fine], [0, 0])         # same page, off 0
    pages, scales = traj[-1]
    got = pages[1].astype(np.float32)[0] * scales[1]
    assert scales[1] == pytest.approx(0.01 / INT8_QMAX)
    assert np.abs(got - fine).max() <= scales[1] / 2 + 1e-7


# --------------------------------------- spill/restore serving equivalence

def _serve_outputs(cfg, engine, layer0_bytes, kv_quant, reqs):
    geom = sm.derive_page_geometry(cfg, MAX_LEN, page_tokens=PT,
                                   max_slots=3, layer0_bytes=layer0_bytes,
                                   layer1_bytes=256 * 1024,
                                   kv_quant=kv_quant)
    sch = sm.Scheduler(3, pages=geom)
    rids = [sch.submit(p, g).rid for p, g in reqs]
    with jax.transfer_guard_device_to_host("disallow"):
        rep = engine.serve(scheduler=sch)
    return [rep.outputs[r] for r in rids], rep.stats


@pytest.mark.parametrize("kv_quant", ["int8", "fp8"])
def test_quantized_spill_restore_matches_never_spilled(kv_quant):
    """Preempt-and-restore under a quantized codec is bit-identical to the
    same quantized serve with an ample pool: same-codec tier copies move
    codes AND scales verbatim, so a spill round trip is lossless even when
    the codec itself is lossy."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=MAX_LEN, sync_interval=4))
    rng = np.random.RandomState(5)
    reqs = [(rng.randint(2, 128, size=n).astype(np.int32), g)
            for n, g in ((21, 12), (17, 10), (25, 8), (13, 14))]
    ample, st_a = _serve_outputs(TINY, eng, 128 * 1024, kv_quant, reqs)
    tight, st_t = _serve_outputs(TINY, eng, 9000, kv_quant, reqs)
    assert st_t["preemptions"] > 0, "tight pool never spilled"
    assert st_a["preemptions"] == 0
    assert st_t["layer0_codec"] == ("int8" if kv_quant == "int8" else "fp8")
    assert tight == ample


# ---------------------------------------------------------- policy & gates

def test_quant_policy_mapping():
    assert quant_policy(None) == ("fp16", "fp16")
    assert quant_policy("none") == ("fp16", "fp16")
    assert quant_policy("fp16") == ("fp16", "fp16")
    assert quant_policy("fp8") == ("fp8", "int8")    # spill quantizes harder
    assert quant_policy("int8") == ("int8", "int8")
    with pytest.raises(ValueError, match="kv quant"):
        quant_policy("int4")


def test_codec_table_prices_bytes():
    assert CODECS["fp16"].bytes_per_value == 2
    assert CODECS["fp8"].bytes_per_value == 1
    assert CODECS["int8"].bytes_per_value == 1
    assert CODECS["int8"].scaled and not CODECS["fp8"].scaled
    assert not CODECS["fp16"].scaled


def test_int8_doubles_pages_in_same_budget():
    """The capacity claim: int8 fits ~2x the pages of fp16 in the SAME
    layer-0 byte budget (scales cost a little, hence >= 1.8x not 2.0x)."""
    budget = 32 * 1024
    f16 = sm.derive_page_geometry(TINY, MAX_LEN, page_tokens=PT,
                                  max_slots=32, layer0_bytes=budget)
    i8 = sm.derive_page_geometry(TINY, MAX_LEN, page_tokens=PT,
                                 max_slots=32, layer0_bytes=budget,
                                 kv_quant="int8")
    assert i8.layer0_codec == "int8" and f16.layer0_codec == "fp16"
    assert (i8.n_pages - 1) >= 1.8 * (f16.n_pages - 1)


@pytest.mark.parametrize("kv_quant", ["int8", "fp8"])
def test_recurrent_family_rejects_quantized_codecs(kv_quant):
    """SSM state is a running summary, not a token log — requantizing it
    per page would compound error every step, so both the geometry
    derivation and the pool constructor refuse loudly."""
    with pytest.raises(ValueError, match="recurrent"):
        sm.derive_page_geometry(TINY_HYBRID, MAX_LEN, page_tokens=PT,
                                max_slots=3, layer0_bytes=64 * 1024,
                                kv_quant=kv_quant)
    # the pool constructor has its own guard: a hand-built geometry with a
    # quantized codec must not slip past derive_page_geometry's check
    geom = sm.derive_page_geometry(TINY_HYBRID, MAX_LEN, page_tokens=PT,
                                   max_slots=3, layer0_bytes=64 * 1024)
    geom = dataclasses.replace(geom, layer0_codec=kv_quant,
                               layer1_codec=kv_quant)
    model = build_model(TINY_HYBRID)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=MAX_LEN, sync_interval=4))
    sch = sm.Scheduler(3, pages=geom)
    with pytest.raises(ValueError, match="recurrent"):
        eng.init_paged_pool(sch)


def test_mla_serves_quantized():
    """The MLA latent/rope leaves take the quantized path too (scaled int8
    latent + rope pages) — a smoke serve must complete every request."""
    model = build_model(TINY_MLA)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(max_len=MAX_LEN, sync_interval=4))
    rng = np.random.RandomState(9)
    reqs = [(rng.randint(2, 128, size=n).astype(np.int32), 8)
            for n in (15, 21)]
    outs, st = _serve_outputs(TINY_MLA, eng, 128 * 1024, "int8", reqs)
    assert all(len(o) == 8 for o in outs)
    assert st["layer0_codec"] == "int8"


# ----------------------------------------- codec-tagged allocator property
# (hypothesis-gated so the rest of this file still runs without it; CI
# hard-installs hypothesis, mirroring test_paged_properties.py)

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:                                    # pragma: no cover
    hypothesis = None


@pytest.mark.parametrize("seed", [0, 11, 23])
def test_codec_tags_change_only_via_tier_copies(seed):
    """Deterministic slice of the property below — runs everywhere, with
    or without hypothesis."""
    _codec_tag_property(seed, n_reqs=8, n_slots=3)


def _hyp_codec_property():
    @hypothesis.given(st.integers(0, 2 ** 31 - 1), st.integers(4, 12),
                      st.integers(2, 6))
    @hypothesis.settings(max_examples=25, deadline=None)
    def prop(seed, n_reqs, n_slots):
        _codec_tag_property(seed, n_reqs, n_slots)
    return prop


if hypothesis is not None:
    test_codec_tags_property_hypothesis = _hyp_codec_property()


def _codec_tag_property(seed, n_reqs, n_slots):
    """Extend the allocator model with codec tags: every page a request
    maps in layer 0 carries the layer-0 codec, every spilled page the
    layer-1 codec, and a request's content NEVER changes tier codec
    without a SpillAction/RestoreAction in that boundary's plan (the tier
    copy that re-encodes it). Per-page scales are modeled as living
    exactly as long as the page is mapped: the scale set == the mapped
    layer-0 page set at every boundary, and empty at drain."""
    rng = np.random.RandomState(seed)
    max_len, chunk, pt = 32, 4, 8
    geom = sm.derive_page_geometry(
        TINY, max_len, page_tokens=pt, max_slots=n_slots,
        layer0_bytes=int(rng.randint(4, 10)) * 1100,
        layer1_bytes=int(rng.randint(6, 12)) * 1100,
        kv_quant="int8")
    assert geom.layer0_codec == "int8" == geom.layer1_codec
    sch = sm.Scheduler(n_slots=n_slots, pages=geom)
    for _ in range(n_reqs):
        sch.submit(rng.randint(2, 128, size=rng.randint(1, 12)),
                   int(rng.randint(1, 16)))
    tier_of = {}                 # rid -> "l0" | "l1" (content's tier codec)
    scales = set()               # mapped layer-0 pages holding a live scale
    for _ in range(200):
        if not sch.has_work():
            break
        plan = sch.plan_boundary(chunk_tokens=chunk, max_len=max_len)
        spilled_rids = {a.req.rid for a in plan.spills}
        restored_rids = {a.req.rid for a in plan.restores}
        for slot, req in plan.admits:
            tier_of[req.rid] = "l0"
        # ---- codec-transition invariant: tier changes require a copy
        for req in list(sch.queue):
            if req.status == sm.PREEMPTED:
                if tier_of.get(req.rid) == "l0":
                    assert req.rid in spilled_rids, \
                        "page content changed codec without a spill copy"
                tier_of[req.rid] = "l1"
        for slot, req in sch.active.items():
            if tier_of.get(req.rid) == "l1":
                assert req.rid in restored_rids, \
                    "page content changed codec without a restore copy"
            tier_of[req.rid] = "l0"
        # ---- scale lifetime: exactly the mapped layer-0 pages
        scales = {p for r in sch.active.values() for p in r.pages}
        assert scales.isdisjoint(sch.page_pool._free)
        spilled_pages = [p for r in sch.queue if r.status == sm.PREEMPTED
                         for p in r.spill_pages]
        assert len(spilled_pages) == len(set(spilled_pages))
        # ---- simulate the decode chunk + drain boundary
        for slot in sorted(sch.active):
            req = sch.active[slot]
            take = min(chunk, req.max_new_tokens - len(req.tokens),
                       max_len - req.cache_len)
            req.tokens.extend([7] * max(take, 0))
            if (len(req.tokens) >= req.max_new_tokens
                    or req.cache_len >= max_len):
                sch.complete(slot)
    assert not sch.has_work()
    assert sch.page_pool.in_use == 0     # every scale's page was released
    assert sch.spill_pool.in_use == 0
